"""Build-time compile path: JAX/Pallas models lowered AOT to HLO text."""
