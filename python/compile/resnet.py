"""Layer-2 JAX model: trainable residual CNN for the Table-I experiment.

Depth-reduced, norm-free stand-in for ResNet-34 (see DESIGN.md
Substitutions): stem conv + 3 stages x 2 pre-activation basic blocks
(16/32/64 channels) + global average pool + linear head. Norm-free
training uses SkipInit-style residual scalars (alpha init 0) instead of
batch norm so the AOT artifact needs no running statistics — the rust
coordinator owns all state between steps.

The group-lasso proximal step supports both conv groupings from the paper
(Sec. III-D):
  * FK — one group per (k, n) kernel: norm over the kh*kw taps.
  * PK — one group per kernel *column* (fixed kw, k, n): norm over kh.
"""

import jax
import jax.numpy as jnp

from .kernels import prox
from .shapes import (MOMENTUM, RESNET_CHANNELS, RESNET_CLASSES, RESNET_IMG,
                     RESNET_STAGES)


def param_specs():
    """Ordered (name, shape) list — the artifact calling convention.

    Conv kernels are HWIO. Order is the flattening order used by
    ``train_step`` / ``eval_step`` and recorded in the manifest.
    """
    specs = [("stem_w", (3, 3, RESNET_CHANNELS, RESNET_STAGES[0])),
             ("stem_b", (RESNET_STAGES[0],))]
    c_in = RESNET_STAGES[0]
    for si, c in enumerate(RESNET_STAGES):
        for bi in range(2):
            p = f"s{si}b{bi}"
            specs.append((f"{p}_c1w", (3, 3, c_in if bi == 0 else c, c)))
            specs.append((f"{p}_c1b", (c,)))
            specs.append((f"{p}_c2w", (3, 3, c, c)))
            specs.append((f"{p}_c2b", (c,)))
            if bi == 0 and (si > 0 or c_in != c):
                specs.append((f"{p}_projw", (1, 1, c_in, c)))
            specs.append((f"{p}_alpha", (1,)))
        c_in = c
    specs.append(("fc_w", (RESNET_CLASSES, RESNET_STAGES[-1])))
    specs.append(("fc_b", (RESNET_CLASSES,)))
    return specs


PARAM_SPECS = param_specs()
PARAM_NAMES = [n for n, _ in PARAM_SPECS]
CONV_KERNEL_NAMES = [n for n, s in PARAM_SPECS
                     if n.endswith(("c1w", "c2w")) and len(s) == 4]


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b[None, None, None, :]


def forward(params, x):
    """Logits for x [B, 32, 32, 3] float32."""
    p = params
    h = _conv(x, p["stem_w"], p["stem_b"])
    c_in = RESNET_STAGES[0]
    for si, c in enumerate(RESNET_STAGES):
        for bi in range(2):
            pre = f"s{si}b{bi}"
            stride = 2 if (si > 0 and bi == 0) else 1
            r = jax.nn.relu(h)
            f = _conv(r, p[f"{pre}_c1w"], p[f"{pre}_c1b"], stride=stride)
            f = jax.nn.relu(f)
            f = _conv(f, p[f"{pre}_c2w"], p[f"{pre}_c2b"])
            if f"{pre}_projw" in p:
                sc = jax.lax.conv_general_dilated(
                    r, p[f"{pre}_projw"], window_strides=(stride, stride),
                    padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            else:
                sc = h
            h = sc + p[f"{pre}_alpha"] * f
        c_in = c
    h = jax.nn.relu(h)
    feat = jnp.mean(h, axis=(1, 2))                      # global average pool
    return feat @ p["fc_w"].T + p["fc_b"]


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def loss_fn(params, x, labels):
    return _xent(forward(params, x), labels)


def prox_conv(w, thresh, mode):
    """Group-lasso prox on an HWIO conv kernel (paper Sec. III-D).

    mode "fk": groups = whole kernels, i.e. reshape to (kh*kw, in*out) and
    threshold columns (rows after transpose). mode "pk": groups = kernel
    columns, reshape to (kh, kw*in*out).
    """
    kh, kw, ci, co = w.shape
    if mode == "fk":
        flat = w.reshape(kh * kw, ci * co).T          # rows = groups
    elif mode == "pk":
        flat = w.reshape(kh, kw * ci * co).T
    else:
        raise ValueError(mode)
    flat = prox.prox_group_lasso_rows(flat, thresh)
    return flat.T.reshape(kh, kw, ci, co)


def train_step(mode, *args):
    """One momentum-SGD + prox step. ``mode`` in {"fk", "pk"} is static.

    args = [P params..., P momenta..., x, labels, lr, lam] with P =
    len(PARAM_SPECS). Returns params' + momenta' + (loss,).
    """
    n = len(PARAM_SPECS)
    params = dict(zip(PARAM_NAMES, args[:n]))
    momenta = list(args[n:2 * n])
    x, labels, lr, lam = args[2 * n:]

    loss, grads = jax.value_and_grad(loss_fn)(params, x, labels)

    out_params, out_momenta = [], []
    for i, name in enumerate(PARAM_NAMES):
        g = grads[name]
        m = MOMENTUM * momenta[i] + g
        p = params[name] - lr * m
        if name in CONV_KERNEL_NAMES:
            p = prox_conv(p, lr * lam, mode)
        out_params.append(p)
        out_momenta.append(m)
    return tuple(out_params) + tuple(out_momenta) + (loss,)


def eval_step(*args):
    """args = [P params..., x, labels] -> (loss_sum, correct_count)."""
    n = len(PARAM_SPECS)
    params = dict(zip(PARAM_NAMES, args[:n]))
    x, labels = args[n:]
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    loss_sum = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=1))
    correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))
    return loss_sum, correct
