"""AOT-lower every entrypoint to HLO *text* + write the artifact manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the rust `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--only NAME]

Scalars cross the boundary as shape-[1] arrays (the rust literal bridge
works in rank>=1 buffers); wrappers index [0] internally. All entrypoints
are positional and flat; ``manifest.tsv`` records, per artifact, the
ordered input names/dtypes/shapes and output names/dtypes/shapes, and the
rust runtime is entirely manifest-driven.
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, resnet
from .shapes import (MLP_EVAL_BATCH, MLP_HIDDEN, MLP_IN, MLP_OUT,
                     MLP_SERVE_BATCH, MLP_TRAIN_BATCH, RESNET_CHANNELS,
                     RESNET_CLASSES, RESNET_EVAL_BATCH, RESNET_IMG,
                     RESNET_TRAIN_BATCH)

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Entrypoint wrappers: flat positional args, scalars as [1]-arrays,
# every output rank >= 1.
# --------------------------------------------------------------------------

def _mlp_param_specs():
    shapes = model.param_shapes()
    return [("W1", spec(shapes["W1"])), ("b1", spec(shapes["b1"])),
            ("W2", spec(shapes["W2"])), ("b2", spec(shapes["b2"]))]


def mlp_train_step_entry(w1, b1, w2, b2, m1, mb1, m2, mb2,
                         x, labels, lr, lam, colmask, cluster_labels,
                         share_flag):
    outs = model.mlp_train_step(
        w1, b1, w2, b2, m1, mb1, m2, mb2, x, labels,
        lr[0], lam[0], colmask, cluster_labels, share_flag[0])
    *state, loss = outs
    return tuple(state) + (loss.reshape(1),)


def mlp_eval_entry(w1, b1, w2, b2, x, labels):
    loss_sum, correct = model.mlp_eval_step(w1, b1, w2, b2, x, labels)
    return loss_sum.reshape(1), correct.reshape(1)


def mlp_fwd_entry(w1, b1, w2, b2, x):
    return (model.mlp_forward(w1, b1, w2, b2, x),)


def prox_entry(w, thresh):
    return (model.prox_step(w, thresh[0]),)


def shared_matvec_entry(x, onehot, centroids):
    return (model.shared_matvec_graph(x, onehot, centroids),)


def resnet_train_entry(mode):
    def entry(*args):
        *rest, lr, lam = args
        outs = resnet.train_step(mode, *rest, lr[0], lam[0])
        *state, loss = outs
        return tuple(state) + (loss.reshape(1),)
    return entry


def resnet_eval_entry(*args):
    loss_sum, correct = resnet.eval_step(*args)
    return loss_sum.reshape(1), correct.reshape(1)


def build_registry():
    """name -> (fn, [(arg_name, ShapeDtypeStruct)], [out_name, ...])."""
    mlp_params = _mlp_param_specs()
    mlp_momenta = [("m" + n, s) for n, s in mlp_params]
    reg = {}

    reg["mlp_train_step"] = (
        mlp_train_step_entry,
        mlp_params + mlp_momenta + [
            ("x", spec((MLP_TRAIN_BATCH, MLP_IN))),
            ("labels", spec((MLP_TRAIN_BATCH,), I32)),
            ("lr", spec((1,))), ("lam", spec((1,))),
            ("colmask", spec((MLP_IN,))),
            ("cluster_labels", spec((MLP_IN,), I32)),
            ("share_flag", spec((1,)))],
        [n for n, _ in mlp_params + mlp_momenta] + ["loss"])

    reg["mlp_eval"] = (
        mlp_eval_entry,
        mlp_params + [("x", spec((MLP_EVAL_BATCH, MLP_IN))),
                      ("labels", spec((MLP_EVAL_BATCH,), I32))],
        ["loss_sum", "correct"])

    reg["mlp_fwd"] = (
        mlp_fwd_entry,
        mlp_params + [("x", spec((MLP_SERVE_BATCH, MLP_IN)))],
        ["logits"])

    reg["prox_step"] = (
        prox_entry,
        [("w", spec((MLP_IN, MLP_HIDDEN))), ("thresh", spec((1,)))],
        ["w_out"])

    reg["shared_matvec"] = (
        shared_matvec_entry,
        [("x", spec((MLP_TRAIN_BATCH, MLP_IN))),
         ("onehot", spec((MLP_IN, 64))),
         ("centroids", spec((MLP_HIDDEN, 64)))],
        ["y"])

    rn_params = [(n, spec(s)) for n, s in resnet.PARAM_SPECS]
    rn_momenta = [("m_" + n, s) for n, s in rn_params]
    for mode in ("fk", "pk"):
        reg[f"resnet_train_step_{mode}"] = (
            resnet_train_entry(mode),
            rn_params + rn_momenta + [
                ("x", spec((RESNET_TRAIN_BATCH, RESNET_IMG, RESNET_IMG,
                            RESNET_CHANNELS))),
                ("labels", spec((RESNET_TRAIN_BATCH,), I32)),
                ("lr", spec((1,))), ("lam", spec((1,)))],
            [n for n, _ in rn_params + rn_momenta] + ["loss"])

    reg["resnet_eval"] = (
        resnet_eval_entry,
        rn_params + [
            ("x", spec((RESNET_EVAL_BATCH, RESNET_IMG, RESNET_IMG,
                        RESNET_CHANNELS))),
            ("labels", spec((RESNET_EVAL_BATCH,), I32))],
        ["loss_sum", "correct"])

    return reg


def _dt(d):
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


def lower_all(out_dir, only=None):
    os.makedirs(out_dir, exist_ok=True)
    reg = build_registry()
    manifest_lines = []
    for name, (fn, in_specs, out_names) in sorted(reg.items()):
        if only and name != only:
            continue
        specs = [s for _, s in in_specs]
        print(f"[aot] lowering {name} ({len(specs)} inputs)...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *specs)
        manifest_lines.append(f"artifact\t{name}\t{fname}")
        for (arg_name, s) in in_specs:
            dims = ",".join(str(d) for d in s.shape)
            manifest_lines.append(f"in\t{arg_name}\t{_dt(s.dtype)}\t{dims}")
        for out_name, s in zip(out_names, out_shapes):
            dims = ",".join(str(d) for d in s.shape)
            manifest_lines.append(f"out\t{out_name}\t{_dt(s.dtype)}\t{dims}")
        print(f"[aot]   wrote {fname} ({len(text)} chars)", flush=True)
    if not only:
        with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"[aot] wrote manifest.tsv ({len(manifest_lines)} lines)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    lower_all(args.out_dir, args.only)


if __name__ == "__main__":
    main()
