"""Layer-2 JAX model: the paper's MLP (784 -> 300 -> 10) with the
group-lasso proximal training step (paper Sec. III-B, eq. 5-8) and the
weight-sharing retraining step (Sec. III-C, eq. 9).

Everything here is build-time python: ``aot.py`` lowers these entrypoints
once to HLO text and the rust coordinator drives the artifacts via PJRT.
The proximal operator is the Pallas kernel from ``kernels/prox.py`` so the
L1 kernel lowers into the same HLO module.

Parameter flattening order (rust runtime relies on it, and it is recorded
in artifacts/manifest.tsv): W1 [H, K], b1 [H], W2 [O, H], b2 [O].
"""

import jax
import jax.numpy as jnp

from .kernels import prox
from .shapes import MLP_HIDDEN, MLP_IN, MLP_OUT, MOMENTUM

PARAM_NAMES = ("W1", "b1", "W2", "b2")


def param_shapes():
    return {
        "W1": (MLP_HIDDEN, MLP_IN),
        "b1": (MLP_HIDDEN,),
        "W2": (MLP_OUT, MLP_HIDDEN),
        "b2": (MLP_OUT,),
    }


def mlp_forward(w1, b1, w2, b2, x):
    """Logits for a batch ``x`` [B, 784]. ReLU hidden layer (paper eq. 1)."""
    h = jax.nn.relu(x @ w1.T + b1)
    return h @ w2.T + b2


def _xent(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def mlp_loss(w1, b1, w2, b2, x, labels):
    return _xent(mlp_forward(w1, b1, w2, b2, x), labels)


def _cluster_mean_grads(g, labels, active):
    """Average the columns of gradient ``g`` within each cluster (eq. 9).

    labels [K] int32 maps every column to its cluster id in [0, K); inactive
    (pruned) columns must point at themselves so they do not pollute a
    cluster. ``active`` [K] float32 masks pruned columns.
    """
    k = g.shape[1]
    onehot = jax.nn.one_hot(labels, k, dtype=g.dtype)          # [K, K]
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)              # [K]
    sums = g @ onehot                                          # [H, K] per-cluster
    means = sums / counts
    return jnp.take(means, labels, axis=1) * active[None, :]


def mlp_train_step(w1, b1, w2, b2, m1, mb1, m2, mb2,
                   x, labels, lr, lam, colmask, cluster_labels, share_flag):
    """One SGD-momentum step with the proximal group-lasso update fused in.

    * ``lam``: group-lasso weight lambda_{1,1} for layer 1; the proximal
      threshold is ``lr * lam`` (paper eq. 7-8). lam == 0 disables pruning.
    * ``colmask`` [784]: 1 for active input columns, 0 for pruned ones —
      fixed-shape stand-in for physically removing columns at train time.
    * ``cluster_labels`` [784] + ``share_flag``: when share_flag > 0 the
      layer-1 gradient columns are averaged within clusters (eq. 9), which
      is the weight-sharing retraining procedure. With identity labels and
      share_flag == 0 this is a plain regularized step, so one artifact
      serves stages 1 (regularized training) and 3 (sharing retraining).

    Returns (w1', b1', w2', b2', m1', mb1', m2', mb2', loss).
    """
    loss, grads = jax.value_and_grad(mlp_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, labels)
    g1, gb1, g2, gb2 = grads

    g1 = g1 * colmask[None, :]
    g1_shared = _cluster_mean_grads(g1, cluster_labels, colmask)
    g1 = jnp.where(share_flag > 0.0, g1_shared, g1)

    m1n = MOMENTUM * m1 + g1
    mb1n = MOMENTUM * mb1 + gb1
    m2n = MOMENTUM * m2 + g2
    mb2n = MOMENTUM * mb2 + gb2

    w1n = w1 - lr * m1n
    b1n = b1 - lr * mb1n
    w2n = w2 - lr * m2n
    b2n = b2 - lr * mb2n

    # Proximal step on layer 1. The paper prunes *input neurons*, i.e.
    # columns of W1, so groups are the rows of W1^T (Sec. III-B).
    w1n = prox.prox_group_lasso_rows(w1n.T, lr * lam).T
    w1n = w1n * colmask[None, :]

    return w1n, b1n, w2n, b2n, m1n, mb1n, m2n, mb2n, loss


def mlp_eval_step(w1, b1, w2, b2, x, labels):
    """Returns (summed loss, correct count) over one eval batch."""
    logits = mlp_forward(w1, b1, w2, b2, x)
    logp = jax.nn.log_softmax(logits)
    loss_sum = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=1))
    correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))
    return loss_sum, correct


def prox_step(w, thresh):
    """Standalone prox artifact (Pallas kernel on the hot path)."""
    return prox.prox_group_lasso_rows(w, thresh)


def shared_matvec_graph(x, onehot, centroids):
    """Standalone eq. (10) artifact used by the rust serving layer tests."""
    from .kernels import shared_matvec as sm
    return sm.shared_matvec(x, onehot, centroids)
