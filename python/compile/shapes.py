"""Single source of truth for every AOT-lowered shape.

The rust runtime parses ``artifacts/manifest.tsv`` (written by aot.py) and
never hard-codes shapes, but keeping the constants here in one place makes
the python side consistent across model.py / resnet.py / aot.py / tests.
"""

# ---- MLP on (synthetic) MNIST ------------------------------------------------
MLP_IN = 784          # 28*28 input features
MLP_HIDDEN = 300      # hidden width (paper Sec. IV-A)
MLP_OUT = 10          # 10 digit classes
MLP_TRAIN_BATCH = 128
MLP_EVAL_BATCH = 256
MLP_SERVE_BATCH = 32
MOMENTUM = 0.9        # SGD momentum (paper Sec. IV-A)

# ---- tiny ResNet on synthetic tiny-images ------------------------------------
# Depth-reduced stand-in for ResNet-34 (see DESIGN.md Substitutions): the
# full ResNet-34 graph lives in rust/src/nn/resnet.rs for exact adder
# accounting; this trainable variant exercises identical conv code paths.
RESNET_IMG = 32            # 32x32 inputs
RESNET_CHANNELS = 3
RESNET_CLASSES = 40
RESNET_STAGES = (16, 32, 64)   # channels per stage, 2 basic blocks each
RESNET_TRAIN_BATCH = 32
RESNET_EVAL_BATCH = 64
