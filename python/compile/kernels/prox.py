"""Pallas kernel: block soft-thresholding over matrix rows (paper eq. 8).

This is the proximal operator of the group-lasso penalty
``r(A) = lambda * sum_i ||[A]_i||_2`` with threshold ``t = eta * lambda``;
each row is scaled by ``max(1 - t/||row||, 0)``. Groups are rows here —
the caller arranges its weight matrix so that groups land on rows (for a
dense layer the paper prunes *input neurons*, i.e. columns of W, so the
caller passes W^T).

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step owns a
``(ROW_BLOCK, M)`` VMEM tile — the full row must be resident to form its
l2 norm, so tiling is over rows only. The reduction and the scale are
VPU element-wise work; there is no MXU use. VMEM footprint per step is
``ROW_BLOCK * M * 4`` bytes (~100 KiB at ROW_BLOCK=32, M=784), far under
the ~16 MiB/core budget, and rows are independent so the grid pipelines
HBM loads against compute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 32


def _prox_kernel(a_ref, t_ref, o_ref):
    a = a_ref[...]
    t = t_ref[0, 0]
    norm = jnp.sqrt(jnp.sum(a * a, axis=1, keepdims=True))
    scale = jnp.where(norm > 0.0, jnp.maximum(1.0 - t / norm, 0.0), 0.0)
    o_ref[...] = a * scale


@functools.partial(jax.jit, static_argnames=())
def prox_group_lasso_rows(a, thresh):
    """Pallas block soft-thresholding on rows of ``a`` ([I, M] float32).

    ``thresh`` is a scalar (python float or 0-d array). Rows are padded to
    a multiple of ROW_BLOCK; padded rows have zero norm and stay zero.
    """
    i, m = a.shape
    pad = (-i) % ROW_BLOCK
    a_pad = jnp.pad(a, ((0, pad), (0, 0)))
    t_arr = jnp.asarray(thresh, dtype=a.dtype).reshape(1, 1)
    out = pl.pallas_call(
        _prox_kernel,
        grid=((i + pad) // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, m), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, m), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((i + pad, m), a.dtype),
        interpret=True,
    )(a_pad, t_arr)
    return out[:i]
