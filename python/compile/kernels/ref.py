"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas (interpret=True) kernels match these
reference implementations to tight tolerances.
"""

import jax.numpy as jnp


def prox_group_lasso_rows(a, thresh):
    """Block soft-thresholding on the rows of ``a`` (paper eq. 8).

    prox_{t * sum_i ||row_i||_2}(A) scales each row by
    ``max(1 - t / ||row||_2, 0)`` (rows with zero norm map to zero).
    """
    norms = jnp.linalg.norm(a, axis=1, keepdims=True)
    scale = jnp.where(norms > 0.0, jnp.maximum(1.0 - thresh / norms, 0.0), 0.0)
    return a * scale


def lcc_factor_apply(signs, exps, x):
    """Apply one LCC matrix factor to ``x`` (paper eq. 4, one factor).

    The factor is F = signs * 2**exps with ``signs`` in {-1, 0, +1}: every
    nonzero entry is a signed power of two. Returns F @ x.
    """
    f = signs * jnp.exp2(exps)
    return f @ x


def shared_matvec(x, onehot, centroids):
    """Weight-shared matvec (paper eq. 10).

    x        [B, K]  batch of inputs
    onehot   [K, C]  column-cluster indicator (one 1 per row)
    centroids[N, C]  unique cluster centroid columns g_i

    y[b] = sum_i g_i * sum_{j in I_i} x[b, j]  ==  (x @ onehot) @ centroids.T
    """
    sums = x @ onehot
    return sums @ centroids.T
