"""Pallas kernel: apply one LCC matrix factor (paper eq. 4).

An LCC factor F has entries that are zeros or signed powers of two. The
build-time representation is the pair (signs in {-1,0,1}, integer
exponents), the hardware representation is the adder graph executed by
``rust/src/graph``. This kernel materializes ``F = signs * 2**exps`` tile
by tile in VMEM and feeds the MXU with a plain matmul — on TPU the
shift-add trick does not beat the systolic array, so the insight is kept
at the *representation* level (exact powers of two, bit-exact with the
rust VM) while the compute maps to what the hardware is good at
(bf16/f32 MXU matmul). See DESIGN.md §Hardware-Adaptation.

Grid: (N/BN, B/BB, M/BM) with an accumulator revisited across the M
(contraction) axis; each step holds three small tiles in VMEM
(BN*BM + BM*BB + BN*BB floats ≈ 192 KiB at 128³ tiles).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 64
BB = 64
BM = 128


def _lcc_kernel(s_ref, e_ref, x_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    f = s_ref[...] * jnp.exp2(e_ref[...])
    o_ref[...] += jnp.dot(f, x_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def lcc_factor_apply(signs, exps, x):
    """Compute ``(signs * 2**exps) @ x`` with a tiled Pallas kernel.

    signs [N, M] float32 in {-1, 0, 1}; exps [N, M] float32 (integer
    valued); x [M, B] float32. Shapes are padded to tile multiples; the
    zero padding contributes nothing to the contraction.
    """
    n, m = signs.shape
    m2, b = x.shape
    assert m == m2, f"factor/input mismatch: {m} vs {m2}"
    pn, pm, pb = (-n) % BN, (-m) % BM, (-b) % BB
    s_pad = jnp.pad(signs, ((0, pn), (0, pm)))
    e_pad = jnp.pad(exps, ((0, pn), (0, pm)))
    x_pad = jnp.pad(x, ((0, pm), (0, pb)))
    grid = ((n + pn) // BN, (b + pb) // BB, (m + pm) // BM)
    out = pl.pallas_call(
        _lcc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, BM), lambda i, j, k: (i, k)),
            pl.BlockSpec((BN, BM), lambda i, j, k: (i, k)),
            pl.BlockSpec((BM, BB), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BN, BB), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n + pn, b + pb), x.dtype),
        interpret=True,
    )(s_pad, e_pad, x_pad)
    return out[:n, :b]


def lcc_chain_apply(factors, x):
    """Apply a whole LCC decomposition ``F_P ... F_1 F_0 @ x``.

    ``factors`` is a list of (signs, exps) pairs ordered F_0 first.
    """
    y = x
    for signs, exps in factors:
        y = lcc_factor_apply(signs, exps, y)
    return y
