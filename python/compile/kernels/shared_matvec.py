"""Pallas kernel: weight-shared matvec (paper eq. 10).

After weight sharing, ``W x`` collapses to
``y = G (H^T x)`` where H [K, C] is the column-cluster indicator and
G [N, C] holds the unique centroid columns. The inner product with H is a
segment-sum — scalar additions only, which is where the sharing gain
comes from on the FPGA side (rust ``share`` module counts exactly K - C
additions for it).

Grid tiles over the batch; each step keeps the full (K, C) indicator and
(N, C) centroid tiles resident (C after clustering is small — tens of
columns — so both fit comfortably in VMEM) and runs two MXU matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BB = 32


def _shared_kernel(x_ref, h_ref, g_ref, o_ref):
    sums = jnp.dot(x_ref[...], h_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] = jnp.dot(sums, g_ref[...].T, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def shared_matvec(x, onehot, centroids):
    """Compute ``(x @ onehot) @ centroids.T`` ([B,K],[K,C],[N,C] -> [B,N])."""
    b, k = x.shape
    k2, c = onehot.shape
    n, c2 = centroids.shape
    assert k == k2 and c == c2
    pb = (-b) % BB
    x_pad = jnp.pad(x, ((0, pb), (0, 0)))
    out = pl.pallas_call(
        _shared_kernel,
        grid=((b + pb) // BB,),
        in_specs=[
            pl.BlockSpec((BB, k), lambda i: (i, 0)),
            pl.BlockSpec((k, c), lambda i: (0, 0)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BB, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pb, n), x.dtype),
        interpret=True,
    )(x_pad, onehot, centroids)
    return out[:b]
