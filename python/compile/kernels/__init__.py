"""Layer-1 Pallas kernels (interpret=True) + their pure-jnp oracles."""

from . import lcc_apply, prox, ref, shared_matvec  # noqa: F401
