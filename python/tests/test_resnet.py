"""L2 residual CNN: shapes, prox groupings (FK vs PK), training sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import resnet
from compile.shapes import (RESNET_CHANNELS, RESNET_CLASSES, RESNET_IMG)


def _init(seed=0):
    rng = np.random.default_rng(seed)
    params, momenta = [], []
    for name, shape in resnet.PARAM_SPECS:
        if name.endswith("_alpha"):
            arr = np.zeros(shape, dtype=np.float32)
        elif name.endswith(("w",)) and len(shape) >= 2:
            fan_in = int(np.prod(shape[:-1]))
            arr = rng.normal(size=shape).astype(np.float32) * np.sqrt(
                2.0 / fan_in)
        else:
            arr = np.zeros(shape, dtype=np.float32)
        params.append(jnp.asarray(arr))
        momenta.append(jnp.zeros(shape, dtype=jnp.float32))
    return params, momenta


def _batch(b=8, seed=1):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(
        size=(b, RESNET_IMG, RESNET_IMG, RESNET_CHANNELS)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, RESNET_CLASSES, size=b).astype(np.int32))
    return x, y


def test_param_specs_wellformed():
    names = [n for n, _ in resnet.PARAM_SPECS]
    assert len(names) == len(set(names))
    assert "fc_w" in names and "stem_w" in names
    assert len(resnet.CONV_KERNEL_NAMES) == 12  # 3 stages * 2 blocks * 2 convs


def test_forward_shape():
    params, _ = _init()
    x, _ = _batch(5)
    p = dict(zip(resnet.PARAM_NAMES, params))
    assert resnet.forward(p, x).shape == (5, RESNET_CLASSES)


@pytest.mark.parametrize("mode", ["fk", "pk"])
def test_train_step_runs_and_loss_decreases(mode):
    params, momenta = _init()
    x, y = _batch(16)
    losses = []
    for _ in range(8):
        out = resnet.train_step(mode, *params, *momenta, x, y, 0.05, 0.0)
        n = len(resnet.PARAM_SPECS)
        params, momenta = list(out[:n]), list(out[n:2 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_prox_fk_zeroes_whole_kernels():
    w = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 3, 4, 8)).astype(np.float32))
    out = np.asarray(resnet.prox_conv(w, 1e6, "fk"))
    assert np.all(out == 0.0)
    out2 = np.asarray(resnet.prox_conv(w, 0.0, "fk"))
    np.testing.assert_allclose(out2, np.asarray(w), rtol=1e-6)


def test_prox_fk_group_structure():
    """FK groups are whole (in,out) kernels: a kernel is zeroed atomically."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=(3, 3, 2, 3)).astype(np.float32)
    w[:, :, 0, 0] *= 0.01        # one tiny-norm kernel
    out = np.asarray(resnet.prox_conv(jnp.asarray(w), 0.5, "fk"))
    assert np.all(out[:, :, 0, 0] == 0.0)
    assert np.any(out[:, :, 1, 2] != 0.0)


def test_prox_pk_group_structure():
    """PK groups are kernel columns (norm over kh only)."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(3, 3, 2, 2)).astype(np.float32)
    w[:, 1, 0, 0] *= 1e-3        # one tiny column
    out = np.asarray(resnet.prox_conv(jnp.asarray(w), 0.1, "pk"))
    assert np.all(out[:, 1, 0, 0] == 0.0)
    assert np.any(out[:, 0, 0, 0] != 0.0)


def test_eval_step_counts():
    params, _ = _init()
    x, y = _batch(12, seed=5)
    loss_sum, correct = resnet.eval_step(*params, x, y)
    assert 0 <= int(correct) <= 12
    assert float(loss_sum) > 0.0
