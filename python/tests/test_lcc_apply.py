"""Pallas LCC factor-apply kernel vs pure-jnp oracle (paper eq. 4)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import lcc_apply, ref


def _factor(n, m, seed, density=0.3):
    """Random signed-power-of-two factor as (signs, exps)."""
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 0.0, 1.0], size=(n, m),
                       p=[density / 2, 1 - density, density / 2])
    exps = rng.integers(-6, 4, size=(n, m)).astype(np.float32)
    return jnp.asarray(signs.astype(np.float32)), jnp.asarray(exps)


def _x(m, b, seed):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray(rng.normal(size=(m, b)).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 150), m=st.integers(1, 150), b=st.integers(1, 70),
       seed=st.integers(0, 2**31 - 1))
def test_matches_reference(n, m, b, seed):
    signs, exps = _factor(n, m, seed)
    x = _x(m, b, seed)
    got = lcc_apply.lcc_factor_apply(signs, exps, x)
    want = ref.lcc_factor_apply(signs, exps, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_power_of_two_exactness():
    """Signed-po2 entries applied to po2 inputs are bit-exact."""
    signs = jnp.asarray([[1.0, -1.0], [0.0, 1.0]])
    exps = jnp.asarray([[1.0, -3.0], [0.0, -1.0]])
    x = jnp.asarray([[4.0], [8.0]])
    got = np.asarray(lcc_apply.lcc_factor_apply(signs, exps, x))
    assert got[0, 0] == 2.0 * 4.0 - 0.125 * 8.0
    assert got[1, 0] == 0.5 * 8.0


def test_chain_matches_matrix_product():
    f0 = _factor(32, 24, 3)
    f1 = _factor(40, 32, 4)
    x = _x(24, 8, 5)
    got = lcc_apply.lcc_chain_apply([f0, f1], x)
    d0 = np.asarray(f0[0]) * np.exp2(np.asarray(f0[1]))
    d1 = np.asarray(f1[0]) * np.exp2(np.asarray(f1[1]))
    want = d1 @ (d0 @ np.asarray(x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_tile_boundaries():
    """Shapes exactly at and just past the tile sizes."""
    for n, m, b in [(64, 128, 64), (65, 129, 65), (63, 127, 1)]:
        signs, exps = _factor(n, m, n * m)
        x = _x(m, b, b)
        got = lcc_apply.lcc_factor_apply(signs, exps, x)
        want = ref.lcc_factor_apply(signs, exps, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
