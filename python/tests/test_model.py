"""L2 MLP model: shapes, training dynamics, prox + sharing semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.shapes import MLP_HIDDEN, MLP_IN, MLP_OUT


def _init(seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    shapes = model.param_shapes()
    p = [jnp.asarray(rng.normal(size=shapes[n]).astype(np.float32) * scale)
         for n in model.PARAM_NAMES]
    m = [jnp.zeros(shapes[n], dtype=jnp.float32) for n in model.PARAM_NAMES]
    return p, m


def _batch(b=32, seed=1):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, MLP_IN)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, MLP_OUT, size=b).astype(np.int32))
    return x, y


def _ident_clusters():
    return jnp.arange(MLP_IN, dtype=jnp.int32)


def test_forward_shape():
    (w1, b1, w2, b2), _ = _init()
    x, _ = _batch(17)
    assert model.mlp_forward(w1, b1, w2, b2, x).shape == (17, MLP_OUT)


def test_train_step_reduces_loss_on_fixed_batch():
    p, m = _init()
    x, y = _batch(64)
    mask = jnp.ones(MLP_IN)
    losses = []
    for _ in range(30):
        out = model.mlp_train_step(*p, *m, x, y, 0.1, 0.0, mask,
                                   _ident_clusters(), 0.0)
        p, m = list(out[:4]), list(out[4:8])
        losses.append(float(out[8]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_prox_prunes_columns_with_large_lambda():
    p, m = _init()
    x, y = _batch(64)
    mask = jnp.ones(MLP_IN)
    for _ in range(10):
        out = model.mlp_train_step(*p, *m, x, y, 0.05, 50.0, mask,
                                   _ident_clusters(), 0.0)
        p, m = list(out[:4]), list(out[4:8])
    col_norms = np.linalg.norm(np.asarray(p[0]), axis=0)
    assert (col_norms == 0.0).mean() > 0.5  # most columns pruned


def test_colmask_keeps_columns_zero():
    p, m = _init()
    x, y = _batch(32)
    mask = np.ones(MLP_IN, dtype=np.float32)
    mask[:100] = 0.0
    p[0] = p[0] * jnp.asarray(mask)[None, :]
    out = model.mlp_train_step(*p, *m, x, y, 0.1, 0.0, jnp.asarray(mask),
                               _ident_clusters(), 0.0)
    w1 = np.asarray(out[0])
    assert np.all(w1[:, :100] == 0.0)


def test_shared_training_ties_cluster_columns():
    """With share_flag on, columns in one cluster get identical updates."""
    p, m = _init()
    x, y = _batch(32)
    labels = np.arange(MLP_IN, dtype=np.int32)
    labels[5] = labels[3]   # tie columns 3 and 5
    # start them equal so tied gradients keep them equal
    w1 = np.asarray(p[0]).copy()
    w1[:, 5] = w1[:, 3]
    p[0] = jnp.asarray(w1)
    out = model.mlp_train_step(*p, *m, x, y, 0.1, 0.0, jnp.ones(MLP_IN),
                               jnp.asarray(labels), 1.0)
    w1n = np.asarray(out[0])
    np.testing.assert_allclose(w1n[:, 3], w1n[:, 5], rtol=1e-5, atol=1e-6)


def test_eval_step_counts():
    (w1, b1, w2, b2), _ = _init()
    x, y = _batch(64, seed=3)
    loss_sum, correct = model.mlp_eval_step(w1, b1, w2, b2, x, y)
    logits = model.mlp_forward(w1, b1, w2, b2, x)
    acc = int(np.sum(np.argmax(np.asarray(logits), axis=1) == np.asarray(y)))
    assert int(correct) == acc
    assert float(loss_sum) > 0.0


def test_gradient_of_tied_columns_is_mean():
    """eq. (9): tied-column update equals the cluster-mean gradient."""
    g = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 6)).astype(np.float32))
    labels = jnp.asarray(np.array([0, 0, 2, 3, 0, 5], dtype=np.int32))
    active = jnp.ones(6)
    out = np.asarray(model._cluster_mean_grads(g, labels, active))
    gnp = np.asarray(g)
    mean0 = gnp[:, [0, 1, 4]].mean(axis=1)
    for j in (0, 1, 4):
        np.testing.assert_allclose(out[:, j], mean0, rtol=1e-5)
    np.testing.assert_allclose(out[:, 2], gnp[:, 2], rtol=1e-5)
