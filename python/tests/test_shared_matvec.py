"""Pallas weight-shared matvec kernel vs oracle (paper eq. 10)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, shared_matvec


def _setup(b, k, c, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, k)).astype(np.float32)
    labels = rng.integers(0, c, size=k)
    onehot = np.zeros((k, c), dtype=np.float32)
    onehot[np.arange(k), labels] = 1.0
    g = rng.normal(size=(n, c)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(onehot), jnp.asarray(g)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 80), k=st.integers(1, 96), c=st.integers(1, 32),
       n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_matches_reference(b, k, c, n, seed):
    x, h, g = _setup(b, k, c, n, seed)
    got = shared_matvec.shared_matvec(x, h, g)
    want = ref.shared_matvec(x, h, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_equals_expanded_dense_product():
    """Sharing then multiplying == multiplying the expanded matrix (eq. 10)."""
    x, h, g = _setup(16, 40, 8, 12, 0)
    w_expanded = np.asarray(g) @ np.asarray(h).T         # [N, K]
    want = np.asarray(x) @ w_expanded.T
    got = np.asarray(shared_matvec.shared_matvec(x, h, g))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_single_cluster_sums_all_columns():
    b, k, n = 4, 10, 3
    x = jnp.asarray(np.arange(b * k, dtype=np.float32).reshape(b, k))
    h = jnp.ones((k, 1), dtype=jnp.float32)
    g = jnp.asarray(np.ones((n, 1), dtype=np.float32) * 2.0)
    got = np.asarray(shared_matvec.shared_matvec(x, h, g))
    want = 2.0 * np.asarray(x).sum(axis=1, keepdims=True) * np.ones((1, n))
    np.testing.assert_allclose(got, want, rtol=1e-5)
