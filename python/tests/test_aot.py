"""AOT lowering: every registry entrypoint lowers to parseable HLO text."""

import jax
import jax.numpy as jnp

from compile import aot


def test_registry_complete():
    reg = aot.build_registry()
    expected = {"mlp_train_step", "mlp_eval", "mlp_fwd", "prox_step",
                "shared_matvec", "resnet_train_step_fk",
                "resnet_train_step_pk", "resnet_eval"}
    assert expected == set(reg)


def test_mlp_fwd_lowers_to_hlo_text():
    reg = aot.build_registry()
    fn, in_specs, out_names = reg["mlp_fwd"]
    lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[32,10]" in text


def test_prox_step_lowers_with_pallas_inlined():
    """interpret=True pallas must lower to plain HLO (no custom-call)."""
    reg = aot.build_registry()
    fn, in_specs, _ = reg["prox_step"]
    lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_manifest_dtype_mapping():
    assert aot._dt(jnp.float32) == "f32"
    assert aot._dt(jnp.int32) == "i32"


def test_eval_shape_matches_declared_outputs():
    reg = aot.build_registry()
    for name in ("mlp_eval", "mlp_train_step"):
        fn, in_specs, out_names = reg[name]
        outs = jax.eval_shape(fn, *[s for _, s in in_specs])
        assert len(outs) == len(out_names), name
