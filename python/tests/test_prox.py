"""Pallas prox kernel vs pure-jnp oracle (paper eq. 8)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import prox, ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 97),
    cols=st.integers(1, 64),
    thresh=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference(rows, cols, thresh, seed):
    a = _rand((rows, cols), seed)
    got = prox.prox_group_lasso_rows(a, thresh)
    want = ref.prox_group_lasso_rows(a, thresh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_zero_threshold_is_identity():
    a = _rand((33, 17), 0)
    got = prox.prox_group_lasso_rows(a, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a), rtol=1e-6)


def test_large_threshold_zeros_everything():
    a = _rand((8, 8), 1)
    got = prox.prox_group_lasso_rows(a, 1e6)
    assert np.all(np.asarray(got) == 0.0)


def test_zero_rows_stay_zero():
    a = np.zeros((5, 9), dtype=np.float32)
    a[2] = 3.0
    got = np.asarray(prox.prox_group_lasso_rows(jnp.asarray(a), 0.5))
    assert np.all(got[0] == 0) and np.all(got[4] == 0)
    assert np.all(got[2] > 0)  # norm 9, scale 1 - 0.5/9 > 0


@pytest.mark.parametrize("rows", [1, 31, 32, 33, 64, 300, 784])
def test_row_padding_boundary(rows):
    """Rows around the ROW_BLOCK boundary all round-trip correctly."""
    a = _rand((rows, 7), rows)
    got = prox.prox_group_lasso_rows(a, 0.3)
    want = ref.prox_group_lasso_rows(a, 0.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_shrinkage_monotone_in_threshold():
    a = _rand((16, 16), 7)
    n1 = np.linalg.norm(np.asarray(prox.prox_group_lasso_rows(a, 0.1)))
    n2 = np.linalg.norm(np.asarray(prox.prox_group_lasso_rows(a, 0.5)))
    n3 = np.linalg.norm(np.asarray(prox.prox_group_lasso_rows(a, 2.0)))
    assert n1 >= n2 >= n3
