//! Integration: training orchestration through the PJRT artifacts.

mod common;

use common::runtime_or_skip;
use lccnn::data::synth_mnist;
use lccnn::nn::mlp::MlpParams;
use lccnn::nn::resnet::init_params;
use lccnn::train::{ConvGrouping, LrSchedule, MlpTrainer, ResnetTrainer};

#[test]
fn mlp_loss_decreases() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = synth_mnist::generate(1024, 0);
    let mut tr = MlpTrainer::new(&rt, &MlpParams::init(0)).unwrap();
    let sched = LrSchedule { base: 0.05, every: 100, factor: 0.95 };
    let curve = tr.train(&data, 60, sched, 10, 1).unwrap();
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(last < first * 0.8, "loss did not decrease: {first} -> {last}");
}

#[test]
fn mlp_prox_prunes_columns() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = synth_mnist::generate(512, 1);
    let mut tr = MlpTrainer::new(&rt, &MlpParams::init(1)).unwrap();
    tr.lambda = 1.0; // aggressive pruning: per-step threshold lr*lambda
    let sched = LrSchedule { base: 0.05, every: 1000, factor: 1.0 };
    tr.train(&data, 60, sched, 10, 2).unwrap();
    let w1 = tr.params().w1;
    let zero_cols = w1
        .col_norms()
        .iter()
        .filter(|&&n| n == 0.0)
        .count();
    assert!(zero_cols > 100, "only {zero_cols} columns pruned");
}

#[test]
fn mlp_colmask_freezes_columns() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = synth_mnist::generate(512, 2);
    let mut tr = MlpTrainer::new(&rt, &MlpParams::init(2)).unwrap();
    let mut mask = vec![0.0; 784];
    for m in mask.iter_mut().skip(392) {
        *m = 1.0;
    }
    tr.set_colmask(mask);
    let sched = LrSchedule { base: 0.05, every: 1000, factor: 1.0 };
    tr.train(&data, 10, sched, 5, 3).unwrap();
    let w1 = tr.params().w1;
    let norms = w1.col_norms();
    // masked-out columns keep receiving no gradient, but they started
    // nonzero; the artifact multiplies W1 by the mask, so they are zero
    for j in 0..392 {
        assert_eq!(norms[j], 0.0, "col {j} not masked");
    }
    assert!(norms[500] > 0.0);
}

#[test]
fn mlp_evaluate_reports_accuracy_in_range() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = synth_mnist::generate(512, 3);
    let tr = MlpTrainer::new(&rt, &MlpParams::init(3)).unwrap();
    let (loss, acc) = tr.evaluate(&data).unwrap();
    assert!(loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn resnet_step_runs_and_loss_finite() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = lccnn::data::synth_tiny::generate(128, 4);
    let mut tr = ResnetTrainer::new(&rt, &init_params(4), ConvGrouping::Fk).unwrap();
    let sched = LrSchedule { base: 0.02, every: 1000, factor: 1.0 };
    let curve = tr.train(&data, 6, sched, 1, 5).unwrap();
    assert_eq!(tr.steps_taken, 6);
    for (_, loss) in &curve {
        assert!(loss.is_finite() && *loss > 0.0, "bad loss {loss}");
    }
}

#[test]
fn resnet_pk_grouping_also_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let data = lccnn::data::synth_tiny::generate(64, 5);
    let mut tr = ResnetTrainer::new(&rt, &init_params(5), ConvGrouping::Pk).unwrap();
    tr.lambda = 0.01;
    let sched = LrSchedule { base: 0.02, every: 1000, factor: 1.0 };
    let curve = tr.train(&data, 3, sched, 1, 6).unwrap();
    assert!(curve.last().unwrap().1.is_finite());
    let (_, acc) = tr.evaluate(&data).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}
