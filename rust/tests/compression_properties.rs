//! Property-based tests over the compression substrates: randomized
//! sweeps asserting the invariants the pipeline relies on. (First-party
//! property harness — proptest is not in the offline vendor tree — with
//! explicit seeds so failures are reproducible.)

mod common;

use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::config::ExecConfig;
use lccnn::convert::{conv_forward_fk, conv_forward_pk, fk_matrices, pk_matrices};
use lccnn::exec::{po2_shift_negate, Executor, FixedEngine};
use lccnn::graph::{schedule, verify_against};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::prune::{compact_columns, prox_group_lasso_rows};
use lccnn::quant::{csd_digits, csd_value, matrix_csd_adders, quantize_matrix, FixedPointFormat};
use lccnn::share::SharedLayer;
use lccnn::tensor::{conv2d, Conv2dParams, Matrix, Padding, Tensor4};
use lccnn::util::Rng;

/// Every decomposition must verify numerically at the quantization-
/// matched distortion level, for both algorithms, across random shapes.
#[test]
fn prop_decomposition_always_verifies() {
    let mut rng = Rng::new(100);
    let fmt = FixedPointFormat::default_weights();
    for trial in 0..12 {
        let n = 8 + rng.below(120);
        let k = 2 + rng.below(24);
        let scale = 0.1 + rng.f32() * 0.9;
        let w = Matrix::randn(n, k, scale, &mut rng);
        let (_, wq) = quantize_matrix(&w, fmt);
        let q_err = {
            let mut d = wq.clone();
            d.sub_assign(&w);
            d.frobenius()
        };
        for cfg in [LccConfig::fp(), LccConfig::fs()] {
            let dec = decompose(&w, &cfg);
            let approx = dec.to_dense();
            let mut diff = approx.clone();
            diff.sub_assign(&w);
            // LCC error is allowed to be at most ~the combination of the
            // relative target and the quantization floor
            let budget = (w.frobenius() * cfg.target_rel_err).max(q_err) * 3.0;
            assert!(
                diff.frobenius() <= budget + 1e-6,
                "trial {trial} {n}x{k} scale {scale}: err {} > budget {}",
                diff.frobenius(),
                budget
            );
            // and the lowered graph must agree with its own dense form
            let rep = verify_against(dec.graph(), &approx, 4, &mut rng);
            assert!(rep.passes(1e-3), "graph != dense reconstruction: {rep:?}");
        }
    }
}

/// Addition counts must be consistent: graph nodes == breakdown total,
/// and the schedule must cover every node exactly once.
#[test]
fn prop_addition_accounting_consistent() {
    let mut rng = Rng::new(200);
    for _ in 0..8 {
        let n = 16 + rng.below(64);
        let k = 4 + rng.below(16);
        let w = Matrix::randn(n, k, 0.5, &mut rng);
        let d = decompose(&w, &LccConfig::fs());
        assert_eq!(d.breakdown().total(), d.additions());
        let s = schedule(d.graph());
        assert_eq!(s.levels.len(), d.additions());
        assert_eq!(s.width_histogram.iter().sum::<usize>(), d.additions());
    }
}

/// Compaction + gather must be exactly equivalent to the masked product.
#[test]
fn prop_compaction_exact() {
    let mut rng = Rng::new(300);
    for _ in 0..10 {
        let n = 4 + rng.below(24);
        let k = 6 + rng.below(40);
        let mut w = Matrix::randn(n, k, 1.0, &mut rng);
        // zero a random subset of columns
        for c in 0..k {
            if rng.f32() < 0.4 {
                for r in 0..n {
                    *w.at_mut(r, c) = 0.0;
                }
            }
        }
        let compact = compact_columns(&w, 1e-9);
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let x_kept: Vec<f32> = compact.kept.iter().map(|&i| x[i]).collect();
        let y_full = w.matvec(&x);
        let y_comp = compact.weights.matvec(&x_kept);
        for (a, b) in y_full.iter().zip(&y_comp) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}

/// Sharing with exactly duplicated columns is lossless and saves exactly
/// (K - C) segment additions.
#[test]
fn prop_sharing_lossless_on_duplicates() {
    let mut rng = Rng::new(400);
    for _ in 0..6 {
        let n = 8 + rng.below(24);
        let c = 2 + rng.below(6);
        let dup = 2 + rng.below(4);
        let k = c * dup;
        let mut w = Matrix::zeros(n, k);
        for ci in 0..c {
            let col = rng.normal_vec(n, 1.0);
            for d in 0..dup {
                for r in 0..n {
                    *w.at_mut(r, ci * dup + d) = col[r];
                }
            }
        }
        let clustering = cluster_columns(&w, &AffinityParams::default());
        assert_eq!(clustering.num_clusters(), c, "expected {c} clusters");
        let layer = SharedLayer::from_clustering(&w, &clustering);
        assert_eq!(layer.segment_additions(), k - c);
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let y_shared = layer.apply(&x);
        let y_dense = w.matvec(&x);
        for (a, b) in y_shared.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}

/// The prox operator is non-expansive and monotone in the threshold.
#[test]
fn prop_prox_nonexpansive_monotone() {
    let mut rng = Rng::new(500);
    for _ in 0..10 {
        let a = Matrix::randn(6 + rng.below(20), 3 + rng.below(20), 1.0, &mut rng);
        let t1 = rng.f32() * 0.5;
        let t2 = t1 + rng.f32() * 0.5;
        let p1 = prox_group_lasso_rows(&a, t1);
        let p2 = prox_group_lasso_rows(&a, t2);
        assert!(p1.frobenius() <= a.frobenius() + 1e-6);
        assert!(p2.frobenius() <= p1.frobenius() + 1e-6);
        // row-wise: prox never flips signs
        for r in 0..a.rows() {
            for c in 0..a.cols() {
                assert!(p1.at(r, c) * a.at(r, c) >= 0.0);
            }
        }
    }
}

/// CSD recoding round-trips and never uses more digits than binary, for
/// random mantissas.
#[test]
fn prop_csd_roundtrip_random() {
    let mut rng = Rng::new(600);
    for _ in 0..2000 {
        let m = (rng.next_u64() % (1 << 20)) as i64 - (1 << 19);
        let digits = csd_digits(m);
        assert_eq!(csd_value(&digits), m);
        assert!(digits.len() <= (m.unsigned_abs().count_ones() as usize).max(1));
    }
}

/// FK and PK forwards equal direct convolution on random geometries.
#[test]
fn prop_conv_reformulations_equal_direct() {
    let mut rng = Rng::new(700);
    for trial in 0..6 {
        let h = 4 + rng.below(6);
        let w_sp = 4 + rng.below(6);
        let ci = 1 + rng.below(3);
        let co = 1 + rng.below(4);
        let kh = [1, 3][rng.below(2)];
        let stride = 1 + rng.below(2);
        let input = Tensor4::from_vec(1, h, w_sp, ci, rng.normal_vec(h * w_sp * ci, 1.0));
        let kernel = Tensor4::from_vec(kh, kh, ci, co, rng.normal_vec(kh * kh * ci * co, 1.0));
        let params = Conv2dParams { stride, padding: Padding::Same };
        let want = conv2d(&input, &kernel, params);
        let fkm = fk_matrices(&kernel);
        let got_fk = conv_forward_fk(&input, kernel.shape(), params, |k, x| fkm[k].matvec(x));
        let pkm = pk_matrices(&kernel);
        let got_pk = conv_forward_pk(&input, kernel.shape(), params, |k, x| pkm[k].matvec(x));
        for (a, (b, c)) in want.data().iter().zip(got_fk.data().iter().zip(got_pk.data())) {
            assert!((a - b).abs() < 1e-3, "trial {trial} FK: {a} vs {b}");
            assert!((a - c).abs() < 1e-3, "trial {trial} PK: {a} vs {c}");
        }
    }
}

/// More compressible structure must never cost more: duplicating the
/// rows of a matrix must not increase the FS per-row cost.
#[test]
fn prop_fs_exploits_row_duplication() {
    let mut rng = Rng::new(800);
    for _ in 0..5 {
        let n = 8 + rng.below(16);
        let k = 4 + rng.below(8);
        let base = Matrix::randn(n, k, 0.5, &mut rng);
        // stack the same rows twice
        let mut doubled = Matrix::zeros(2 * n, k);
        for r in 0..n {
            doubled.row_mut(r).copy_from_slice(base.row(r));
            doubled.row_mut(n + r).copy_from_slice(base.row(r));
        }
        // pin a single slice: auto slicing differs between n and 2n rows
        // (width = log2 rows), which would change cross-slice adds and
        // mask the property under test
        let mut cfg = LccConfig::fs();
        cfg.slice_width = Some(k);
        let cost_base = decompose(&base, &cfg).additions();
        let cost_doubled = decompose(&doubled, &cfg).additions();
        assert!(
            cost_doubled <= cost_base + n, // at most one extra ref per dup row
            "duplication raised cost: {cost_base} -> {cost_doubled}"
        );
    }
}

/// Random matrices through decompose/reconstruct stay within the
/// configured error budget across the whole slicing-config space (every
/// explicit width plus auto), for both algorithms. Slicing is the eq. 3
/// lever; no width choice may break the fidelity contract.
#[test]
fn prop_lcc_error_bounded_across_slicing_configs() {
    let mut rng = Rng::new(1000);
    let fmt = FixedPointFormat::default_weights();
    for (n, k, seed) in [(64usize, 16usize, 0u64), (96, 24, 1), (40, 12, 2)] {
        let mut mrng = Rng::new(3000 + seed);
        let w = Matrix::randn(n, k, 0.1 + 0.8 * mrng.f32(), &mut mrng);
        let (_, wq) = quantize_matrix(&w, fmt);
        let q_err = {
            let mut d = wq.clone();
            d.sub_assign(&w);
            d.frobenius()
        };
        for width in [Some(1usize), Some(2), Some(4), Some(8), None] {
            for base in [LccConfig::fp(), LccConfig::fs()] {
                let mut cfg = base;
                cfg.slice_width = width;
                let dec = decompose(&w, &cfg);
                let approx = dec.to_dense();
                let mut diff = approx.clone();
                diff.sub_assign(&w);
                // the same budget form the fidelity property uses: the
                // relative target or the quantization floor, with slack
                let budget = (w.frobenius() * cfg.target_rel_err).max(q_err) * 3.0;
                assert!(
                    diff.frobenius() <= budget + 1e-6,
                    "{n}x{k} width {width:?} {:?}: err {} > budget {}",
                    cfg.algo,
                    diff.frobenius(),
                    budget
                );
                // the slicing cover must be exact and in column order
                let mut covered = 0usize;
                for s in &dec.slices {
                    assert_eq!(s.col_start, covered, "slices must tile the columns");
                    covered += s.width;
                }
                assert_eq!(covered, k);
                if let Some(wd) = width {
                    assert!(dec.slices.iter().all(|s| s.width <= wd));
                }
                // and the lowered graph must agree with its own dense form
                let x: Vec<f32> = rng.normal_vec(k, 1.0);
                let ya = dec.apply(&x);
                let yd = approx.matvec(&x);
                for (a, b) in ya.iter().zip(&yd) {
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
                }
            }
        }
    }
}

/// Golden CSD vectors (checked in under `rust/tests/common/`): digit
/// strings match the recorded non-adjacent form exactly, round-trip to
/// the mantissa, and never have adjacent nonzeros.
#[test]
fn prop_csd_golden_vectors() {
    let path = common::test_data_path("csd_golden.tsv");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut checked = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        // strip only \r: the zero-mantissa row is "0<TAB>" and a full
        // trim would eat the tab separator
        let line = line.trim_end_matches('\r');
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (n_str, digits_str) = line
            .split_once('\t')
            .unwrap_or_else(|| panic!("line {}: expected mantissa<TAB>digits", lineno + 1));
        let n: i64 = n_str.parse().unwrap();
        let want: Vec<(i32, bool)> = digits_str
            .split_whitespace()
            .map(|t| {
                let negative = match t.as_bytes()[0] {
                    b'+' => false,
                    b'-' => true,
                    _ => panic!("line {}: bad digit {t:?}", lineno + 1),
                };
                (t[1..].parse::<i32>().unwrap(), negative)
            })
            .collect();
        let got = csd_digits(n);
        let got_pairs: Vec<(i32, bool)> = got.iter().map(|d| (d.shift, d.negative)).collect();
        assert_eq!(got_pairs, want, "mantissa {n}: digits diverge from golden");
        assert_eq!(csd_value(&got), n, "mantissa {n}: round-trip");
        for w in got.windows(2) {
            assert!(w[1].shift - w[0].shift >= 2, "mantissa {n}: adjacent nonzeros");
        }
        checked += 1;
    }
    assert!(checked >= 55, "golden file truncated? only {checked} vectors");
}

/// The fixed datapath's coefficient lowering agrees with the golden CSD
/// vectors: every f32-exact mantissa whose non-adjacent form is a single
/// digit lowers to exactly that `(shift, negate)` pair, and every exact
/// multi-digit (or zero) mantissa is rejected. Negative shifts are
/// covered by the reciprocal powers of two down to the 2^-31 floor.
#[test]
fn prop_po2_lowering_matches_csd_golden() {
    let path = common::test_data_path("csd_golden.tsv");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut singles = 0usize;
    let mut rejected = 0usize;
    for line in text.lines() {
        let line = line.trim_end_matches('\r');
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let n: i64 = line.split_once('\t').expect("mantissa<TAB>digits").0.parse().unwrap();
        let c = n as f32;
        if c as i64 != n {
            continue; // not f32-exact: the cast may round onto a different mantissa
        }
        let digits = csd_digits(n);
        match digits.as_slice() {
            [d] if d.shift <= 31 => {
                assert_eq!(
                    po2_shift_negate(c),
                    Some((d.shift, d.negative)),
                    "mantissa {n}: lowering diverges from golden digit"
                );
                singles += 1;
            }
            [_] => {}
            _ => {
                assert_eq!(po2_shift_negate(c), None, "mantissa {n}: must not lower to one shift");
                rejected += 1;
            }
        }
    }
    assert!(singles >= 12, "golden file lost its power-of-two rows? only {singles}");
    assert!(rejected >= 10, "golden file lost its multi-digit rows? only {rejected}");
    for k in 1..=31i32 {
        let c = (-k as f32).exp2();
        assert_eq!(po2_shift_negate(c), Some((-k, false)), "2^-{k}");
        assert_eq!(po2_shift_negate(-c), Some((-k, true)), "-2^-{k}");
    }
}

/// The fixed engine's analytic error bound holds on real decomposed
/// graphs across the whole slicing-config space (widths 1/2/4/8 and
/// auto, both algorithms): integer shift-add execution of every lowered
/// program stays within `FixedPlan::error_bounds` of the float oracle,
/// modulo the float oracle's own rounding slack.
#[test]
fn prop_fixed_engine_error_bound_across_slicing_configs() {
    let mut rng = Rng::new(1100);
    for (n, k, seed) in [(48usize, 12usize, 0u64), (64, 16, 1)] {
        let mut mrng = Rng::new(4200 + seed);
        let w = Matrix::randn(n, k, 0.1 + 0.8 * mrng.f32(), &mut mrng);
        let mut checked = 0usize;
        for width in [Some(1usize), Some(2), Some(4), Some(8), None] {
            for base in [LccConfig::fp(), LccConfig::fs()] {
                let mut cfg = base;
                cfg.slice_width = width;
                let dec = decompose(&w, &cfg);
                let engine = FixedEngine::with_config(dec.graph(), ExecConfig::serial())
                    .unwrap_or_else(|e| {
                        panic!("{n}x{k} width {width:?} {:?}: lowering failed: {e}", cfg.algo)
                    });
                // the analytic bound presumes the accumulator never
                // saturates; decomposed graphs stay far from that edge
                let headroom = engine.fixed_plan().max_mantissa_bound(8.0);
                assert!(
                    headroom < 0.25 * i64::MAX as f64,
                    "{n}x{k} width {width:?}: unexpectedly near saturation ({headroom:e})"
                );
                let bounds = engine.error_bounds();
                for _ in 0..3 {
                    let x: Vec<f32> = rng.normal_vec(k, 1.0);
                    let yf = dec.apply(&x);
                    let yx = engine.execute_one(&x);
                    assert_eq!(yx.len(), yf.len());
                    for (o, (a, b)) in yx.iter().zip(&yf).enumerate() {
                        let tol = bounds[o] + 1e-3 * (1.0 + b.abs() as f64);
                        assert!(
                            ((a - b).abs() as f64) <= tol,
                            "{n}x{k} width {width:?} {:?} out {o}: |{a} - {b}| > {tol:e}",
                            cfg.algo
                        );
                        checked += 1;
                    }
                }
            }
        }
        assert!(checked > 100, "sweep too thin: {checked}");
    }
}

/// The CSD baseline grows with precision (more fractional bits -> more
/// digits), so compression ratios are measured against the right floor.
#[test]
fn prop_csd_monotone_in_precision() {
    let mut rng = Rng::new(900);
    let w = Matrix::randn(32, 16, 0.5, &mut rng);
    let mut prev = 0usize;
    for frac in [2u32, 4, 6, 8, 10] {
        let adds = matrix_csd_adders(&w, FixedPointFormat::new(2, frac));
        assert!(adds >= prev, "CSD not monotone: {prev} -> {adds} at {frac} bits");
        prev = adds;
    }
}
