//! Integration: the full Fig. 2 pipeline end to end at a small budget.
//! This is the system-level correctness test — training through PJRT,
//! pruning, affinity propagation, sharing retrain, LCC, VM-backed
//! accuracy — all composing.

mod common;

use common::runtime_or_skip;
use lccnn::config::MlpPipelineConfig;
use lccnn::pipeline::run_mlp_pipeline;

#[test]
fn fig2_pipeline_small_budget() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = MlpPipelineConfig {
        train_examples: 1536,
        test_examples: 512,
        train_steps: 150,
        share_retrain_steps: 40,
        lambda: 0.25,
        ..Default::default()
    };
    let out = run_mlp_pipeline(&rt, &cfg).expect("pipeline");

    // baseline learned something
    assert!(out.baseline_accuracy > 0.5, "baseline acc {}", out.baseline_accuracy);
    assert!(out.baseline_additions > 100_000);

    // three stages, ratios strictly improving along the pipeline
    assert_eq!(out.stages.len(), 3);
    let r: Vec<f64> = out.stages.iter().map(|s| s.ratio).collect();
    assert!(r[0] > 1.0, "pruning ratio {}", r[0]);
    assert!(r[1] > r[0], "sharing did not improve: {r:?}");
    assert!(r[2] > r[1], "LCC did not improve: {r:?}");

    // pruning actually removed columns; clustering actually merged some
    assert!(out.stages[0].active_columns < 784);
    assert!(out.stages[1].clusters > 0);
    assert!(out.stages[1].clusters <= out.stages[1].active_columns);

    // compressed accuracy stays in the baseline's neighbourhood
    for s in &out.stages {
        assert!(
            s.accuracy > out.baseline_accuracy - 0.25,
            "stage {} collapsed: {} vs baseline {}",
            s.stage,
            s.accuracy,
            out.baseline_accuracy
        );
    }

    // the LCC graph is as faithful as the CSD baseline's quantization
    // (joint quantization+computing: LCC replaces quantization, so its
    // distortion is matched to — not better than — the 8-bit grid)
    assert!(
        out.lcc_sqnr_db > out.quant_sqnr_db - 3.0,
        "LCC SQNR {} vs quantization SQNR {}",
        out.lcc_sqnr_db,
        out.quant_sqnr_db
    );

    // loss curves recorded
    assert!(out.baseline_curve.len() > 3);
    assert!(out.reg_curve.last().unwrap().1 < out.reg_curve.first().unwrap().1);
}
