//! Integration: the compression pipeline end to end.
//!
//! Two layers of coverage:
//! * `compression_stack_e2e_*` — a shape matrix over the full
//!   prune -> share -> LCC -> exec -> serve stack on synthetic weights
//!   (no artifacts needed), asserting at every graph-executing stage
//!   that results are **bit-identical** to the `NaiveExecutor` oracle —
//!   through the engine, the single-model server shim, and the
//!   multi-model registry server — and that the recipe-driven
//!   `compress::Pipeline` reproduces the hand-wired stack bit-exactly.
//! * `fig2_pipeline_small_budget` — the trained Fig. 2 pipeline through
//!   PJRT at a small budget (skips when the AOT artifacts are absent).

mod common;

use common::runtime_or_skip;
use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::compress::{Pipeline, Recipe};
use lccnn::config::{ExecConfig, MlpPipelineConfig, ServeConfig};
use lccnn::exec::{Executor, NaiveExecutor};
use lccnn::lcc::LccConfig;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::pipeline::run_mlp_pipeline;
use lccnn::prune::compact_columns;
use lccnn::serve::{CompressedMlpBackend, ModelRegistry, Server};
use lccnn::share::SharedLayer;
use lccnn::tensor::Matrix;
use lccnn::util::Rng;
use std::sync::Arc;

/// Synthetic "post-regularization" weights: `groups` clusters of `per`
/// near-identical columns plus one exactly-zero (pruned) column per
/// group — so pruning, sharing and LCC all genuinely engage.
fn grouped_pruned_weights(rows: usize, groups: usize, per: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let stride = per + 1;
    let mut w = Matrix::zeros(rows, groups * stride);
    for g in 0..groups {
        let base = rng.normal_vec(rows, 0.8);
        for j in 0..per {
            for r in 0..rows {
                *w.at_mut(r, g * stride + j) = base[r] + 0.005 * rng.normal_f32();
            }
        }
        // column g*stride + per stays zero: pruned
    }
    w
}

/// One full pass of the stack for one shape; every graph execution is
/// checked bit-exact against the oracle.
fn run_stack_for_shape(rows: usize, groups: usize, per: usize, exec_cfg: ExecConfig, seed: u64) {
    let w = grouped_pruned_weights(rows, groups, per, seed);
    let cols = w.cols();
    let mut rng = Rng::new(seed + 1000);

    // --- stage 1: prune ---------------------------------------------------
    let compact = compact_columns(&w, 1e-6);
    assert_eq!(compact.kept.len(), groups * per, "pruned columns must compact away");
    let x: Vec<f32> = rng.normal_vec(cols, 1.0);
    let x_kept: Vec<f32> = compact.kept.iter().map(|&i| x[i]).collect();
    let y_full = w.matvec(&x);
    let y_pruned = compact.weights.matvec(&x_kept);
    for (a, b) in y_full.iter().zip(&y_pruned) {
        assert!((a - b).abs() < 1e-5, "pruning changed the product: {a} vs {b}");
    }

    // --- stage 2: share ---------------------------------------------------
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    assert!(
        clustering.num_clusters() < groups * per,
        "near-duplicate columns must share: {} clusters from {} columns",
        clustering.num_clusters(),
        groups * per
    );
    assert!(clustering.num_clusters() > 0);
    let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
    let y_shared = shared.apply(&x_kept);
    for (a, b) in y_shared.iter().zip(&y_pruned) {
        assert!((a - b).abs() < 0.1 + 0.05 * b.abs(), "sharing strayed: {a} vs {b}");
    }

    // --- stage 3: LCC -----------------------------------------------------
    let slcc = shared.with_lcc_exec(&LccConfig::fs(), exec_cfg);
    let oracle = NaiveExecutor::new(slcc.graph().clone());
    assert_eq!(oracle.num_inputs(), shared.num_clusters());

    // --- stage 4: exec, bit-identical to the oracle ------------------------
    let xs: Vec<Vec<f32>> = (0..17).map(|_| rng.normal_vec(cols, 1.0)).collect();
    let xs_kept: Vec<Vec<f32>> = xs
        .iter()
        .map(|x| compact.kept.iter().map(|&i| x[i]).collect())
        .collect();
    let batch = slcc.apply_batch(&xs_kept);
    for (xk, y) in xs_kept.iter().zip(&batch) {
        let sums = shared.segment_sums(xk);
        assert_eq!(*y, oracle.execute_one(&sums), "engine != oracle ({rows}x{cols})");
        assert_eq!(*y, slcc.apply(xk), "batch path != scalar path");
    }

    // --- stage 4b: the recipe-driven pipeline reproduces this exact stack --
    let recipe = Recipe { exec: exec_cfg, ..Recipe::default() };
    let artifact = Pipeline::from_recipe(&recipe)
        .expect("default recipe is valid")
        .run(&w)
        .expect("pipeline runs");
    assert_eq!(artifact.kept(), &compact.kept[..], "recipe pruning agrees");
    assert_eq!(
        artifact.lcc().expect("lcc stage ran").additions(),
        slcc.additions(),
        "recipe addition accounting agrees ({rows}x{cols})"
    );
    let pipe_exec = artifact.executor();
    for (x, y) in xs.iter().zip(&batch) {
        assert_eq!(
            pipe_exec.execute_one(x),
            *y,
            "recipe-driven executor != legacy stack ({rows}x{cols})"
        );
    }

    // --- stage 5a: serve through the single-model shim ---------------------
    let b1: Vec<f32> = rng.normal_vec(rows, 0.1);
    let w2 = Matrix::randn(4, rows, 0.3, &mut rng);
    let b2: Vec<f32> = rng.normal_vec(4, 0.1);
    let model = Arc::new(CompressedMlp {
        kept: compact.kept.clone(),
        layer1: Layer1::SharedLcc(slcc),
        b1: b1.clone(),
        w2: w2.clone(),
        b2: b2.clone(),
    });
    // the oracle-composed reference: identical head math over the
    // oracle-executed LCC program
    let expect = |x: &[f32]| -> Vec<f32> {
        let xk: Vec<f32> = compact.kept.iter().map(|&i| x[i]).collect();
        let mut h = oracle.execute_one(&shared.segment_sums(&xk));
        for (hv, &b) in h.iter_mut().zip(&b1) {
            *hv = (*hv + b).max(0.0);
        }
        let mut out = w2.matvec(&h);
        for (ov, &b) in out.iter_mut().zip(&b2) {
            *ov += b;
        }
        out
    };
    let server = Server::start(
        Arc::new(CompressedMlpBackend { model: Arc::clone(&model) }),
        ServeConfig { max_batch: 8, batch_timeout_us: 200, ..Default::default() },
    );
    let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
    for (x, rx) in xs.iter().zip(rxs) {
        let y = rx.recv().unwrap().unwrap();
        assert_eq!(y, expect(x), "served response != oracle-composed forward");
        assert_eq!(y, model.forward_one(x), "served response != direct forward");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, xs.len() as u64);
}

#[test]
fn compression_stack_e2e_matrix_bit_identical_to_oracle() {
    // three shapes x three engine tunings: serial, default pooled
    // parallel, and a small-chunk configuration
    run_stack_for_shape(16, 4, 4, ExecConfig::serial(), 1);
    run_stack_for_shape(32, 6, 3, ExecConfig::default(), 2);
    run_stack_for_shape(
        24,
        5,
        5,
        ExecConfig { chunk: 4, parallel_min_batch: 8, ..ExecConfig::default() },
        3,
    );
}

/// The same stack served through the multi-model registry: all three
/// shapes resident in one server, every routed response bit-identical
/// to that model's oracle.
#[test]
fn compression_stack_serves_through_registry_bit_identical() {
    let registry = Arc::new(ModelRegistry::new());
    let mut oracles = Vec::new();
    for (i, (rows, groups, per)) in [(16usize, 4usize, 4usize), (32, 6, 3), (24, 5, 5)]
        .into_iter()
        .enumerate()
    {
        let w = grouped_pruned_weights(rows, groups, per, 40 + i as u64);
        let compact = compact_columns(&w, 1e-6);
        let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
        let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
        let slcc = shared.with_lcc_exec(&LccConfig::fs(), ExecConfig::serial());
        let name = format!("shape-{i}");
        registry.register_graph(&name, slcc.graph(), ExecConfig::serial(), 8);
        oracles.push((name, NaiveExecutor::new(slcc.graph().clone())));
    }
    let server = Server::start_registry(Arc::clone(&registry), ServeConfig::default());
    let mut rng = Rng::new(77);
    for round in 0..5 {
        for (name, oracle) in &oracles {
            let x = rng.normal_vec(oracle.num_inputs(), 1.0);
            let want = oracle.execute_one(&x);
            let got = server.infer_model(name, x).expect("registry serves");
            assert_eq!(got, want, "round {round} model {name}");
        }
    }
    for (name, _) in &oracles {
        assert_eq!(server.model_stats(name).requests, 5, "model {name}");
    }
    let _ = server.shutdown();
}

#[test]
fn fig2_pipeline_small_budget() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = MlpPipelineConfig {
        train_examples: 1536,
        test_examples: 512,
        train_steps: 150,
        share_retrain_steps: 40,
        lambda: 0.25,
        ..Default::default()
    };
    let out = run_mlp_pipeline(&rt, &cfg).expect("pipeline");

    // baseline learned something
    assert!(out.baseline_accuracy > 0.5, "baseline acc {}", out.baseline_accuracy);
    assert!(out.baseline_additions > 100_000);

    // three stages, ratios strictly improving along the pipeline
    assert_eq!(out.stages.len(), 3);
    let r: Vec<f64> = out.stages.iter().map(|s| s.ratio).collect();
    assert!(r[0] > 1.0, "pruning ratio {}", r[0]);
    assert!(r[1] > r[0], "sharing did not improve: {r:?}");
    assert!(r[2] > r[1], "LCC did not improve: {r:?}");

    // pruning actually removed columns; clustering actually merged some
    assert!(out.stages[0].active_columns < 784);
    assert!(out.stages[1].clusters > 0);
    assert!(out.stages[1].clusters <= out.stages[1].active_columns);

    // compressed accuracy stays in the baseline's neighbourhood
    for s in &out.stages {
        assert!(
            s.accuracy > out.baseline_accuracy - 0.25,
            "stage {} collapsed: {} vs baseline {}",
            s.stage,
            s.accuracy,
            out.baseline_accuracy
        );
    }

    // the LCC graph is as faithful as the CSD baseline's quantization
    // (joint quantization+computing: LCC replaces quantization, so its
    // distortion is matched to — not better than — the 8-bit grid)
    assert!(
        out.lcc_sqnr_db > out.quant_sqnr_db - 3.0,
        "LCC SQNR {} vs quantization SQNR {}",
        out.lcc_sqnr_db,
        out.quant_sqnr_db
    );

    // loss curves recorded
    assert!(out.baseline_curve.len() > 3);
    assert!(out.reg_curve.last().unwrap().1 < out.reg_curve.first().unwrap().1);
}
