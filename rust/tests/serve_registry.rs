//! Multi-model registry serving under concurrency: N models x M client
//! threads hammering one router, asserting per-model outputs are
//! bit-identical to dedicated single-model servers (and to the
//! `NaiveExecutor` oracle), hot add/remove under load never dropping an
//! accepted request, and shutdown draining every model's queue.

use lccnn::config::{ExecConfig, ServeConfig};
use lccnn::exec::{BatchEngine, Executor, NaiveExecutor};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::serve::{
    BatchEvaluator, ExecutorBackend, ModelRegistry, MutexEvaluator, ServeError, Server,
};
use lccnn::util::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Random shift-add DAG, same construction as the exec engine tests.
fn ladder_graph(inputs: usize, nodes: usize, seed: u64) -> AdderGraph {
    let mut rng = Rng::new(seed);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..3)
        .map(|_| OutputSpec::Ref(refs[rng.below(refs.len())]))
        .collect();
    g.set_outputs(outs);
    g
}

/// The acceptance hammer: 4 models x 6 client threads. Every response
/// from the shared multi-model server must be bit-identical to a
/// dedicated single-model `Server` fed the same input, and to the
/// oracle. The registry's engines are sharded (model `mN` runs on N+1
/// output-range shards; the dedicated servers stay unsharded), so the
/// hammer also pins sharded == unsharded under concurrent load.
#[test]
fn hammer_bit_identical_to_dedicated_single_model_servers() {
    const N_MODELS: usize = 4;
    const N_CLIENTS: usize = 6;
    const PER_CLIENT: usize = 48;

    let graphs: Vec<AdderGraph> =
        (0..N_MODELS).map(|i| ladder_graph(4 + i, 40 + 10 * i, i as u64)).collect();
    let oracles: Vec<NaiveExecutor> =
        graphs.iter().map(|g| NaiveExecutor::new(g.clone())).collect();

    let serve_cfg = ServeConfig { max_batch: 8, batch_timeout_us: 500, ..Default::default() };
    let registry = Arc::new(ModelRegistry::new());
    for (i, g) in graphs.iter().enumerate() {
        let cfg = ExecConfig { shards: i + 1, ..ExecConfig::default() };
        registry.register_graph(&format!("m{i}"), g, cfg, 8);
    }
    let multi = Server::start_registry(Arc::clone(&registry), serve_cfg.clone());
    let dedicated: Vec<Server> = graphs
        .iter()
        .map(|g| {
            let engine: Arc<dyn Executor> =
                Arc::new(BatchEngine::with_config(g, ExecConfig::default()));
            Server::start(Arc::new(ExecutorBackend::new(engine, 8)), serve_cfg.clone())
        })
        .collect();

    std::thread::scope(|scope| {
        for t in 0..N_CLIENTS {
            let multi = &multi;
            let dedicated = &dedicated;
            let oracles = &oracles;
            scope.spawn(move || {
                let mut rng = Rng::new(1000 + t as u64);
                for k in 0..PER_CLIENT {
                    let m = (t + k) % N_MODELS;
                    let x = rng.normal_vec(oracles[m].num_inputs(), 1.0);
                    let want = oracles[m].execute_one(&x);
                    let got_multi =
                        multi.infer_model(&format!("m{m}"), x.clone()).expect("multi serves");
                    let got_single = dedicated[m].infer(x).expect("dedicated serves");
                    assert_eq!(got_multi, want, "client {t} req {k} model m{m} vs oracle");
                    assert_eq!(got_multi, got_single, "client {t} req {k} model m{m}");
                }
            });
        }
    });

    // every request accounted to its model, none lost or misrouted
    let total: u64 = (0..N_MODELS).map(|m| multi.model_stats(&format!("m{m}")).requests).sum();
    assert_eq!(total, (N_CLIENTS * PER_CLIENT) as u64);
    for m in 0..N_MODELS {
        let s = multi.model_stats(&format!("m{m}"));
        assert_eq!(s.requests, (N_CLIENTS * PER_CLIENT / N_MODELS) as u64, "model m{m}: {s:?}");
    }
    let stats = multi.shutdown();
    assert_eq!(stats.requests, (N_CLIENTS * PER_CLIENT) as u64);
}

/// Hot add and hot remove while clients are hammering. The invariant:
/// every submit gets exactly one response — an accepted request (entry
/// resolved before removal) is served bit-identically, and a rejection
/// can only ever happen after the removal actually started. The
/// surviving model must be completely unaffected.
#[test]
fn hot_add_remove_under_load_never_drops_accepted_requests() {
    let keep_g = ladder_graph(5, 50, 10);
    let victim_g = ladder_graph(6, 60, 11);
    let late_g = ladder_graph(4, 40, 12);
    let keep_oracle = NaiveExecutor::new(keep_g.clone());
    let victim_oracle = NaiveExecutor::new(victim_g.clone());
    let late_oracle = NaiveExecutor::new(late_g.clone());

    let registry = Arc::new(ModelRegistry::new());
    registry.register_graph("keep", &keep_g, ExecConfig::default(), 8);
    registry.register_graph("victim", &victim_g, ExecConfig::default(), 8);
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig { max_batch: 8, batch_timeout_us: 300, ..Default::default() },
    );

    let removed = AtomicBool::new(false);
    let victim_served = AtomicUsize::new(0);
    let victim_rejected = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..4 {
            let server = &server;
            let removed = &removed;
            let victim_served = &victim_served;
            let victim_rejected = &victim_rejected;
            let keep_oracle = &keep_oracle;
            let victim_oracle = &victim_oracle;
            scope.spawn(move || {
                let mut rng = Rng::new(2000 + t as u64);
                for k in 0..120 {
                    // the surviving model must always answer, bit-identically
                    let x = rng.normal_vec(keep_oracle.num_inputs(), 1.0);
                    let want = keep_oracle.execute_one(&x);
                    assert_eq!(server.infer_model("keep", x).expect("keep always serves"), want);

                    // the victim races removal: Ok must be bit-identical,
                    // Err implies the removal had already begun
                    let x = rng.normal_vec(victim_oracle.num_inputs(), 1.0);
                    let want = victim_oracle.execute_one(&x);
                    match server.infer_model("victim", x) {
                        Ok(y) => {
                            assert_eq!(y, want, "client {t} req {k}: accepted but wrong");
                            victim_served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            assert!(e.contains("unknown model"), "unexpected error: {e}");
                            assert!(
                                removed.load(Ordering::SeqCst),
                                "client {t} req {k}: rejected before removal started"
                            );
                            victim_rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }

        // mid-load: remove the victim, then hot add a brand-new model and
        // serve it immediately
        std::thread::sleep(Duration::from_millis(5));
        removed.store(true, Ordering::SeqCst);
        let entry = registry.remove("victim").expect("victim was registered");
        assert_eq!(entry.name(), "victim");
        registry.register_graph("late", &late_g, ExecConfig::default(), 8);
        let mut rng = Rng::new(3000);
        for _ in 0..30 {
            let x = rng.normal_vec(late_oracle.num_inputs(), 1.0);
            let want = late_oracle.execute_one(&x);
            assert_eq!(server.infer_model("late", x).expect("hot-added model serves"), want);
        }
    });

    // accounting: every victim submit is either served or rejected
    assert_eq!(
        victim_served.load(Ordering::Relaxed) + victim_rejected.load(Ordering::Relaxed),
        4 * 120
    );
    assert_eq!(server.model_stats("keep").requests, 4 * 120);
    assert_eq!(
        server.model_stats("victim").requests,
        victim_served.load(Ordering::Relaxed) as u64,
        "served == accepted: removal dropped a request"
    );
    assert_eq!(server.model_stats("late").requests, 30);
    assert_eq!(
        server.metrics().counter("rejected"),
        victim_rejected.load(Ordering::Relaxed) as u64
    );
    let _ = server.shutdown();
}

/// Shutdown must drain every model's queue: requests already submitted
/// to deliberately slow backends all complete across shutdown.
#[test]
fn shutdown_drains_all_models() {
    fn slow_echo(scale: f32) -> Arc<dyn BatchEvaluator> {
        Arc::new(MutexEvaluator::new(
            move |xs: &[Vec<f32>]| {
                std::thread::sleep(Duration::from_millis(1));
                Ok(xs.iter().map(|x| vec![scale * x.iter().sum::<f32>()]).collect())
            },
            4,
            "slow-echo",
        ))
    }
    let registry = Arc::new(ModelRegistry::new());
    registry.register_evaluator("a", slow_echo(1.0));
    registry.register_evaluator("b", slow_echo(2.0));
    registry.register_evaluator("c", slow_echo(3.0));
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig { max_batch: 4, batch_timeout_us: 100, ..Default::default() },
    );
    let names = ["a", "b", "c"];
    let scales = [1.0f32, 2.0, 3.0];
    let rxs: Vec<_> = (0..45)
        .map(|i| (i, server.submit_to(names[i % 3], vec![i as f32, 1.0])))
        .collect();
    let metrics = Arc::clone(server.metrics());
    let stats = server.shutdown(); // drains all three queues, then joins
    for (i, rx) in rxs {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(y)) => assert_eq!(y, vec![scales[i % 3] * (i as f32 + 1.0)], "request {i}"),
            Ok(Err(e)) => panic!("request {i}: drained shutdown must complete, got {e}"),
            Err(e) => panic!("request {i} hung or was dropped across shutdown: {e}"),
        }
    }
    assert_eq!(stats.requests, 45);
    for n in names {
        assert_eq!(metrics.counter(&format!("model.{n}.requests")), 15, "model {n}");
    }
}

/// Overload hammer: a slow model behind a small `queue_capacity` is
/// flooded from several threads. The invariants: every submit resolves
/// (served correctly or shed with the typed error — never dropped, never
/// hung), the shed counter matches the observed sheds exactly, only
/// accepted requests are counted as served, and the overload must
/// actually shed.
#[test]
fn overload_sheds_without_dropping_accepted_requests() {
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 40;
    const CAPACITY: usize = 8;

    let registry = Arc::new(ModelRegistry::new());
    registry.register_evaluator(
        "slow",
        Arc::new(MutexEvaluator::new(
            |xs: &[Vec<f32>]| {
                std::thread::sleep(Duration::from_millis(2));
                Ok(xs.iter().map(|x| vec![x.iter().sum::<f32>() + 1.0]).collect())
            },
            4,
            "slow-echo",
        )),
    );
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 4,
            batch_timeout_us: 100,
            queue_capacity: CAPACITY,
            ..Default::default()
        },
    );

    let served = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            let server = &server;
            let served = &served;
            let shed = &shed;
            scope.spawn(move || {
                // burst-submit the whole allotment first (outpacing the
                // 2ms-per-batch backend, so the cap must engage), then
                // collect every response
                let rxs: Vec<_> = (0..PER_CLIENT)
                    .map(|k| {
                        let v = (t * PER_CLIENT + k) as f32;
                        (v, server.submit_to("slow", vec![v, 1.0]))
                    })
                    .collect();
                for (v, rx) in rxs {
                    match rx.recv().expect("every submit resolves") {
                        Ok(y) => {
                            assert_eq!(y, vec![v + 2.0], "accepted request served wrong");
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::Shed { model }) => {
                            assert_eq!(model, "slow");
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
            });
        }
    });

    let served = served.load(Ordering::Relaxed) as u64;
    let shed = shed.load(Ordering::Relaxed) as u64;
    assert_eq!(served + shed, (CLIENTS * PER_CLIENT) as u64, "no request lost");
    assert!(shed > 0, "burst of {} against capacity {CAPACITY} must shed", CLIENTS * PER_CLIENT);
    assert!(served > 0, "admitted requests must be served");
    assert_eq!(server.metrics().counter("model.slow.shed"), shed);
    assert_eq!(server.metrics().counter("shed"), shed);
    assert_eq!(server.metrics().counter("model.slow.requests"), served, "only accepted count");
    let stats = server.shutdown(); // joins the router: every slot released
    assert_eq!(stats.requests, served);
    assert_eq!(registry.get("slow").unwrap().queued(), 0, "all slots released");
}

/// A failing model's errors stay confined to it.
#[test]
fn per_model_error_isolation() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register_evaluator(
        "good",
        Arc::new(MutexEvaluator::new(
            |xs: &[Vec<f32>]| Ok(xs.iter().map(|x| vec![x.iter().sum()]).collect()),
            8,
            "echo",
        )),
    );
    registry.register_evaluator(
        "bad",
        Arc::new(MutexEvaluator::new(|_: &[Vec<f32>]| anyhow::bail!("kaput"), 8, "fail")),
    );
    let server = Server::start_registry(Arc::clone(&registry), ServeConfig::default());
    let err = server.infer_model("bad", vec![1.0]).unwrap_err();
    assert!(err.contains("kaput") && err.contains("bad"), "{err}");
    assert_eq!(server.infer_model("good", vec![1.0, 2.0]).unwrap(), vec![3.0]);
    assert_eq!(server.metrics().counter("model.bad.errors"), 1);
    assert_eq!(server.metrics().counter("model.good.errors"), 0);
    assert_eq!(server.metrics().counter("errors"), 1);
    let _ = server.shutdown();
}
