//! Property test: the batch-major execution engine is equivalent to the
//! naive interpreter oracle on random adder graphs and random batches.
//!
//! The engine evaluates the same `mul, mul, add` expression per node in
//! topological order as the oracle (no FMA contraction, no
//! reassociation), so the primary assertion is **bit-identical** outputs.
//! A secondary tolerance sweep (documented slack `1e-5 * (1 + |y|)`, the
//! float-reassociation budget) guards the invariant even if a future
//! kernel rewrite introduces a different-but-legal summation order.

use lccnn::compress::{demo_network, NetworkPipeline, Recipe};
use lccnn::config::{ExecConfig, ExecMode, PoolMode, ShardMode};
use lccnn::exec::{
    engine_for_graph, BatchEngine, ExecPlan, Executor, FixedEngine, NaiveExecutor, ShardPlan,
    ShardedExecutor,
};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::util::Rng;

/// Random DAG: mixed depth/width, scaled+negated operands, some Zero and
/// scaled outputs — the full IR surface.
fn random_graph(rng: &mut Rng) -> AdderGraph {
    let inputs = 1 + rng.below(12);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    let nodes = rng.below(80);
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(9) as i32 - 4, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(9) as i32 - 4, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..1 + rng.below(8))
        .map(|_| {
            if rng.f32() < 0.15 {
                OutputSpec::Zero
            } else {
                OutputSpec::Ref(
                    refs[rng.below(refs.len())].scaled(rng.below(3) as i32 - 1, rng.f32() < 0.5),
                )
            }
        })
        .collect();
    g.set_outputs(outs);
    g
}

/// Every kernel-selection config crossed with both dispatch paths
/// (per-call scoped threads vs the persistent worker pool) — the two
/// must stay bit-identical.
fn engine_configs() -> Vec<(String, ExecConfig)> {
    let base = [
        ("serial", ExecConfig { threads: 1, chunk: 8, ..ExecConfig::default() }),
        (
            "chunk-parallel",
            ExecConfig { threads: 4, chunk: 4, parallel_min_batch: 2, ..ExecConfig::default() },
        ),
        (
            "level-parallel",
            ExecConfig {
                threads: 3,
                chunk: 4096,
                parallel_min_batch: usize::MAX,
                level_parallel_min_ops: 1,
                ..ExecConfig::default()
            },
        ),
    ];
    let mut out = Vec::new();
    for (mode_name, mode) in [("scoped", PoolMode::Scoped), ("persistent", PoolMode::Persistent)]
    {
        for (name, cfg) in base {
            out.push((format!("{name}/{mode_name}"), ExecConfig { pool_mode: mode, ..cfg }));
        }
    }
    out
}

#[test]
fn prop_engine_bit_identical_to_oracle() {
    let mut rng = Rng::new(0xE8EC);
    for trial in 0..25 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        for &b in &[0usize, 1, 2, 7, 33, 65] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            for (name, cfg) in engine_configs() {
                let engine = BatchEngine::with_config(&g, cfg);
                let got = engine.execute_batch(&xs);
                assert_eq!(got.len(), b, "trial {trial} {name} b {b}");
                for s in 0..b {
                    assert_eq!(
                        got[s], want[s],
                        "trial {trial} engine {name} batch {b} sample {s}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_engine_within_reassociation_tolerance() {
    // redundant with bit-equality today; keeps the documented contract
    // (1e-5 relative) if a kernel ever changes summation order
    let mut rng = Rng::new(0xBA7C);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let engine = BatchEngine::with_config(
            &g,
            ExecConfig { threads: 2, chunk: 8, parallel_min_batch: 4, ..ExecConfig::default() },
        );
        let xs: Vec<Vec<f32>> = (0..17).map(|_| rng.normal_vec(g.num_inputs(), 2.0)).collect();
        let want = oracle.execute_batch(&xs);
        let got = engine.execute_batch(&xs);
        for (ws, gs) in want.iter().zip(&got) {
            for (w, g) in ws.iter().zip(gs) {
                assert!(
                    (w - g).abs() <= 1e-5 * (1.0 + w.abs()),
                    "outside reassociation tolerance: {w} vs {g}"
                );
            }
        }
    }
}

/// Degenerate-shape sweep: single-level graphs (every node reads only
/// inputs — one ASAP level, the widest possible level for its size) and
/// node-free graphs, at batch 0/1 and chunk-boundary sizes, across every
/// config × pool-mode combination.
#[test]
fn prop_degenerate_shapes_bit_identical_to_oracle() {
    let mut rng = Rng::new(0xF1A7);
    for &nodes in &[0usize, 1, 48] {
        let inputs = 2 + rng.below(6);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..nodes {
            // operands are inputs only: the whole graph is ASAP level 1
            let a = Operand::input(rng.below(inputs))
                .scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            let b = Operand::input(rng.below(inputs))
                .scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..3)
            .map(|_| {
                if rng.f32() < 0.2 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        let oracle = NaiveExecutor::new(g.clone());
        for &b in &[0usize, 1, 2, 8, 9] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            for (name, cfg) in engine_configs() {
                let engine = BatchEngine::with_config(&g, cfg);
                let got = engine.execute_batch(&xs);
                assert_eq!(got, want, "nodes {nodes} engine {name} batch {b}");
            }
        }
    }
}

/// Shard sweep: shards 1/2/3/7 x both shard modes x both pool modes,
/// plus uneven explicit cuts, on random graphs and random batches — the
/// sharded scatter/gather layer must stay bit-identical to both the
/// unsharded engine and the `NaiveExecutor` oracle.
#[test]
fn prop_sharded_execution_bit_identical_to_oracle_and_unsharded() {
    let mut rng = Rng::new(0x54A2D);
    for trial in 0..12 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let plan = ExecPlan::new(&g);
        let unsharded = BatchEngine::with_config(&g, ExecConfig::serial());
        for &b in &[0usize, 1, 5, 33] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            assert_eq!(unsharded.execute_batch(&xs), want, "trial {trial} unsharded b {b}");
            for mode in [ShardMode::Serial, ShardMode::Parallel] {
                for pool in [PoolMode::Scoped, PoolMode::Persistent] {
                    for shards in [1usize, 2, 3, 7] {
                        let cfg = ExecConfig {
                            threads: 2,
                            shards,
                            shard_mode: mode,
                            pool_mode: pool,
                            ..ExecConfig::default()
                        };
                        let sharded = ShardedExecutor::from_graph(&g, cfg);
                        assert_eq!(
                            sharded.execute_batch(&xs),
                            want,
                            "trial {trial} b {b} x{shards} {mode:?}/{pool:?}"
                        );
                    }
                }
            }
            // uneven column splits via explicit interior cuts
            let n = g.num_outputs();
            if n >= 3 {
                for cuts in [vec![1], vec![1, n - 1], vec![n / 2]] {
                    let sp = ShardPlan::with_cuts(&plan, &cuts).expect("valid cuts");
                    let sharded = ShardedExecutor::from_shard_plan(sp, ExecConfig::serial());
                    assert_eq!(
                        sharded.execute_batch(&xs),
                        want,
                        "trial {trial} b {b} cuts {cuts:?}"
                    );
                }
            }
        }
    }
}

/// Fixed-datapath differential sweep on the same random-graph surface:
/// every engine config must land within the lowered plan's analytic
/// error bound of the float oracle (plus slack for the oracle's own f32
/// rounding), and all configs must agree **bit-exactly** with each other
/// — integer lanes leave no scheduling freedom. Trials whose worst-case
/// mantissa could saturate the accumulator are skipped: saturation is
/// the bound's stated precondition.
#[test]
fn prop_fixed_engine_within_error_bound_on_all_shapes() {
    let mut rng = Rng::new(0xF17ED);
    let mut checked = 0usize;
    for trial in 0..20 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let probe =
            FixedEngine::with_config(&g, ExecConfig::serial()).expect("±2^k plans always lower");
        if probe.fixed_plan().max_mantissa_bound(8.0) >= 0.25 * i64::MAX as f64 {
            continue;
        }
        let bounds = probe.error_bounds().to_vec();
        for &b in &[0usize, 1, 2, 7, 33] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            let reference = probe.execute_batch(&xs);
            for (ws, gs) in want.iter().zip(&reference) {
                for ((w, g), &e) in ws.iter().zip(gs).zip(&bounds) {
                    let tol = e + 1e-4 * (1.0 + w.abs() as f64);
                    assert!(
                        ((w - g).abs() as f64) <= tol,
                        "trial {trial} b {b}: fixed {g} vs float {w}, bound {e}"
                    );
                    checked += 1;
                }
            }
            for (name, cfg) in engine_configs() {
                let engine = FixedEngine::with_config(
                    &g,
                    ExecConfig { exec_mode: ExecMode::Fixed, ..cfg },
                )
                .unwrap();
                assert_eq!(
                    engine.execute_batch(&xs),
                    reference,
                    "trial {trial} {name} b {b}: fixed results must be bit-stable"
                );
            }
        }
    }
    assert!(checked > 100, "sweep degenerated: only {checked} values checked");
}

/// Sharded fixed execution: shards 1/2/3/7 × both shard modes × both
/// pool modes, plus uneven explicit cuts — all bit-identical to the
/// unsharded fixed engine (and therefore within the same error bound of
/// the oracle).
#[test]
fn prop_fixed_sharded_bit_identical_to_unsharded_fixed() {
    let mut rng = Rng::new(0x54F12D);
    for trial in 0..8 {
        let g = random_graph(&mut rng);
        let plan = ExecPlan::new(&g);
        let unsharded =
            FixedEngine::with_config(&g, ExecConfig::serial()).expect("±2^k plans always lower");
        for &b in &[0usize, 1, 5, 33] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = unsharded.execute_batch(&xs);
            for mode in [ShardMode::Serial, ShardMode::Parallel] {
                for pool in [PoolMode::Scoped, PoolMode::Persistent] {
                    for shards in [1usize, 2, 3, 7] {
                        let cfg = ExecConfig {
                            threads: 2,
                            shards,
                            shard_mode: mode,
                            pool_mode: pool,
                            exec_mode: ExecMode::Fixed,
                            ..ExecConfig::default()
                        };
                        let sharded = engine_for_graph(&g, cfg);
                        assert_eq!(
                            sharded.execute_batch(&xs),
                            want,
                            "trial {trial} b {b} x{shards} {mode:?}/{pool:?}"
                        );
                    }
                }
            }
            let n = g.num_outputs();
            if n >= 3 {
                for cuts in [vec![1], vec![1, n - 1], vec![n / 2]] {
                    let sp = ShardPlan::with_cuts(&plan, &cuts).expect("valid cuts");
                    let cfg =
                        ExecConfig { exec_mode: ExecMode::Fixed, ..ExecConfig::serial() };
                    let sharded = ShardedExecutor::from_shard_plan(sp, cfg);
                    assert_eq!(
                        sharded.execute_batch(&xs),
                        want,
                        "trial {trial} b {b} cuts {cuts:?}"
                    );
                }
            }
        }
    }
}

/// Exactly-representable plans (nonnegative shifts, inputs on the
/// activation grid, magnitudes small enough that f32 arithmetic is
/// exact): the fixed engine must agree with the float oracle bit for
/// bit, across every engine config.
#[test]
fn prop_fixed_bit_exact_on_representable_plans() {
    let mut rng = Rng::new(0xB17E);
    for trial in 0..10 {
        // growth-capped generator: <= 6 nodes, shifts in {0, 1}, input
        // mantissas <= 2^6, so every intermediate mantissa stays below
        // 2^6 * 4^7 = 2^20 < 2^24 — all float arithmetic is exact
        let inputs = 2 + rng.below(5);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..rng.below(7) {
            let a = refs[rng.below(refs.len())].scaled(rng.below(2) as i32, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(2) as i32, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..2 + rng.below(3))
            .map(|_| {
                OutputSpec::Ref(
                    refs[rng.below(refs.len())].scaled(rng.below(2) as i32, rng.f32() < 0.5),
                )
            })
            .collect();
        g.set_outputs(outs);
        let oracle = NaiveExecutor::new(g.clone());
        let probe = FixedEngine::with_config(&g, ExecConfig::serial()).unwrap();
        let step = probe.fixed_plan().step() as f32;
        assert!(
            probe.fixed_plan().max_mantissa_bound(64.0 * step as f64) < (24f64).exp2(),
            "trial {trial}: generator must keep all mantissas f32-exact"
        );
        // inputs are exact multiples of the activation grid step
        let xs: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..inputs).map(|_| (rng.below(129) as f32 - 64.0) * step).collect())
            .collect();
        let want = oracle.execute_batch(&xs);
        for (name, cfg) in engine_configs() {
            let engine = FixedEngine::with_config(&g, cfg).unwrap();
            assert_eq!(engine.execute_batch(&xs), want, "trial {trial} {name}");
        }
    }
}

#[test]
fn prop_execute_one_matches_batch_row() {
    let mut rng = Rng::new(0x51);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let engine = BatchEngine::with_config(&g, ExecConfig::serial());
        let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
        let one = engine.execute_one(&x);
        assert_eq!(one, oracle.execute_one(&x));
        assert_eq!(one, engine.execute_batch(&[x.clone()])[0]);
    }
}

#[test]
fn engine_reports_graph_shape() {
    let mut rng = Rng::new(9);
    let g = random_graph(&mut rng);
    let engine = BatchEngine::new(&g);
    assert_eq!(engine.num_inputs(), g.num_inputs());
    assert_eq!(engine.num_outputs(), g.num_outputs());
    assert_eq!(engine.plan().additions(), g.additions());
}

/// Full-network differential sweep: the chained `NetworkExecutor` vs
/// the hand-chained per-layer `NaiveExecutor` oracle
/// (`CompressedNetwork::oracle_forward_batch`), across float/fixed exec
/// modes x shards 1/2 x both pool modes. Float chains must match the
/// oracle bit for bit; fixed chains stay within the network's
/// propagated analytic bound (per-layer bounds composed through the
/// operator inf-norms; ReLU is 1-Lipschitz); and within a mode every
/// config agrees bit-exactly with every other — sharding and dispatch
/// leave no numerical freedom.
#[test]
fn prop_network_executor_matches_hand_chained_oracle() {
    let ckpt = demo_network(&[10, 8, 6], 0xD1FF);
    let mut rng = Rng::new(0x2D1FF);
    let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(ckpt.input_dim(), 1.0)).collect();
    for mode in [ExecMode::Float, ExecMode::Fixed] {
        let mut runs: Vec<(String, Vec<Vec<f32>>)> = Vec::new();
        for shards in [1usize, 2] {
            for pool in [PoolMode::Scoped, PoolMode::Persistent] {
                let exec = ExecConfig {
                    exec_mode: mode,
                    shards,
                    pool_mode: pool,
                    threads: 2,
                    ..ExecConfig::default()
                };
                let recipe = Recipe { exec, ..Recipe::default() };
                let net = NetworkPipeline::from_recipe(&recipe).unwrap().run(&ckpt).unwrap();
                let engine = net.executor().unwrap();
                let got = engine.execute_batch(&xs);
                let want = net.oracle_forward_batch(&xs);
                let bound = engine.max_error_bound();
                let tag = format!("{mode:?} x{shards} {pool:?}");
                if mode == ExecMode::Float {
                    assert_eq!(bound, 0.0, "{tag}: float chains carry no error bound");
                    assert_eq!(got, want, "{tag}");
                } else {
                    assert!(bound > 0.0, "{tag}: fixed chains must propagate a bound");
                    for (gs, ws) in got.iter().zip(&want) {
                        for (g, w) in gs.iter().zip(ws) {
                            let tol = bound + 1e-3 * (1.0 + w.abs() as f64);
                            assert!(((g - w).abs() as f64) <= tol, "{tag}: {g} vs {w}");
                        }
                    }
                }
                runs.push((tag, got));
            }
        }
        let (first_tag, first) = &runs[0];
        for (tag, run) in &runs[1..] {
            assert_eq!(run, first, "{tag} diverged from {first_tag}");
        }
    }
}
