//! Property test: the batch-major execution engine is equivalent to the
//! naive interpreter oracle on random adder graphs and random batches.
//!
//! The engine evaluates the same `mul, mul, add` expression per node in
//! topological order as the oracle (no FMA contraction, no
//! reassociation), so the primary assertion is **bit-identical** outputs.
//! A secondary tolerance sweep (documented slack `1e-5 * (1 + |y|)`, the
//! float-reassociation budget) guards the invariant even if a future
//! kernel rewrite introduces a different-but-legal summation order.

use lccnn::config::{ExecConfig, PoolMode, ShardMode};
use lccnn::exec::{BatchEngine, ExecPlan, Executor, NaiveExecutor, ShardPlan, ShardedExecutor};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::util::Rng;

/// Random DAG: mixed depth/width, scaled+negated operands, some Zero and
/// scaled outputs — the full IR surface.
fn random_graph(rng: &mut Rng) -> AdderGraph {
    let inputs = 1 + rng.below(12);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    let nodes = rng.below(80);
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(9) as i32 - 4, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(9) as i32 - 4, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..1 + rng.below(8))
        .map(|_| {
            if rng.f32() < 0.15 {
                OutputSpec::Zero
            } else {
                OutputSpec::Ref(
                    refs[rng.below(refs.len())].scaled(rng.below(3) as i32 - 1, rng.f32() < 0.5),
                )
            }
        })
        .collect();
    g.set_outputs(outs);
    g
}

/// Every kernel-selection config crossed with both dispatch paths
/// (per-call scoped threads vs the persistent worker pool) — the two
/// must stay bit-identical.
fn engine_configs() -> Vec<(String, ExecConfig)> {
    let base = [
        ("serial", ExecConfig { threads: 1, chunk: 8, ..ExecConfig::default() }),
        (
            "chunk-parallel",
            ExecConfig { threads: 4, chunk: 4, parallel_min_batch: 2, ..ExecConfig::default() },
        ),
        (
            "level-parallel",
            ExecConfig {
                threads: 3,
                chunk: 4096,
                parallel_min_batch: usize::MAX,
                level_parallel_min_ops: 1,
                ..ExecConfig::default()
            },
        ),
    ];
    let mut out = Vec::new();
    for (mode_name, mode) in [("scoped", PoolMode::Scoped), ("persistent", PoolMode::Persistent)]
    {
        for (name, cfg) in base {
            out.push((format!("{name}/{mode_name}"), ExecConfig { pool_mode: mode, ..cfg }));
        }
    }
    out
}

#[test]
fn prop_engine_bit_identical_to_oracle() {
    let mut rng = Rng::new(0xE8EC);
    for trial in 0..25 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        for &b in &[0usize, 1, 2, 7, 33, 65] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            for (name, cfg) in engine_configs() {
                let engine = BatchEngine::with_config(&g, cfg);
                let got = engine.execute_batch(&xs);
                assert_eq!(got.len(), b, "trial {trial} {name} b {b}");
                for s in 0..b {
                    assert_eq!(
                        got[s], want[s],
                        "trial {trial} engine {name} batch {b} sample {s}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_engine_within_reassociation_tolerance() {
    // redundant with bit-equality today; keeps the documented contract
    // (1e-5 relative) if a kernel ever changes summation order
    let mut rng = Rng::new(0xBA7C);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let engine = BatchEngine::with_config(
            &g,
            ExecConfig { threads: 2, chunk: 8, parallel_min_batch: 4, ..ExecConfig::default() },
        );
        let xs: Vec<Vec<f32>> = (0..17).map(|_| rng.normal_vec(g.num_inputs(), 2.0)).collect();
        let want = oracle.execute_batch(&xs);
        let got = engine.execute_batch(&xs);
        for (ws, gs) in want.iter().zip(&got) {
            for (w, g) in ws.iter().zip(gs) {
                assert!(
                    (w - g).abs() <= 1e-5 * (1.0 + w.abs()),
                    "outside reassociation tolerance: {w} vs {g}"
                );
            }
        }
    }
}

/// Degenerate-shape sweep: single-level graphs (every node reads only
/// inputs — one ASAP level, the widest possible level for its size) and
/// node-free graphs, at batch 0/1 and chunk-boundary sizes, across every
/// config × pool-mode combination.
#[test]
fn prop_degenerate_shapes_bit_identical_to_oracle() {
    let mut rng = Rng::new(0xF1A7);
    for &nodes in &[0usize, 1, 48] {
        let inputs = 2 + rng.below(6);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..nodes {
            // operands are inputs only: the whole graph is ASAP level 1
            let a = Operand::input(rng.below(inputs))
                .scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            let b = Operand::input(rng.below(inputs))
                .scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..3)
            .map(|_| {
                if rng.f32() < 0.2 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        let oracle = NaiveExecutor::new(g.clone());
        for &b in &[0usize, 1, 2, 8, 9] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            for (name, cfg) in engine_configs() {
                let engine = BatchEngine::with_config(&g, cfg);
                let got = engine.execute_batch(&xs);
                assert_eq!(got, want, "nodes {nodes} engine {name} batch {b}");
            }
        }
    }
}

/// Shard sweep: shards 1/2/3/7 x both shard modes x both pool modes,
/// plus uneven explicit cuts, on random graphs and random batches — the
/// sharded scatter/gather layer must stay bit-identical to both the
/// unsharded engine and the `NaiveExecutor` oracle.
#[test]
fn prop_sharded_execution_bit_identical_to_oracle_and_unsharded() {
    let mut rng = Rng::new(0x54A2D);
    for trial in 0..12 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let plan = ExecPlan::new(&g);
        let unsharded = BatchEngine::with_config(&g, ExecConfig::serial());
        for &b in &[0usize, 1, 5, 33] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            assert_eq!(unsharded.execute_batch(&xs), want, "trial {trial} unsharded b {b}");
            for mode in [ShardMode::Serial, ShardMode::Parallel] {
                for pool in [PoolMode::Scoped, PoolMode::Persistent] {
                    for shards in [1usize, 2, 3, 7] {
                        let cfg = ExecConfig {
                            threads: 2,
                            shards,
                            shard_mode: mode,
                            pool_mode: pool,
                            ..ExecConfig::default()
                        };
                        let sharded = ShardedExecutor::from_graph(&g, cfg);
                        assert_eq!(
                            sharded.execute_batch(&xs),
                            want,
                            "trial {trial} b {b} x{shards} {mode:?}/{pool:?}"
                        );
                    }
                }
            }
            // uneven column splits via explicit interior cuts
            let n = g.num_outputs();
            if n >= 3 {
                for cuts in [vec![1], vec![1, n - 1], vec![n / 2]] {
                    let sp = ShardPlan::with_cuts(&plan, &cuts).expect("valid cuts");
                    let sharded = ShardedExecutor::from_shard_plan(sp, ExecConfig::serial());
                    assert_eq!(
                        sharded.execute_batch(&xs),
                        want,
                        "trial {trial} b {b} cuts {cuts:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_execute_one_matches_batch_row() {
    let mut rng = Rng::new(0x51);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let oracle = NaiveExecutor::new(g.clone());
        let engine = BatchEngine::with_config(&g, ExecConfig::serial());
        let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
        let one = engine.execute_one(&x);
        assert_eq!(one, oracle.execute_one(&x));
        assert_eq!(one, engine.execute_batch(&[x.clone()])[0]);
    }
}

#[test]
fn engine_reports_graph_shape() {
    let mut rng = Rng::new(9);
    let g = random_graph(&mut rng);
    let engine = BatchEngine::new(&g);
    assert_eq!(engine.num_inputs(), g.num_inputs());
    assert_eq!(engine.num_outputs(), g.num_outputs());
    assert_eq!(engine.plan().additions(), g.additions());
}
