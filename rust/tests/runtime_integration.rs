//! Integration: the PJRT runtime executes the AOT artifacts and matches
//! the rust-side reference numerics (artifact ≡ substrate parity).

mod common;

use common::runtime_or_skip;
use lccnn::nn::mlp::MlpParams;
use lccnn::prune::prox_group_lasso_rows;
use lccnn::runtime::HostTensor;
use lccnn::tensor::Matrix;
use lccnn::util::Rng;

#[test]
fn artifact_registry_lists_everything() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.artifact_names();
    for expected in [
        "mlp_train_step",
        "mlp_eval",
        "mlp_fwd",
        "prox_step",
        "shared_matvec",
        "resnet_train_step_fk",
        "resnet_train_step_pk",
        "resnet_eval",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing {expected}");
    }
}

#[test]
fn mlp_fwd_matches_rust_forward() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.get("mlp_fwd").expect("compile mlp_fwd");
    let params = MlpParams::init(7);
    let batch = exe.spec.inputs[4].dims[0];
    let mut rng = Rng::new(8);
    let x: Vec<f32> = rng.normal_vec(batch * 784, 1.0);
    let inputs = vec![
        HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
        HostTensor::F32(vec![300], params.b1.clone()),
        HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
        HostTensor::F32(vec![10], params.b2.clone()),
        HostTensor::F32(vec![batch, 784], x.clone()),
    ];
    let outs = exe.run(&inputs).expect("run");
    let logits = outs[0].as_f32().unwrap();
    for b in 0..batch {
        let want = params.forward_one(&x[b * 784..(b + 1) * 784]);
        for j in 0..10 {
            let got = logits[b * 10 + j];
            assert!(
                (got - want[j]).abs() < 1e-3 + 1e-3 * want[j].abs(),
                "b={b} j={j}: {got} vs {}",
                want[j]
            );
        }
    }
}

#[test]
fn prox_artifact_matches_rust_prox() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.get("prox_step").expect("compile prox_step");
    let mut rng = Rng::new(9);
    // artifact shape: W [784, 300] (rows = groups = W1 columns)
    let w = Matrix::randn(784, 300, 0.1, &mut rng);
    let thresh = 0.3f32;
    let outs = exe
        .run(&[HostTensor::F32(vec![784, 300], w.data().to_vec()), HostTensor::scalar_f32(thresh)])
        .expect("run");
    let got = outs[0].as_f32().unwrap();
    let want = prox_group_lasso_rows(&w, thresh);
    for (g, w) in got.iter().zip(want.data()) {
        assert!((g - w).abs() < 1e-5 + 1e-4 * w.abs(), "{g} vs {w}");
    }
}

#[test]
fn shared_matvec_artifact_matches_rust_shared_layer() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.get("shared_matvec").expect("compile shared_matvec");
    let batch = exe.spec.inputs[0].dims[0];
    let k = exe.spec.inputs[0].dims[1];
    let c = exe.spec.inputs[1].dims[1];
    let n = exe.spec.inputs[2].dims[0];
    let mut rng = Rng::new(10);
    let x: Vec<f32> = rng.normal_vec(batch * k, 1.0);
    let labels: Vec<usize> = (0..k).map(|_| rng.below(c)).collect();
    let mut onehot = vec![0.0f32; k * c];
    for (j, &l) in labels.iter().enumerate() {
        onehot[j * c + l] = 1.0;
    }
    let centroids = Matrix::randn(n, c, 0.5, &mut rng);
    let outs = exe
        .run(&[
            HostTensor::F32(vec![batch, k], x.clone()),
            HostTensor::F32(vec![k, c], onehot),
            HostTensor::F32(vec![n, c], centroids.data().to_vec()),
        ])
        .expect("run");
    let got = outs[0].as_f32().unwrap();
    let layer = lccnn::share::SharedLayer { centroids, labels };
    for b in 0..batch {
        let want = layer.apply(&x[b * k..(b + 1) * k]);
        for j in 0..n {
            let g = got[b * n + j];
            assert!((g - want[j]).abs() < 1e-2 + 1e-3 * want[j].abs(), "{g} vs {}", want[j]);
        }
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.get("prox_step").expect("compile");
    let bad = exe.run(&[
        HostTensor::F32(vec![10, 10], vec![0.0; 100]),
        HostTensor::scalar_f32(0.0),
    ]);
    assert!(bad.is_err(), "shape mismatch must be rejected");
    let wrong_arity = exe.run(&[HostTensor::scalar_f32(0.0)]);
    assert!(wrong_arity.is_err());
}
