//! Integration: serving layer over both backends (compressed VM + dense
//! PJRT via the thread-confined service).

mod common;

use common::artifacts_dir;
use lccnn::config::ServeConfig;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::nn::mlp::MlpParams;
use lccnn::runtime::{HostTensor, PjrtService};
use lccnn::serve::{CompressedMlpBackend, MutexEvaluator, PjrtMlpBackend, Server};
use lccnn::tensor::Matrix;
use lccnn::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn dense_as_compressed(params: &MlpParams) -> CompressedMlp {
    CompressedMlp {
        kept: (0..784).collect(),
        layer1: Layer1::Dense(params.w1.clone()),
        b1: params.b1.clone(),
        w2: params.w2.clone(),
        b2: params.b2.clone(),
    }
}

#[test]
fn vm_backend_serves_correct_logits() {
    let params = MlpParams::init(0);
    let model = Arc::new(dense_as_compressed(&params));
    let server = Server::start(Arc::new(CompressedMlpBackend { model }), ServeConfig::default());
    let mut rng = Rng::new(1);
    let x: Vec<f32> = rng.normal_vec(784, 1.0);
    let y = server.infer(x.clone()).unwrap();
    let want = params.forward_one(&x);
    for (a, b) in y.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn pjrt_backend_matches_vm_backend() {
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let service = Arc::new(PjrtService::start(artifacts_dir()).unwrap());
    let params = MlpParams::init(2);
    let host_params = vec![
        HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
        HostTensor::F32(vec![300], params.b1.clone()),
        HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
        HostTensor::F32(vec![10], params.b2.clone()),
    ];
    let backend = PjrtMlpBackend::new(service, host_params, 32);
    let server = Server::start(Arc::new(backend), ServeConfig::default());
    let mut rng = Rng::new(3);
    // submit a burst so batching kicks in, including a partial batch
    let xs: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(784, 1.0)).collect();
    let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
    for (x, rx) in xs.iter().zip(rxs) {
        let y = rx.recv().unwrap().unwrap();
        let want = params.forward_one(x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-2 + 1e-3 * b.abs(), "{a} vs {b}");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 40);
}

/// Shutdown ordering: every request submitted before shutdown either
/// completes or gets a clean error — never a hang — and the latency
/// percentiles the final stats report are monotone (p50 ≤ p99).
#[test]
fn shutdown_with_in_flight_requests_never_hangs() {
    // a deliberately slow backend so shutdown races a deep queue
    let slow: Arc<dyn lccnn::serve::BatchEvaluator> = Arc::new(MutexEvaluator::new(
        |xs: &[Vec<f32>]| {
            std::thread::sleep(Duration::from_millis(1));
            Ok(xs.iter().map(|x| vec![x.iter().sum()]).collect())
        },
        8,
        "slow-echo",
    ));
    let cfg = ServeConfig { max_batch: 8, batch_timeout_us: 100, ..Default::default() };
    let server = Server::start(slow, cfg);
    let rxs: Vec<_> = (0..40).map(|i| server.submit(vec![i as f32])).collect();
    let stats = server.shutdown(); // drains the queue, then joins
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(y)) => assert_eq!(y, vec![i as f32], "request {i} got the wrong answer"),
            Ok(Err(e)) => panic!("request {i}: drained shutdown must complete, got error {e}"),
            Err(e) => panic!("request {i} hung or was dropped across shutdown: {e}"),
        }
    }
    assert_eq!(stats.requests, 40);
    assert!(stats.batches >= 1);
    assert!(
        stats.p50_latency_us <= stats.p99_latency_us,
        "percentiles must be monotone: {stats:?}"
    );
    assert!(stats.p50_latency_us >= 0.0 && stats.p99_latency_us.is_finite(), "{stats:?}");
}

#[test]
fn matrix_identity_sanity() {
    // serving tests share this crate; quick cross-check that the dense
    // path used above is the true reference
    let m = Matrix::identity(3);
    assert_eq!(m.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
}
