//! Integration: serving layer over both backends (compressed VM + dense
//! PJRT via the thread-confined service).

mod common;

use common::artifacts_dir;
use lccnn::config::ServeConfig;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::nn::mlp::MlpParams;
use lccnn::runtime::{HostTensor, PjrtService};
use lccnn::serve::{CompressedMlpBackend, PjrtMlpBackend, Server};
use lccnn::tensor::Matrix;
use lccnn::util::Rng;
use std::sync::Arc;

fn dense_as_compressed(params: &MlpParams) -> CompressedMlp {
    CompressedMlp {
        kept: (0..784).collect(),
        layer1: Layer1::Dense(params.w1.clone()),
        b1: params.b1.clone(),
        w2: params.w2.clone(),
        b2: params.b2.clone(),
    }
}

#[test]
fn vm_backend_serves_correct_logits() {
    let params = MlpParams::init(0);
    let model = Arc::new(dense_as_compressed(&params));
    let server = Server::start(
        Arc::new(CompressedMlpBackend { model }),
        ServeConfig::default(),
    );
    let mut rng = Rng::new(1);
    let x: Vec<f32> = rng.normal_vec(784, 1.0);
    let y = server.infer(x.clone()).unwrap();
    let want = params.forward_one(&x);
    for (a, b) in y.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn pjrt_backend_matches_vm_backend() {
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let service = Arc::new(PjrtService::start(artifacts_dir()).unwrap());
    let params = MlpParams::init(2);
    let host_params = vec![
        HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
        HostTensor::F32(vec![300], params.b1.clone()),
        HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
        HostTensor::F32(vec![10], params.b2.clone()),
    ];
    let backend = PjrtMlpBackend::new(service, host_params, 32);
    let server = Server::start(Arc::new(backend), ServeConfig::default());
    let mut rng = Rng::new(3);
    // submit a burst so batching kicks in, including a partial batch
    let xs: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(784, 1.0)).collect();
    let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
    for (x, rx) in xs.iter().zip(rxs) {
        let y = rx.recv().unwrap().unwrap();
        let want = params.forward_one(x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-2 + 1e-3 * b.abs(), "{a} vs {b}");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 40);
}

#[test]
fn matrix_identity_sanity() {
    // serving tests share this crate; quick cross-check that the dense
    // path used above is the true reference
    let m = Matrix::identity(3);
    assert_eq!(m.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
}
