//! Integration: remote shard serving (`exec::remote`) over loopback
//! TCP — in-process `ShardWorker`s on ephemeral ports, no fixtures.
//!
//! * Equivalence: a remote gather is **bit-identical** to the local
//!   `ShardedExecutor` over the same cuts and to the full engine, for
//!   1/2/3 shards × uneven ranges × `float|fixed`.
//! * Robustness: garbage, wrong-version and oversized-length frames
//!   get typed error frames (worker side) or typed connect errors
//!   (client side) — never a panic or a hang.
//! * Failover: a killed shard sheds within the configured timeouts
//!   with `ExecError::Unavailable` and a `shard.<i>.dead` count;
//!   survivors keep serving; a slow-loris peer stalls only itself.
//! * Recovery: a worker killed and restarted on the same address is
//!   rediscovered by the half-open cooldown probe (`shard.<i>.recovered`)
//!   without rebuilding the gather, and the probe is a single cheap
//!   attempt — never the full retry+backoff ladder.
//! * Replication: `|`-grouped replicas of one output range fail over
//!   client-side (`shard.<i>.failover`) — killing one replica causes
//!   zero sheds and bit-identical answers.
//! * Drain: a `Drain` frame (or `ShardWorker::drain`) finishes
//!   in-flight batches, refuses new ones with `ERR_DRAINING`, and
//!   surfaces through `Ping` status and `health_report`.
//! * Serving: `ModelRegistry::register_remote_sharded` entries shed
//!   (`ServeError::Shed` + `model.<name>.shed`) when a worker dies,
//!   while local models on the same server keep answering.

use lccnn::config::{ExecConfig, ExecMode, ServeConfig};
use lccnn::exec::remote::protocol;
use lccnn::exec::{
    remote_sharded_executor, BatchEngine, ExecError, ExecHealth, ExecPlan, Executor, FixedEngine,
    RemoteExecutor, RemoteOptions, ShardWorker, ShardedExecutor,
};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::metrics::Metrics;
use lccnn::serve::{ModelRegistry, Server};
use lccnn::util::Rng;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn wide_graph(inputs: usize, nodes: usize, outputs: usize, seed: u64) -> AdderGraph {
    let mut rng = Rng::new(seed);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..outputs)
        .map(|_| {
            if rng.f32() < 0.1 {
                OutputSpec::Zero
            } else {
                OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
            }
        })
        .collect();
    g.set_outputs(outs);
    g
}

/// Serial engine over one output-column cut of `plan`, float or fixed.
fn shard_engine(plan: &ExecPlan, range: &Range<usize>, mode: ExecMode) -> Arc<dyn Executor> {
    let sub = plan.extract_output_range(range.start, range.end);
    let cfg = ExecConfig { exec_mode: mode, ..ExecConfig::serial() };
    match mode {
        ExecMode::Float => Arc::new(BatchEngine::from_plan(sub, cfg)),
        ExecMode::Fixed => Arc::new(FixedEngine::from_plan(&sub, cfg).expect("±2^k plans lower")),
    }
}

/// One worker per cut, each on an ephemeral loopback port.
fn spawn_workers(
    plan: &ExecPlan,
    cuts: &[Range<usize>],
    mode: ExecMode,
) -> (Vec<ShardWorker>, Vec<String>) {
    let workers: Vec<ShardWorker> = cuts
        .iter()
        .map(|r| {
            ShardWorker::spawn(shard_engine(plan, r, mode), r.clone(), mode, "127.0.0.1:0")
                .expect("spawn shard worker")
        })
        .collect();
    let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
    (workers, addrs)
}

/// Short bounded timeouts so failover tests finish in milliseconds,
/// not the production defaults.
fn fast_opts() -> RemoteOptions {
    RemoteOptions {
        connect_timeout: Duration::from_millis(300),
        read_timeout: Duration::from_millis(600),
        write_timeout: Duration::from_millis(600),
        retries: 1,
        backoff: Duration::from_millis(10),
        cooldown: Duration::from_millis(150),
        ..RemoteOptions::default()
    }
}

#[test]
fn remote_gather_bit_identical_to_local_across_shards_and_modes() {
    let g = wide_graph(12, 40, 9, 7);
    let plan = ExecPlan::new(&g);
    let oracle = lccnn::exec::NaiveExecutor::new(g.clone());
    let mut rng = Rng::new(0x2E307E);
    let xs: Vec<Vec<f32>> = (0..13).map(|_| rng.normal_vec(12, 1.0)).collect();
    let cuts: [&[Range<usize>]; 3] = [&[0..9], &[0..2, 2..9], &[0..4, 4..5, 5..9]];
    for mode in [ExecMode::Float, ExecMode::Fixed] {
        let full = shard_engine(&plan, &(0..9), mode);
        let want = full.execute_batch(&xs);
        if mode == ExecMode::Float {
            assert_eq!(want, oracle.execute_batch(&xs), "float engine is the oracle bit-exact");
        }
        for cut in cuts {
            // the local reference: the same cuts gathered in-process
            let parts: Vec<(Range<usize>, Arc<dyn Executor>)> =
                cut.iter().map(|r| (r.clone(), shard_engine(&plan, r, mode))).collect();
            let local = ShardedExecutor::from_executors(parts, ExecConfig::serial()).unwrap();
            assert_eq!(local.execute_batch(&xs), want, "{mode:?} local gather over {cut:?}");

            let (workers, addrs) = spawn_workers(&plan, cut, mode);
            let metrics = Arc::new(Metrics::new());
            let remote =
                remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), metrics)
                    .expect("connect all shards");
            assert_eq!(remote.num_shards(), cut.len());
            assert_eq!(remote.num_inputs(), 12);
            assert_eq!(remote.num_outputs(), 9);
            let got = remote.execute_batch(&xs);
            assert_eq!(got, want, "{mode:?} remote gather over {cut:?} must be bit-identical");
            // empty batch round-trips too
            assert_eq!(remote.execute_batch(&[]), Vec::<Vec<f32>>::new());
            drop(workers);
        }
    }
}

#[test]
fn remote_handshake_reports_the_shard_range() {
    let g = wide_graph(6, 20, 5, 11);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[1..4], ExecMode::Float);
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    assert_eq!(client.range(), 1..4);
    assert_eq!(client.num_inputs(), 6);
    assert_eq!(client.num_outputs(), 3);
    assert_eq!(client.name(), "remote-shard");
    // a gather whose single shard does not start at output 0 is rejected
    let metrics = Arc::new(Metrics::new());
    let err = remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), metrics);
    assert!(err.is_err(), "partial-coverage gather must be rejected");
    drop(workers);
}

/// Worker-side robustness: garbage, wrong-version and oversized-length
/// frames each get a typed `Err` frame (or a clean close) and never
/// take the worker down — a fresh client still serves afterwards.
#[test]
fn worker_answers_garbage_with_typed_errors_and_survives() {
    let g = wide_graph(4, 12, 3, 3);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..3], ExecMode::Float);

    let mut bad_version = Vec::new();
    bad_version.extend_from_slice(&protocol::MAGIC.to_le_bytes());
    bad_version.extend_from_slice(&9u16.to_le_bytes());
    bad_version.extend_from_slice(&[3, 1]);
    bad_version.extend_from_slice(&7u64.to_le_bytes());
    bad_version.extend_from_slice(&0u32.to_le_bytes());

    let mut oversized = Vec::new();
    oversized.extend_from_slice(&protocol::MAGIC.to_le_bytes());
    oversized.extend_from_slice(&protocol::VERSION.to_le_bytes());
    oversized.extend_from_slice(&[3, 1]);
    oversized.extend_from_slice(&7u64.to_le_bytes());
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());

    let attacks: [(&str, Vec<u8>); 3] = [
        ("random bytes", vec![0xAB; 64]),
        ("wrong version", bad_version),
        ("oversized length prefix", oversized),
    ];
    for (name, bytes) in &attacks {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(3))).unwrap();
        s.write_all(bytes).unwrap();
        match protocol::read_frame(&mut s, protocol::MAX_FRAME) {
            Ok(frame) => {
                assert_eq!(frame.kind, protocol::Kind::Err, "{name}: typed error frame");
                let (code, _msg) = protocol::decode_error(&frame.payload).unwrap();
                assert_eq!(code, protocol::ERR_PROTOCOL, "{name}");
            }
            // a close without a reply is acceptable; a hang is not
            Err(protocol::ProtocolError::Truncated | protocol::ProtocolError::Io(_)) => {}
            Err(e) => panic!("{name}: unexpected client-side failure {e}"),
        }
    }
    // half a header then close: the worker treats it as a clean EOF
    let mut s = TcpStream::connect(&addrs[0]).unwrap();
    s.write_all(&protocol::MAGIC.to_le_bytes()).unwrap();
    drop(s);

    // the worker survived every attack and still serves real clients
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    let xs = vec![vec![1.0, 2.0, 3.0, 4.0]];
    let want = shard_engine(&plan, &(0..3), ExecMode::Float).execute_batch(&xs);
    assert_eq!(client.execute_batch(&xs), want);
    drop(workers);
}

/// Client-side robustness: a server speaking garbage (or nothing) at
/// the handshake yields a typed, bounded connect error — never a hang.
#[test]
fn client_rejects_garbage_and_silent_servers_with_bounded_typed_errors() {
    // garbage greeter: accepts and answers the handshake with junk
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let greeter = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let _ = s.write_all(&[0xEE; 40]);
            std::thread::sleep(Duration::from_millis(200));
        }
    });
    let t0 = Instant::now();
    let err = RemoteExecutor::connect(&addr, fast_opts()).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed: {err}");
    assert!(t0.elapsed() < Duration::from_secs(5), "bounded: {:?}", t0.elapsed());
    greeter.join().unwrap();

    // accept-then-hang: the handshake read must hit read_timeout
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let hanger = std::thread::spawn(move || {
        if let Ok((s, _)) = listener.accept() {
            std::thread::sleep(Duration::from_millis(1500));
            drop(s);
        }
    });
    let t0 = Instant::now();
    let err = RemoteExecutor::connect(&addr, fast_opts()).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed: {err}");
    let bound = fast_opts().connect_timeout + fast_opts().read_timeout + Duration::from_secs(2);
    assert!(t0.elapsed() < bound, "hang-bounded: {:?}", t0.elapsed());
    hanger.join().unwrap();
}

#[test]
fn killed_shard_sheds_within_timeout_and_survivor_keeps_serving() {
    let g = wide_graph(10, 30, 8, 21);
    let plan = ExecPlan::new(&g);
    let cuts = [0..5, 5..8];
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);
    let metrics = Arc::new(Metrics::new());
    let remote =
        remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), Arc::clone(&metrics))
            .unwrap();
    let mut rng = Rng::new(5150);
    let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(10, 1.0)).collect();
    let want = shard_engine(&plan, &(0..8), ExecMode::Float).execute_batch(&xs);
    assert_eq!(remote.execute_batch(&xs), want, "healthy gather matches local");

    workers[0].stop(); // port provably closed once stop() returns
    let t0 = Instant::now();
    let mut ys = Vec::new();
    let err = remote.try_execute_batch_into(&xs, &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed shed: {err}");
    let o = fast_opts();
    let per_try = o.connect_timeout + o.read_timeout + o.write_timeout + o.backoff * 256;
    let bound = per_try * (o.retries + 1) + Duration::from_secs(2);
    assert!(t0.elapsed() < bound, "shed within timeouts: {:?} > {bound:?}", t0.elapsed());
    assert!(metrics.counter("shard.0.dead") >= 1, "dead shard counted");
    assert_eq!(metrics.counter("shard.1.dead"), 0, "survivor not counted dead");

    // dead cooldown: the next batch sheds near-instantly, no re-dial
    let t1 = Instant::now();
    let err = remote.try_execute_batch_into(&xs, &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }));
    assert!(t1.elapsed() < o.connect_timeout, "cooldown fast-fail: {:?}", t1.elapsed());
    assert!(metrics.counter("shard.0.dead") >= 2);

    // the surviving worker still answers its own columns bit-exact
    let survivor = RemoteExecutor::connect(&addrs[1], fast_opts()).unwrap();
    let got = survivor.execute_batch(&xs);
    for (row, full) in got.iter().zip(&want) {
        assert_eq!(row.as_slice(), &full[5..8], "survivor's slice matches");
    }
    drop(workers);
}

/// A worker killed and restarted on the same address is rediscovered
/// by the half-open probe once its cooldown lapses: the *same* gather
/// serves again, bit-identical, with `shard.0.recovered` counted — no
/// client rebuild, no server restart.
#[test]
fn killed_worker_recovers_on_restart_without_rebuilding_the_gather() {
    let g = wide_graph(10, 30, 8, 47);
    let plan = ExecPlan::new(&g);
    let cuts = [0..5, 5..8];
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);
    let metrics = Arc::new(Metrics::new());
    let remote =
        remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), Arc::clone(&metrics))
            .unwrap();
    let mut rng = Rng::new(4242);
    let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(10, 1.0)).collect();
    let want = shard_engine(&plan, &(0..8), ExecMode::Float).execute_batch(&xs);
    assert_eq!(remote.execute_batch(&xs), want, "healthy gather matches local");

    workers[0].stop();
    let mut ys = Vec::new();
    assert!(remote.try_execute_batch_into(&xs, &mut ys).is_err(), "dead shard sheds");
    assert!(metrics.counter("shard.0.dead") >= 1);

    // restart a fresh worker on the *same* address (SO_REUSEADDR lets
    // the rebind beat TIME_WAIT; retry briefly in case the old accept
    // thread is still winding down)
    let deadline = Instant::now() + Duration::from_secs(5);
    let _restarted = loop {
        let engine = shard_engine(&plan, &cuts[0], ExecMode::Float);
        match ShardWorker::spawn(engine, cuts[0].clone(), ExecMode::Float, &addrs[0]) {
            Ok(w) => break w,
            Err(e) => {
                assert!(Instant::now() < deadline, "rebind {}: {e}", addrs[0]);
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    // the client must rediscover the worker through the probe alone
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match remote.try_execute_batch_into(&xs, &mut ys) {
            Ok(()) => break,
            Err(_) if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(20)),
            Err(e) => panic!("restarted worker never rediscovered: {e}"),
        }
    }
    assert_eq!(ys, want, "post-recovery gather is bit-identical");
    assert!(metrics.counter("shard.0.recovered") >= 1, "recovery counted");
    assert_eq!(metrics.counter("shard.1.recovered"), 0, "survivor never went through a probe");
    drop(workers);
}

/// The half-open probe is a single cheap attempt: after the cooldown
/// lapses against a still-dead worker, the call must *not* rerun the
/// full retry+backoff ladder on the serving thread, and its failure
/// re-arms the cooldown immediately.
#[test]
fn half_open_probe_skips_retry_ladder() {
    let g = wide_graph(6, 18, 4, 13);
    let plan = ExecPlan::new(&g);
    let (mut workers, addrs) = spawn_workers(&plan, &[0..4], ExecMode::Float);
    // a deliberately expensive ladder: 4 retries with 120 ms exponential
    // backoff sleeps at least 120+240+480+960 = 1800 ms per full run
    let opts = RemoteOptions {
        connect_timeout: Duration::from_millis(300),
        read_timeout: Duration::from_millis(600),
        write_timeout: Duration::from_millis(600),
        retries: 4,
        backoff: Duration::from_millis(120),
        cooldown: Duration::from_millis(100),
        ..RemoteOptions::default()
    };
    let client = RemoteExecutor::connect(&addrs[0], opts).unwrap();
    let xs = vec![vec![0.5f32; 6]];
    let mut ys = Vec::new();
    client.try_execute_batch_into(&xs, &mut ys).unwrap();
    workers[0].stop();

    let t0 = Instant::now();
    let err = client.try_execute_batch_into(&xs, &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed: {err}");
    assert!(t0.elapsed() >= Duration::from_millis(1000), "full ladder ran: {:?}", t0.elapsed());

    // during the cooldown: instant shed, no dial
    let t1 = Instant::now();
    assert!(client.try_execute_batch_into(&xs, &mut ys).is_err());
    assert!(t1.elapsed() < Duration::from_millis(100), "cooldown fast-fail: {:?}", t1.elapsed());

    std::thread::sleep(Duration::from_millis(150)); // let the cooldown lapse

    // the probe: one attempt against a closed loopback port refuses
    // near-instantly — far under even a single ladder rung's backoff
    let t2 = Instant::now();
    assert!(client.try_execute_batch_into(&xs, &mut ys).is_err());
    assert!(t2.elapsed() < Duration::from_millis(600), "probe is one attempt: {:?}", t2.elapsed());

    // the failed probe re-armed the cooldown: instant shed again
    let t3 = Instant::now();
    assert!(client.try_execute_batch_into(&xs, &mut ys).is_err());
    assert!(t3.elapsed() < Duration::from_millis(100), "probe re-arms: {:?}", t3.elapsed());
    drop(workers);
}

/// Two replicas of one output range: killing one keeps the gather
/// serving bit-identical answers with zero sheds — the failure is
/// absorbed client-side (`shard.0.failover`), never surfaced.
#[test]
fn replica_failover_keeps_serving_with_zero_sheds() {
    let g = wide_graph(9, 28, 7, 17);
    let plan = ExecPlan::new(&g);
    let cuts = [0..4, 0..4, 4..7]; // two replicas of the first range
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);
    let metrics = Arc::new(Metrics::new());
    let remote =
        remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), Arc::clone(&metrics))
            .unwrap();
    assert_eq!(remote.num_shards(), 2, "equal-range workers group into one replicated shard");
    let labels: Vec<String> = remote.health_report().into_iter().map(|(l, _)| l).collect();
    assert_eq!(labels, ["shard.0.replica.0", "shard.0.replica.1", "shard.1"]);

    let mut rng = Rng::new(0xFA11);
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(9, 1.0)).collect();
    let want = shard_engine(&plan, &(0..7), ExecMode::Float).execute_batch(&xs);
    assert_eq!(remote.execute_batch(&xs), want, "healthy replicated gather matches local");

    workers[0].stop(); // kill the primary replica of shard 0
    let mut ys = Vec::new();
    for k in 0..40 {
        remote
            .try_execute_batch_into(&xs, &mut ys)
            .unwrap_or_else(|e| panic!("request {k} shed: {e}"));
        assert_eq!(ys, want, "request {k} bit-identical through the surviving replica");
    }
    assert!(metrics.counter("shard.0.failover") >= 1, "failover counted");
    assert_eq!(metrics.counter("shard.0.dead"), 0, "no shed while a replica survives");
    assert_eq!(metrics.counter("shard.1.dead"), 0);
    drop(workers);
}

/// A wire `Drain` frame is acked with a draining `PingOk`, flips the
/// worker's status, and new batches get the typed `ERR_DRAINING`
/// refusal (surfaced as `Unavailable` so clients fail over, not fail).
#[test]
fn drain_refuses_new_batches_with_a_typed_error_and_reports_status() {
    let g = wide_graph(5, 14, 3, 29);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..3], ExecMode::Float);

    let mut s = TcpStream::connect(&addrs[0]).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(3))).unwrap();
    protocol::write_frame(&mut s, protocol::Kind::Drain, protocol::Lanes::None, 5, &[]).unwrap();
    let ack = protocol::read_frame(&mut s, protocol::MAX_FRAME).unwrap();
    assert_eq!(ack.kind, protocol::Kind::PingOk, "drain is acked");
    assert_eq!(ack.req_id, 5);
    assert!(protocol::decode_worker_status(&ack.payload).unwrap(), "ack reports draining");
    assert!(workers[0].is_draining());
    assert!(workers[0].drained(), "nothing in flight");

    // the listener stays up: the handshake still answers, pings report
    // draining, and a fresh batch is refused with the typed code
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    assert!(client.ping().unwrap(), "ping sees the draining status");
    let mut ys = Vec::new();
    let err = client.try_execute_batch_into(&[vec![0.0f32; 5]], &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed refusal: {err}");
    assert!(err.to_string().contains("draining"), "{err}");
    drop(workers);
}

/// `health_report` tracks the worker lifecycle: ready → draining (after
/// a drain) → dead (once a refused batch arms the cooldown).
#[test]
fn health_report_tracks_ready_draining_dead() {
    let g = wide_graph(4, 10, 2, 31);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..2], ExecMode::Float);
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    assert_eq!(client.health_report(), vec![(String::new(), ExecHealth::Ready)]);

    workers[0].drain();
    assert_eq!(client.health(), ExecHealth::Draining);

    // a refused batch arms the cooldown: Dead until the window lapses
    let mut ys = Vec::new();
    assert!(client.try_execute_batch_into(&[vec![0.0f32; 4]], &mut ys).is_err());
    assert_eq!(client.health(), ExecHealth::Dead);
    drop(workers);
}

/// A worker answering `ExecOk` with the reserved `i32` lane tag is a
/// typed, non-retried client error — never a panic, never a hang.
#[test]
fn i32_reply_lanes_are_a_typed_client_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let hello = protocol::read_frame(&mut s, protocol::MAX_FRAME).unwrap();
        assert_eq!(hello.kind, protocol::Kind::Hello);
        let info = protocol::ShardInfo {
            num_inputs: 3,
            num_outputs: 2,
            range_start: 0,
            range_end: 2,
            mode: 1,
        };
        let payload = protocol::encode_shard_info(&info);
        let (k, l) = (protocol::Kind::HelloOk, protocol::Lanes::None);
        protocol::write_frame(&mut s, k, l, hello.req_id, &payload).unwrap();
        let exec = protocol::read_frame(&mut s, protocol::MAX_FRAME).unwrap();
        assert_eq!(exec.kind, protocol::Kind::Exec);
        let rows = protocol::encode_rows_i32(&[vec![1, 2]]).unwrap();
        let (k, l) = (protocol::Kind::ExecOk, protocol::Lanes::I32);
        protocol::write_frame(&mut s, k, l, exec.req_id, &rows).unwrap();
    });
    let client = RemoteExecutor::connect(&addr, fast_opts()).unwrap();
    let mut ys = Vec::new();
    let err = client.try_execute_batch_into(&[vec![1.0, 2.0, 3.0]], &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Failed { .. }), "fatal, not retried: {err}");
    assert!(err.to_string().contains("unsupported lane dtype"), "{err}");
    server.join().unwrap();
}

/// A peer that trickles a partial header and stalls occupies only its
/// own connection: concurrent real clients are served promptly.
#[test]
fn slow_loris_peer_does_not_stall_other_clients() {
    let g = wide_graph(5, 15, 4, 9);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..4], ExecMode::Float);
    let mut loris = TcpStream::connect(&addrs[0]).unwrap();
    loris.write_all(&protocol::MAGIC.to_le_bytes()[..2]).unwrap(); // 2 of 20 header bytes

    let t0 = Instant::now();
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    let xs = vec![vec![1.0, -2.0, 0.5, 3.0, 0.0]];
    let want = shard_engine(&plan, &(0..4), ExecMode::Float).execute_batch(&xs);
    assert_eq!(client.execute_batch(&xs), want);
    assert!(t0.elapsed() < Duration::from_secs(5), "loris must not stall others");
    drop(loris);
    drop(workers);
}

#[test]
fn server_sheds_remote_model_when_worker_dies_and_local_model_survives() {
    let g = wide_graph(10, 30, 8, 33);
    let plan = ExecPlan::new(&g);
    let cuts = [0..3, 3..8];
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);

    let registry = Arc::new(ModelRegistry::new());
    let shard_metrics = Arc::new(Metrics::new());
    registry
        .register_remote_sharded(
            "far",
            &addrs,
            fast_opts(),
            ExecConfig::serial(),
            Arc::clone(&shard_metrics),
            8,
        )
        .unwrap();
    let local_g = wide_graph(4, 10, 2, 44);
    registry.register_graph("near", &local_g, ExecConfig::serial(), 8);
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig { max_batch: 4, batch_timeout_us: 200, ..Default::default() },
    );

    let mut rng = Rng::new(77);
    let x = rng.normal_vec(10, 1.0);
    let want = shard_engine(&plan, &(0..8), ExecMode::Float).execute_one(&x);
    assert_eq!(server.infer_model("far", x.clone()).unwrap(), want, "remote model serves");
    let lx = rng.normal_vec(4, 1.0);
    let lwant = lccnn::exec::NaiveExecutor::new(local_g.clone()).execute_one(&lx);
    assert_eq!(server.infer_model("near", lx.clone()).unwrap(), lwant);

    workers[1].stop();
    let err = server.infer_model("far", x.clone()).unwrap_err();
    assert!(err.contains("shed"), "dead shard must surface as a shed, got: {err}");
    assert!(server.metrics().counter("model.far.shed") >= 1, "shed counted per model");
    assert_eq!(server.metrics().counter("model.far.errors"), 0, "shed is not a backend error");
    assert!(shard_metrics.counter("shard.1.dead") >= 1, "dead shard indexed correctly");

    // the local model on the same server is unaffected
    assert_eq!(server.infer_model("near", lx).unwrap(), lwant, "local model keeps serving");

    // the metrics render publishes per-shard health gauges for the
    // remote entry and a plain always-ready gauge for the local one
    let text = server.metrics_text();
    assert!(text.contains("model.far.health.shard.0"), "{text}");
    assert!(text.contains("model.far.health.shard.1"), "{text}");
    assert!(text.contains("model.near.health = 1"), "{text}");
    server.shutdown();
    drop(workers);
}
