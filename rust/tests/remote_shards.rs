//! Integration: remote shard serving (`exec::remote`) over loopback
//! TCP — in-process `ShardWorker`s on ephemeral ports, no fixtures.
//!
//! * Equivalence: a remote gather is **bit-identical** to the local
//!   `ShardedExecutor` over the same cuts and to the full engine, for
//!   1/2/3 shards × uneven ranges × `float|fixed`.
//! * Robustness: garbage, wrong-version and oversized-length frames
//!   get typed error frames (worker side) or typed connect errors
//!   (client side) — never a panic or a hang.
//! * Failover: a killed shard sheds within the configured timeouts
//!   with `ExecError::Unavailable` and a `shard.<i>.dead` count;
//!   survivors keep serving; a slow-loris peer stalls only itself.
//! * Serving: `ModelRegistry::register_remote_sharded` entries shed
//!   (`ServeError::Shed` + `model.<name>.shed`) when a worker dies,
//!   while local models on the same server keep answering.

use lccnn::config::{ExecConfig, ExecMode, ServeConfig};
use lccnn::exec::remote::protocol;
use lccnn::exec::{
    remote_sharded_executor, BatchEngine, ExecError, ExecPlan, Executor, FixedEngine,
    RemoteExecutor, RemoteOptions, ShardWorker, ShardedExecutor,
};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::metrics::Metrics;
use lccnn::serve::{ModelRegistry, Server};
use lccnn::util::Rng;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn wide_graph(inputs: usize, nodes: usize, outputs: usize, seed: u64) -> AdderGraph {
    let mut rng = Rng::new(seed);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..outputs)
        .map(|_| {
            if rng.f32() < 0.1 {
                OutputSpec::Zero
            } else {
                OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
            }
        })
        .collect();
    g.set_outputs(outs);
    g
}

/// Serial engine over one output-column cut of `plan`, float or fixed.
fn shard_engine(plan: &ExecPlan, range: &Range<usize>, mode: ExecMode) -> Arc<dyn Executor> {
    let sub = plan.extract_output_range(range.start, range.end);
    let cfg = ExecConfig { exec_mode: mode, ..ExecConfig::serial() };
    match mode {
        ExecMode::Float => Arc::new(BatchEngine::from_plan(sub, cfg)),
        ExecMode::Fixed => Arc::new(FixedEngine::from_plan(&sub, cfg).expect("±2^k plans lower")),
    }
}

/// One worker per cut, each on an ephemeral loopback port.
fn spawn_workers(
    plan: &ExecPlan,
    cuts: &[Range<usize>],
    mode: ExecMode,
) -> (Vec<ShardWorker>, Vec<String>) {
    let workers: Vec<ShardWorker> = cuts
        .iter()
        .map(|r| {
            ShardWorker::spawn(shard_engine(plan, r, mode), r.clone(), mode, "127.0.0.1:0")
                .expect("spawn shard worker")
        })
        .collect();
    let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
    (workers, addrs)
}

/// Short bounded timeouts so failover tests finish in milliseconds,
/// not the production defaults.
fn fast_opts() -> RemoteOptions {
    RemoteOptions {
        connect_timeout: Duration::from_millis(300),
        read_timeout: Duration::from_millis(600),
        write_timeout: Duration::from_millis(600),
        retries: 1,
        backoff: Duration::from_millis(10),
        cooldown: Duration::from_millis(150),
        ..RemoteOptions::default()
    }
}

#[test]
fn remote_gather_bit_identical_to_local_across_shards_and_modes() {
    let g = wide_graph(12, 40, 9, 7);
    let plan = ExecPlan::new(&g);
    let oracle = lccnn::exec::NaiveExecutor::new(g.clone());
    let mut rng = Rng::new(0x2E307E);
    let xs: Vec<Vec<f32>> = (0..13).map(|_| rng.normal_vec(12, 1.0)).collect();
    let cuts: [&[Range<usize>]; 3] = [&[0..9], &[0..2, 2..9], &[0..4, 4..5, 5..9]];
    for mode in [ExecMode::Float, ExecMode::Fixed] {
        let full = shard_engine(&plan, &(0..9), mode);
        let want = full.execute_batch(&xs);
        if mode == ExecMode::Float {
            assert_eq!(want, oracle.execute_batch(&xs), "float engine is the oracle bit-exact");
        }
        for cut in cuts {
            // the local reference: the same cuts gathered in-process
            let parts: Vec<(Range<usize>, Arc<dyn Executor>)> =
                cut.iter().map(|r| (r.clone(), shard_engine(&plan, r, mode))).collect();
            let local = ShardedExecutor::from_executors(parts, ExecConfig::serial()).unwrap();
            assert_eq!(local.execute_batch(&xs), want, "{mode:?} local gather over {cut:?}");

            let (workers, addrs) = spawn_workers(&plan, cut, mode);
            let metrics = Arc::new(Metrics::new());
            let remote =
                remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), metrics)
                    .expect("connect all shards");
            assert_eq!(remote.num_shards(), cut.len());
            assert_eq!(remote.num_inputs(), 12);
            assert_eq!(remote.num_outputs(), 9);
            let got = remote.execute_batch(&xs);
            assert_eq!(got, want, "{mode:?} remote gather over {cut:?} must be bit-identical");
            // empty batch round-trips too
            assert_eq!(remote.execute_batch(&[]), Vec::<Vec<f32>>::new());
            drop(workers);
        }
    }
}

#[test]
fn remote_handshake_reports_the_shard_range() {
    let g = wide_graph(6, 20, 5, 11);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[1..4], ExecMode::Float);
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    assert_eq!(client.range(), 1..4);
    assert_eq!(client.num_inputs(), 6);
    assert_eq!(client.num_outputs(), 3);
    assert_eq!(client.name(), "remote-shard");
    // a gather whose single shard does not start at output 0 is rejected
    let metrics = Arc::new(Metrics::new());
    let err = remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), metrics);
    assert!(err.is_err(), "partial-coverage gather must be rejected");
    drop(workers);
}

/// Worker-side robustness: garbage, wrong-version and oversized-length
/// frames each get a typed `Err` frame (or a clean close) and never
/// take the worker down — a fresh client still serves afterwards.
#[test]
fn worker_answers_garbage_with_typed_errors_and_survives() {
    let g = wide_graph(4, 12, 3, 3);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..3], ExecMode::Float);

    let mut bad_version = Vec::new();
    bad_version.extend_from_slice(&protocol::MAGIC.to_le_bytes());
    bad_version.extend_from_slice(&9u16.to_le_bytes());
    bad_version.extend_from_slice(&[3, 1]);
    bad_version.extend_from_slice(&7u64.to_le_bytes());
    bad_version.extend_from_slice(&0u32.to_le_bytes());

    let mut oversized = Vec::new();
    oversized.extend_from_slice(&protocol::MAGIC.to_le_bytes());
    oversized.extend_from_slice(&protocol::VERSION.to_le_bytes());
    oversized.extend_from_slice(&[3, 1]);
    oversized.extend_from_slice(&7u64.to_le_bytes());
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());

    let attacks: [(&str, Vec<u8>); 3] = [
        ("random bytes", vec![0xAB; 64]),
        ("wrong version", bad_version),
        ("oversized length prefix", oversized),
    ];
    for (name, bytes) in &attacks {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(3))).unwrap();
        s.write_all(bytes).unwrap();
        match protocol::read_frame(&mut s, protocol::MAX_FRAME) {
            Ok(frame) => {
                assert_eq!(frame.kind, protocol::Kind::Err, "{name}: typed error frame");
                let (code, _msg) = protocol::decode_error(&frame.payload).unwrap();
                assert_eq!(code, protocol::ERR_PROTOCOL, "{name}");
            }
            // a close without a reply is acceptable; a hang is not
            Err(protocol::ProtocolError::Truncated | protocol::ProtocolError::Io(_)) => {}
            Err(e) => panic!("{name}: unexpected client-side failure {e}"),
        }
    }
    // half a header then close: the worker treats it as a clean EOF
    let mut s = TcpStream::connect(&addrs[0]).unwrap();
    s.write_all(&protocol::MAGIC.to_le_bytes()).unwrap();
    drop(s);

    // the worker survived every attack and still serves real clients
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    let xs = vec![vec![1.0, 2.0, 3.0, 4.0]];
    let want = shard_engine(&plan, &(0..3), ExecMode::Float).execute_batch(&xs);
    assert_eq!(client.execute_batch(&xs), want);
    drop(workers);
}

/// Client-side robustness: a server speaking garbage (or nothing) at
/// the handshake yields a typed, bounded connect error — never a hang.
#[test]
fn client_rejects_garbage_and_silent_servers_with_bounded_typed_errors() {
    // garbage greeter: accepts and answers the handshake with junk
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let greeter = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let _ = s.write_all(&[0xEE; 40]);
            std::thread::sleep(Duration::from_millis(200));
        }
    });
    let t0 = Instant::now();
    let err = RemoteExecutor::connect(&addr, fast_opts()).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed: {err}");
    assert!(t0.elapsed() < Duration::from_secs(5), "bounded: {:?}", t0.elapsed());
    greeter.join().unwrap();

    // accept-then-hang: the handshake read must hit read_timeout
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let hanger = std::thread::spawn(move || {
        if let Ok((s, _)) = listener.accept() {
            std::thread::sleep(Duration::from_millis(1500));
            drop(s);
        }
    });
    let t0 = Instant::now();
    let err = RemoteExecutor::connect(&addr, fast_opts()).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed: {err}");
    let bound = fast_opts().connect_timeout + fast_opts().read_timeout + Duration::from_secs(2);
    assert!(t0.elapsed() < bound, "hang-bounded: {:?}", t0.elapsed());
    hanger.join().unwrap();
}

#[test]
fn killed_shard_sheds_within_timeout_and_survivor_keeps_serving() {
    let g = wide_graph(10, 30, 8, 21);
    let plan = ExecPlan::new(&g);
    let cuts = [0..5, 5..8];
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);
    let metrics = Arc::new(Metrics::new());
    let remote =
        remote_sharded_executor(&addrs, fast_opts(), ExecConfig::serial(), Arc::clone(&metrics))
            .unwrap();
    let mut rng = Rng::new(5150);
    let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(10, 1.0)).collect();
    let want = shard_engine(&plan, &(0..8), ExecMode::Float).execute_batch(&xs);
    assert_eq!(remote.execute_batch(&xs), want, "healthy gather matches local");

    workers[0].stop(); // port provably closed once stop() returns
    let t0 = Instant::now();
    let mut ys = Vec::new();
    let err = remote.try_execute_batch_into(&xs, &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }), "typed shed: {err}");
    let o = fast_opts();
    let per_try = o.connect_timeout + o.read_timeout + o.write_timeout + o.backoff * 256;
    let bound = per_try * (o.retries + 1) + Duration::from_secs(2);
    assert!(t0.elapsed() < bound, "shed within timeouts: {:?} > {bound:?}", t0.elapsed());
    assert!(metrics.counter("shard.0.dead") >= 1, "dead shard counted");
    assert_eq!(metrics.counter("shard.1.dead"), 0, "survivor not counted dead");

    // dead cooldown: the next batch sheds near-instantly, no re-dial
    let t1 = Instant::now();
    let err = remote.try_execute_batch_into(&xs, &mut ys).unwrap_err();
    assert!(matches!(err, ExecError::Unavailable { .. }));
    assert!(t1.elapsed() < o.connect_timeout, "cooldown fast-fail: {:?}", t1.elapsed());
    assert!(metrics.counter("shard.0.dead") >= 2);

    // the surviving worker still answers its own columns bit-exact
    let survivor = RemoteExecutor::connect(&addrs[1], fast_opts()).unwrap();
    let got = survivor.execute_batch(&xs);
    for (row, full) in got.iter().zip(&want) {
        assert_eq!(row.as_slice(), &full[5..8], "survivor's slice matches");
    }
    drop(workers);
}

/// A peer that trickles a partial header and stalls occupies only its
/// own connection: concurrent real clients are served promptly.
#[test]
fn slow_loris_peer_does_not_stall_other_clients() {
    let g = wide_graph(5, 15, 4, 9);
    let plan = ExecPlan::new(&g);
    let (workers, addrs) = spawn_workers(&plan, &[0..4], ExecMode::Float);
    let mut loris = TcpStream::connect(&addrs[0]).unwrap();
    loris.write_all(&protocol::MAGIC.to_le_bytes()[..2]).unwrap(); // 2 of 20 header bytes

    let t0 = Instant::now();
    let client = RemoteExecutor::connect(&addrs[0], fast_opts()).unwrap();
    let xs = vec![vec![1.0, -2.0, 0.5, 3.0, 0.0]];
    let want = shard_engine(&plan, &(0..4), ExecMode::Float).execute_batch(&xs);
    assert_eq!(client.execute_batch(&xs), want);
    assert!(t0.elapsed() < Duration::from_secs(5), "loris must not stall others");
    drop(loris);
    drop(workers);
}

#[test]
fn server_sheds_remote_model_when_worker_dies_and_local_model_survives() {
    let g = wide_graph(10, 30, 8, 33);
    let plan = ExecPlan::new(&g);
    let cuts = [0..3, 3..8];
    let (mut workers, addrs) = spawn_workers(&plan, &cuts, ExecMode::Float);

    let registry = Arc::new(ModelRegistry::new());
    let shard_metrics = Arc::new(Metrics::new());
    registry
        .register_remote_sharded(
            "far",
            &addrs,
            fast_opts(),
            ExecConfig::serial(),
            Arc::clone(&shard_metrics),
            8,
        )
        .unwrap();
    let local_g = wide_graph(4, 10, 2, 44);
    registry.register_graph("near", &local_g, ExecConfig::serial(), 8);
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig { max_batch: 4, batch_timeout_us: 200, ..Default::default() },
    );

    let mut rng = Rng::new(77);
    let x = rng.normal_vec(10, 1.0);
    let want = shard_engine(&plan, &(0..8), ExecMode::Float).execute_one(&x);
    assert_eq!(server.infer_model("far", x.clone()).unwrap(), want, "remote model serves");
    let lx = rng.normal_vec(4, 1.0);
    let lwant = lccnn::exec::NaiveExecutor::new(local_g.clone()).execute_one(&lx);
    assert_eq!(server.infer_model("near", lx.clone()).unwrap(), lwant);

    workers[1].stop();
    let err = server.infer_model("far", x.clone()).unwrap_err();
    assert!(err.contains("shed"), "dead shard must surface as a shed, got: {err}");
    assert!(server.metrics().counter("model.far.shed") >= 1, "shed counted per model");
    assert_eq!(server.metrics().counter("model.far.errors"), 0, "shed is not a backend error");
    assert!(shard_metrics.counter("shard.1.dead") >= 1, "dead shard indexed correctly");

    // the local model on the same server is unaffected
    assert_eq!(server.infer_model("near", lx).unwrap(), lwant, "local model keeps serving");
    server.shutdown();
    drop(workers);
}
