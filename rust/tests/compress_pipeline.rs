//! Integration: the `compress::Pipeline` API.
//!
//! * Recipe round trips: TOML serialization and `LCCNN_COMPRESS_*` env
//!   layering reproduce the same recipe.
//! * Equivalence: a recipe-driven run is **bit-identical** to the legacy
//!   hand-wired prune → cluster → share → `with_lcc_exec` path on the
//!   same 3-shape matrix `pipeline_integration` exercises.
//! * Determinism: the same recipe re-run yields an equal
//!   `CompressionReport` and bit-identical outputs — including through a
//!   serialize → reload cycle and a registry artifact load.

use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::compress::{demo_weights, Pipeline, PruneSpec, QuantSpec, Recipe, ShareSpec, StageSpec};
use lccnn::config::{ExecConfig, LccAlgoConfig, ShardMode, ShardSpec};
use lccnn::exec::Executor;
use lccnn::lcc::LccConfig;
use lccnn::nn::npy::NpyArray;
use lccnn::nn::ParamStore;
use lccnn::prune::compact_columns;
use lccnn::serve::ModelRegistry;
use lccnn::share::SharedLayer;
use lccnn::util::Rng;

fn serial_default_recipe() -> Recipe {
    Recipe { exec: ExecConfig::serial(), ..Recipe::default() }
}

/// The 3-shape matrix from `pipeline_integration`, recipe-driven vs the
/// legacy hand-wired stage composition: outputs must be bit-identical at
/// every stage depth, and the addition accounting must agree.
#[test]
fn recipe_bit_identical_to_legacy_stage_wiring_on_shape_matrix() {
    for (i, (rows, groups, per)) in
        [(16usize, 4usize, 4usize), (32, 6, 3), (24, 5, 5)].into_iter().enumerate()
    {
        let w = demo_weights(rows, groups, per, 60 + i as u64);

        // legacy: hand-wired prune -> cluster -> share -> lcc
        let compact = compact_columns(&w, 1e-6);
        let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
        let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
        let legacy = shared.with_lcc_exec(&LccConfig::fs(), ExecConfig::serial());

        // recipe-driven
        let model = Pipeline::from_recipe(&serial_default_recipe()).unwrap().run(&w).unwrap();
        assert_eq!(model.kept(), &compact.kept[..], "shape {i}: kept maps agree");
        let slcc = model.lcc().expect("lcc stage ran");
        assert_eq!(slcc.additions(), legacy.additions(), "shape {i}: addition accounting");
        assert_eq!(
            model.state().shared().unwrap().num_clusters(),
            shared.num_clusters(),
            "shape {i}: same clustering"
        );

        // bit-identical on a batch, through both the Layer1 path and the
        // full-input-dim executor
        let mut rng = Rng::new(100 + i as u64);
        let xs: Vec<Vec<f32>> = (0..11).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let xs_kept: Vec<Vec<f32>> =
            xs.iter().map(|x| compact.kept.iter().map(|&j| x[j]).collect()).collect();
        assert_eq!(slcc.apply_batch(&xs_kept), legacy.apply_batch(&xs_kept), "shape {i}");
        let exec = model.executor();
        assert_eq!(exec.num_inputs(), w.cols(), "served input dim is pre-prune");
        for (x, xk) in xs.iter().zip(&xs_kept) {
            assert_eq!(exec.execute_one(x), legacy.apply(xk), "shape {i}: executor path");
        }
    }
}

/// Same recipe, run twice (and once through a TOML round trip): equal
/// reports, bit-identical engines.
#[test]
fn deterministic_rerun_and_toml_round_trip() {
    let w = demo_weights(24, 4, 4, 7);
    let recipe = serial_default_recipe();
    let a = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
    let b = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
    assert_eq!(a.report(), b.report(), "same recipe must reproduce the same report");

    let reparsed = Recipe::from_toml_str(&recipe.to_toml_string()).unwrap();
    assert_eq!(reparsed, recipe);
    let c = Pipeline::from_recipe(&reparsed).unwrap().run(&w).unwrap();
    assert_eq!(a.report(), c.report(), "TOML round trip must not perturb the run");

    let mut rng = Rng::new(8);
    let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
    let ya = a.executor().execute_batch(&xs);
    assert_eq!(ya, b.executor().execute_batch(&xs));
    assert_eq!(ya, c.executor().execute_batch(&xs));
}

/// `LCCNN_COMPRESS_*` env layering: stage reshaping and per-stage knobs.
/// (One test mutates all compress env vars so parallel tests never race
/// on them; no other suite reads `LCCNN_COMPRESS_*`.)
#[test]
fn env_overrides_layer_over_recipe() {
    std::env::set_var("LCCNN_COMPRESS_STAGES", "prune,lcc");
    std::env::set_var("LCCNN_COMPRESS_PRUNE_EPS", "0.001");
    std::env::set_var("LCCNN_COMPRESS_LCC_ALGO", "fp");
    std::env::set_var("LCCNN_COMPRESS_LCC_SLICE_WIDTH", "5");
    std::env::set_var("LCCNN_COMPRESS_LCC_TARGET_REL_ERR", "0.03");
    let r = Recipe::from_env_over(Recipe::default());
    std::env::remove_var("LCCNN_COMPRESS_STAGES");
    std::env::remove_var("LCCNN_COMPRESS_PRUNE_EPS");
    std::env::remove_var("LCCNN_COMPRESS_LCC_ALGO");
    std::env::remove_var("LCCNN_COMPRESS_LCC_SLICE_WIDTH");
    std::env::remove_var("LCCNN_COMPRESS_LCC_TARGET_REL_ERR");

    let kinds: Vec<&str> = r.stages.iter().map(StageSpec::kind).collect();
    assert_eq!(kinds, vec!["prune", "lcc"], "share dropped by LCCNN_COMPRESS_STAGES");
    match &r.stages[0] {
        StageSpec::Prune(p) => assert!((p.eps - 0.001f32).abs() < 1e-9),
        other => panic!("{other:?}"),
    }
    match &r.stages[1] {
        StageSpec::Lcc(l) => {
            assert_eq!(l.algo, LccAlgoConfig::Fp);
            assert_eq!(l.slice_width, 5);
            assert!((l.target_rel_err - 0.03).abs() < 1e-12);
        }
        other => panic!("{other:?}"),
    }
    // and the layered recipe still round-trips through TOML
    assert_eq!(Recipe::from_toml_str(&r.to_toml_string()).unwrap(), r);
}

/// An artifact directory (weights + recipe.toml) loaded through the
/// registry serves bit-identically to the directly built pipeline.
#[test]
fn registry_artifact_load_matches_direct_pipeline() {
    let w = demo_weights(20, 4, 3, 11);
    let recipe = serial_default_recipe();
    let dir = std::env::temp_dir().join(format!("lccnn-cp-artifact-{}", std::process::id()));
    let mut store = ParamStore::new();
    store.insert("weight", NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()));
    store.save(&dir).unwrap();
    recipe.save(&dir.join("recipe.toml")).unwrap();

    let registry = ModelRegistry::new();
    let entry = registry.load_checkpoint_with_recipe("art", &dir, None, 8).unwrap();
    let direct = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
    let exec = direct.executor();
    assert_eq!(entry.input_dim(), Some(w.cols()));

    let mut rng = Rng::new(12);
    let xs: Vec<Vec<f32>> = (0..7).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
    assert_eq!(entry.eval_batch(&xs).unwrap(), exec.execute_batch(&xs));
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance round-trip for sharded artifacts: a recipe carrying
/// `[compress.shard]` goes TOML -> artifact dir -> registry reload ->
/// served shards, bit-identical to the unsharded serve of the same
/// weights at every step.
#[test]
fn sharded_recipe_round_trips_through_artifact_and_registry() {
    let w = demo_weights(22, 4, 4, 17);
    let plain_recipe = serial_default_recipe();
    let sharded_recipe = Recipe {
        shard: Some(ShardSpec { shards: 3, mode: ShardMode::Parallel }),
        ..plain_recipe.clone()
    };
    // TOML round trip keeps the shard section
    let text = sharded_recipe.to_toml_string();
    let reparsed = Recipe::from_toml_str(&text).unwrap();
    assert_eq!(reparsed, sharded_recipe, "\n{text}");
    assert_eq!(reparsed.shard_spec().unwrap().shards, 3);

    // artifact dir: weights + the sharded recipe.toml
    let dir = std::env::temp_dir().join(format!("lccnn-cp-shard-{}", std::process::id()));
    let mut store = ParamStore::new();
    store.insert("weight", NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()));
    store.save(&dir).unwrap();
    sharded_recipe.save(&dir.join("recipe.toml")).unwrap();

    // registry discovery loads the sharded engine; a second registry
    // load with the plain recipe is the unsharded reference
    let registry = ModelRegistry::new();
    let sharded_entry = registry.load_checkpoint_with_recipe("sharded", &dir, None, 8).unwrap();
    let plain_entry =
        registry.load_checkpoint_with_recipe("plain", &dir, Some(&plain_recipe), 8).unwrap();
    assert_eq!(sharded_entry.input_dim(), Some(w.cols()));

    let direct = Pipeline::from_recipe(&plain_recipe).unwrap().run(&w).unwrap();
    let exec = direct.executor();
    let mut rng = Rng::new(18);
    let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
    let want = exec.execute_batch(&xs);
    assert_eq!(plain_entry.eval_batch(&xs).unwrap(), want, "unsharded reference");
    assert_eq!(
        sharded_entry.eval_batch(&xs).unwrap(),
        want,
        "served shards must be bit-identical to the unsharded engine"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Quantize composes between share and LCC, and the quantized recipe
/// still round-trips + reproduces deterministically.
#[test]
fn quantized_recipe_runs_and_round_trips() {
    let w = demo_weights(16, 3, 4, 13);
    let recipe = Recipe {
        stages: vec![
            StageSpec::Prune(PruneSpec::default()),
            StageSpec::Share(ShareSpec::default()),
            StageSpec::Quantize(QuantSpec { int_bits: 2, frac_bits: 6 }),
            StageSpec::Lcc(Default::default()),
        ],
        exec: ExecConfig::serial(),
        ..Recipe::default()
    };
    assert_eq!(Recipe::from_toml_str(&recipe.to_toml_string()).unwrap(), recipe);
    let p = Pipeline::from_recipe(&recipe).unwrap();
    let a = p.run(&w).unwrap();
    let b = p.run(&w).unwrap();
    assert_eq!(a.report(), b.report());
    let names: Vec<&str> = a.report().stages.iter().map(|s| s.stage.as_str()).collect();
    assert_eq!(names, vec!["prune", "share", "quantize", "lcc"]);
    // quantization distorts; the report must say so before LCC runs
    assert!(a.report().stages[2].rel_err > 0.0);
}
