//! Shared helpers for integration tests. Tests are skipped (not failed)
//! when the AOT artifacts have not been built yet — run `make artifacts`.

// not every test crate uses every helper
#![allow(dead_code)]

use lccnn::runtime::Runtime;
use std::path::PathBuf;

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Checked-in test data (golden vectors and the like) under
/// `rust/tests/common/`.
pub fn test_data_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("common")
        .join(name)
}

pub fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("runtime open"))
}
