//! Shared helpers for integration tests. Tests are skipped (not failed)
//! when the AOT artifacts have not been built yet — run `make artifacts`.

use lccnn::runtime::Runtime;
use std::path::PathBuf;

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("runtime open"))
}
