//! Integration: the `compress::tune` recipe autotuner.
//!
//! * Determinism: the same spec + seed + weights produce an identical
//!   Pareto set and **byte-identical** emitted artifacts
//!   (`recipe-<id>.toml`, `best.toml`, `sweep.json`, `sweep.tsv`).
//! * Reproduction: every emitted frontier recipe round-trips through
//!   `Recipe::from_toml` and re-runs through `Pipeline` to
//!   bit-identical additions / rel-err on the `tune --demo` matrix
//!   (`demo_weights(24, 4, 4, seed)` — the same matrix
//!   `compress --demo 1` compresses as job 0).
//! * `TuneSpec` layering: `LCCNN_TUNE_*` env over TOML, in
//!   `compress_pipeline.rs` style. This file is the sole owner of the
//!   `LCCNN_TUNE_*` variables (one-owner convention: parallel tests
//!   never race on them).
//! * Network sweep smoke over a `demo_network` checkpoint.

use lccnn::compress::{demo_network, demo_weights, tune, Pipeline, Recipe, TuneSpec};
use lccnn::config::{ExecMode, LccAlgoConfig};
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lccnn-tune-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// The demo sweep twice into two directories: identical Pareto sets and
/// byte-identical files — the reproducibility contract `tune` ships.
#[test]
fn demo_sweep_artifacts_are_byte_identical_across_runs() {
    let spec = TuneSpec { budget: 8, seed: 5, ..TuneSpec::default() };
    let w = demo_weights(24, 4, 4, 5);
    let a = tune::sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
    let b = tune::sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
    assert_eq!(a, b, "same spec + seed + weights => identical sweep");
    assert!(!a.frontier().is_empty(), "demo sweep must yield a non-empty frontier");

    let (da, db) = (temp_dir("bytes-a"), temp_dir("bytes-b"));
    a.write(&da).unwrap();
    b.write(&db).unwrap();
    let mut names: Vec<String> = std::fs::read_dir(&da)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    names.sort();
    assert!(names.iter().any(|n| n == "best.toml"), "{names:?}");
    assert!(names.iter().any(|n| n == "sweep.json"), "{names:?}");
    assert_eq!(
        names.iter().filter(|n| n.starts_with("recipe-")).count(),
        8,
        "one recipe per evaluated point: {names:?}"
    );
    for n in &names {
        let (ba, bb) = (std::fs::read(da.join(n)).unwrap(), std::fs::read(db.join(n)).unwrap());
        assert_eq!(ba, bb, "{n} differs between identical runs");
        assert!(!ba.is_empty(), "{n} is empty");
    }
    std::fs::remove_dir_all(&da).ok();
    std::fs::remove_dir_all(&db).ok();
}

/// Acceptance criterion: every emitted recipe — frontier and dominated
/// alike — reloads through `Recipe::from_toml` and reproduces the
/// additions/rel-err the sweep reported, bit-identically, through a
/// fresh `Pipeline` run on the same weights.
#[test]
fn emitted_recipes_reproduce_reported_scores_bit_identically() {
    let spec = TuneSpec { budget: 6, seed: 0, ..TuneSpec::default() };
    let w = demo_weights(24, 4, 4, 0);
    let res = tune::sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
    let dir = temp_dir("repro");
    res.write(&dir).unwrap();
    for p in &res.points {
        let path = dir.join(format!("recipe-{:03}.toml", p.id));
        let recipe = Recipe::from_toml(&path).unwrap();
        assert_eq!(recipe, p.recipe, "emitted TOML round-trips to the evaluated recipe");
        let model = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
        assert_eq!(model.report().final_additions(), p.additions, "point {}", p.id);
        assert_eq!(model.report().final_rel_err(), p.rel_err, "point {}", p.id);
    }
    // best.toml is the frontier's fewest-additions recipe
    let best = Recipe::from_toml(&dir.join("best.toml")).unwrap();
    assert_eq!(best, res.best().unwrap().recipe);
    std::fs::remove_dir_all(&dir).ok();
}

/// The frontier is consistent with the scores: no frontier point is
/// dominated by any evaluated point, and every dominated point is
/// dominated by some frontier point.
#[test]
fn frontier_flags_are_sound() {
    let spec = TuneSpec { seed: 2, ..TuneSpec::default() };
    let w = demo_weights(24, 4, 4, 2);
    let res = tune::sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
    assert_eq!(res.points.len(), res.grid_size, "no budget => the whole grid");
    let dominates = |a: &tune::TunePoint, b: &tune::TunePoint| {
        a.additions <= b.additions
            && a.rel_err <= b.rel_err
            && (a.additions < b.additions || a.rel_err < b.rel_err)
    };
    for p in &res.points {
        let dominated_by_any = res.points.iter().any(|q| dominates(q, p));
        assert_eq!(p.frontier, !dominated_by_any, "point {} ({})", p.id, p.label());
        if !p.frontier {
            assert!(
                res.points.iter().filter(|q| q.frontier).any(|q| dominates(q, p)),
                "dominated point {} must be dominated by a frontier point",
                p.id
            );
        }
    }
}

/// Network sweep smoke: the same axes drive `NetworkPipeline` over a
/// multi-layer demo checkpoint, and the summed accounting behaves.
#[test]
fn network_sweep_smoke() {
    let spec = TuneSpec {
        budget: 4,
        seed: 1,
        lcc_algos: vec![LccAlgoConfig::Fs],
        ..TuneSpec::default()
    };
    let ckpt = demo_network(&[12, 10, 8, 6], 1);
    let res = tune::sweep_network(&spec, &Recipe::default(), &ckpt).unwrap();
    assert_eq!(res.points.len(), 4);
    assert!(res.target.contains("network"), "{}", res.target);
    assert!(!res.frontier().is_empty());
    for p in &res.points {
        assert!(p.additions > 0 && p.baseline_additions > 0 && p.ratio > 0.0, "{}", p.label());
        assert!(p.rel_err.is_finite());
    }
    let again = tune::sweep_network(&spec, &Recipe::default(), &ckpt).unwrap();
    assert_eq!(res, again, "network sweep is deterministic");
}

/// `LCCNN_TUNE_*` env layering over a TOML spec: list axes from comma
/// strings, scalars, and the layered spec still round-trips through
/// TOML. Sole owner of these variables (one-owner convention).
#[test]
fn tune_spec_env_overrides_layer_and_round_trip() {
    let base = TuneSpec::from_toml_str("[tune]\nprune_eps = [0.01]\nlcc_widths = [2]\n").unwrap();
    std::env::set_var("LCCNN_TUNE_PRUNE_EPS", "0.001, 0.1");
    std::env::set_var("LCCNN_TUNE_LCC_ALGOS", "fp");
    std::env::set_var("LCCNN_TUNE_EXEC_MODES", "float, fixed");
    std::env::set_var("LCCNN_TUNE_SHARDS", "1, 2, bogus");
    std::env::set_var("LCCNN_TUNE_BUDGET", "3");
    std::env::set_var("LCCNN_TUNE_MEASURE", "1");
    let spec = TuneSpec::from_env_over(base.clone());
    std::env::remove_var("LCCNN_TUNE_PRUNE_EPS");
    std::env::remove_var("LCCNN_TUNE_LCC_ALGOS");
    std::env::remove_var("LCCNN_TUNE_EXEC_MODES");
    std::env::remove_var("LCCNN_TUNE_SHARDS");
    std::env::remove_var("LCCNN_TUNE_BUDGET");
    std::env::remove_var("LCCNN_TUNE_MEASURE");
    assert_eq!(spec.prune_eps, vec![0.001, 0.1], "env list wins over TOML");
    assert_eq!(spec.lcc_widths, vec![2], "untouched axis keeps the TOML value");
    assert_eq!(spec.lcc_algos, vec![LccAlgoConfig::Fp]);
    assert_eq!(spec.exec_modes, vec![ExecMode::Float, ExecMode::Fixed]);
    assert_eq!(spec.shards, vec![1, 2], "unparsable entry skipped with a warning");
    assert_eq!(spec.budget, 3);
    assert!(spec.measure);
    let back = TuneSpec::from_toml_str(&spec.to_toml_string()).unwrap();
    assert_eq!(back, spec, "layered spec still round-trips");
    // no env set: the base passes through untouched
    assert_eq!(TuneSpec::from_env_over(base.clone()), base);
}
