//! Concurrency stress tests for the exec engine's persistent worker
//! pool: many threads hammering one shared engine stay bit-identical to
//! the oracle, a panicking task poisons only its batch, shutdown joins
//! every worker, and — the acceptance bar — steady-state
//! `execute_batch` spawns zero threads after warmup.

use lccnn::config::{ExecConfig, PoolMode};
use lccnn::exec::{BatchEngine, Executor, NaiveExecutor, WorkerPool};
use lccnn::graph::{AdderGraph, Operand, OutputSpec};
use lccnn::util::Rng;
use std::sync::Arc;

/// Random DAG with scaled/negated operands and a few outputs.
fn random_graph(seed: u64, inputs: usize, nodes: usize) -> AdderGraph {
    let mut rng = Rng::new(seed);
    let mut g = AdderGraph::new(inputs);
    let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
    for _ in 0..nodes {
        let a = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
        let b = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
        refs.push(g.push_add(a, b));
    }
    let outs = (0..4)
        .map(|_| OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false)))
        .collect();
    g.set_outputs(outs);
    g
}

/// Engine config that actually exercises the pool at small batches.
fn pooled_cfg(threads: usize) -> ExecConfig {
    ExecConfig {
        threads,
        chunk: 4,
        parallel_min_batch: 8,
        pool_mode: PoolMode::Persistent,
        pool_spin_us: 0,
        pool_park_ms: 20,
        ..ExecConfig::default()
    }
}

#[test]
fn shared_engine_hammered_from_many_threads_matches_oracle() {
    let g = random_graph(0xC0C0, 6, 60);
    let oracle = NaiveExecutor::new(g.clone());
    let engine = Arc::new(BatchEngine::with_workers(
        &g,
        pooled_cfg(4),
        Arc::new(WorkerPool::new(4, 0, 20)),
    ));
    let shapes: [usize; 6] = [0, 1, 3, 16, 33, 64];
    std::thread::scope(|s| {
        for t in 0..6u64 {
            let engine = Arc::clone(&engine);
            let oracle = &oracle;
            let g = &g;
            s.spawn(move || {
                let mut rng = Rng::new(1000 + t);
                for iter in 0..20 {
                    let b = shapes[(iter + t as usize) % shapes.len()];
                    let xs: Vec<Vec<f32>> =
                        (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
                    let got = engine.execute_batch(&xs);
                    let want = oracle.execute_batch(&xs);
                    assert_eq!(got, want, "thread {t} iter {iter} batch {b}");
                }
            });
        }
    });
}

#[test]
fn steady_state_execute_batch_spawns_zero_threads_after_warmup() {
    let g = random_graph(0x5EED, 5, 40);
    let pool = Arc::new(WorkerPool::new(3, 0, 20));
    let engine = BatchEngine::with_workers(&g, pooled_cfg(3), Arc::clone(&pool));
    let mut rng = Rng::new(7);
    let xs: Vec<Vec<f32>> = (0..48).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
    assert_eq!(pool.stats().threads_spawned, 0, "pool must start lazily");
    let warm = engine.execute_batch(&xs);
    let spawned = pool.stats().threads_spawned;
    assert!(spawned >= 1 && spawned <= 3, "warmup spawns the workers once: {spawned}");
    let tasks_after_warmup = pool.stats().tasks_run;
    assert!(tasks_after_warmup > 0, "parallel batch must dispatch pool tasks");
    for _ in 0..50 {
        assert_eq!(engine.execute_batch(&xs), warm, "steady-state results must not drift");
    }
    let s = pool.stats();
    assert_eq!(s.threads_spawned, spawned, "steady state spawned threads: {s:?}");
    assert!(s.tasks_run > tasks_after_warmup, "work stopped flowing through the pool: {s:?}");
}

#[test]
fn pool_survives_a_panicking_task() {
    let g = random_graph(0xBAD, 3, 12);
    let pool = Arc::new(WorkerPool::new(2, 0, 20));
    let engine = BatchEngine::with_workers(&g, pooled_cfg(2), Arc::clone(&pool));
    let mut rng = Rng::new(9);
    // sample 5 has the wrong arity: the input-length assert fires inside
    // a pooled task (batch 16 ≥ parallel_min_batch 8 → chunk dispatch)
    let mut bad: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
    bad[5] = vec![1.0];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.execute_batch(&bad)
    }));
    assert!(result.is_err(), "wrong arity must fail the batch");
    let after_panic = pool.stats();
    assert!(after_panic.panics >= 1, "panic not recorded: {after_panic:?}");
    // the pool survives: same engine, same pool, good batches still match
    // the oracle and no replacement threads were spawned
    let oracle = NaiveExecutor::new(g.clone());
    let good: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
    for _ in 0..5 {
        assert_eq!(engine.execute_batch(&good), oracle.execute_batch(&good));
    }
    let s = pool.stats();
    assert_eq!(s.threads_spawned, after_panic.threads_spawned, "pool respawned workers: {s:?}");
    assert!(s.tasks_run > after_panic.tasks_run, "pool stopped taking work: {s:?}");
}

#[test]
fn clean_shutdown_joins_all_workers() {
    let g = random_graph(0xD1E, 4, 30);
    let pool = Arc::new(WorkerPool::new(4, 0, 10));
    let engine = BatchEngine::with_workers(&g, pooled_cfg(4), Arc::clone(&pool));
    let mut rng = Rng::new(11);
    let xs: Vec<Vec<f32>> = (0..32).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
    let want = engine.execute_batch(&xs);
    pool.shutdown();
    let s = pool.stats();
    assert!(s.threads_spawned >= 1);
    assert_eq!(s.threads_joined, s.threads_spawned, "leaked worker threads after shutdown: {s:?}");
    // graceful: the engine still answers (tasks run inline on the caller)
    assert_eq!(engine.execute_batch(&xs), want);
    let s2 = pool.stats();
    assert_eq!(s2.threads_spawned, s.threads_spawned, "shutdown pool must not respawn");
    assert!(s2.inline_runs > s.inline_runs, "post-shutdown work should run inline: {s2:?}");
}

#[test]
fn scoped_and_persistent_modes_agree_on_a_shared_engine() {
    let g = random_graph(0xABBA, 7, 80);
    let scoped = BatchEngine::with_config(
        &g,
        ExecConfig { pool_mode: PoolMode::Scoped, ..pooled_cfg(4) },
    );
    let persistent = Arc::new(BatchEngine::with_workers(
        &g,
        pooled_cfg(4),
        Arc::new(WorkerPool::new(4, 0, 20)),
    ));
    let mut rng = Rng::new(21);
    for b in [0usize, 1, 7, 32, 65] {
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
        assert_eq!(
            scoped.execute_batch(&xs),
            persistent.execute_batch(&xs),
            "dispatch paths diverged at batch {b}"
        );
    }
}
