//! Residual-CNN training through the `resnet_train_step_{fk,pk}` /
//! `resnet_eval` artifacts. Parameter order follows
//! [`crate::nn::resnet::param_specs`] (the artifact calling convention),
//! *not* alphabetical checkpoint order.
//!
//! Perf note (EXPERIMENTS.md §Perf): all ~50 state tensors stay in
//! `xla::Literal` form between steps; only the image batch and the two
//! scalars are built per step.

use super::{LossCurve, LrSchedule};
use crate::data::{BatchIter, Dataset};
use crate::nn::checkpoint::ParamStore;
use crate::nn::npy::NpyArray;
use crate::nn::resnet::{param_specs, CHANNELS, IMG};
use crate::runtime::{Executable, HostTensor, Runtime};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Conv prox grouping (paper Sec. III-D): full-kernel or partial-kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvGrouping {
    Fk,
    Pk,
}

pub struct ResnetTrainer {
    step_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    /// params then momenta, in param_specs order (literals)
    state: Vec<xla::Literal>,
    specs: Vec<(String, Vec<usize>)>,
    pub lambda: f32,
    pub steps_taken: usize,
}

fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    HostTensor::F32(dims.to_vec(), data.to_vec()).to_literal()
}

impl ResnetTrainer {
    pub fn new(rt: &Runtime, init: &ParamStore, grouping: ConvGrouping) -> Result<Self> {
        let name = match grouping {
            ConvGrouping::Fk => "resnet_train_step_fk",
            ConvGrouping::Pk => "resnet_train_step_pk",
        };
        let step_exe = rt.get(name)?;
        let eval_exe = rt.get("resnet_eval")?;
        let specs = param_specs();
        let mut state = Vec::with_capacity(specs.len() * 2);
        for (pname, shape) in &specs {
            let arr = init
                .get(pname)
                .unwrap_or_else(|| panic!("init missing param {pname}"));
            assert_eq!(&arr.shape, shape, "shape mismatch for {pname}");
            state.push(lit_f32(shape, &arr.data)?);
        }
        for (_, shape) in &specs {
            let n: usize = shape.iter().product();
            state.push(lit_f32(shape, &vec![0.0; n])?);
        }
        Ok(ResnetTrainer { step_exe, eval_exe, state, specs, lambda: 0.0, steps_taken: 0 })
    }

    pub fn batch_size(&self) -> usize {
        let np = self.specs.len();
        self.step_exe.spec.inputs[2 * np].dims[0]
    }

    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32) -> Result<f64> {
        let b = self.batch_size();
        let img_elems = IMG * IMG * CHANNELS;
        if x.len() != b * img_elems || y.len() != b {
            bail!("bad resnet batch: x {} y {}", x.len(), y.len());
        }
        let x_lit = lit_f32(&[b, IMG, IMG, CHANNELS], x)?;
        let y_lit = HostTensor::I32(vec![b], y.to_vec()).to_literal()?;
        let lr_lit = lit_f32(&[1], &[lr])?;
        let lam_lit = lit_f32(&[1], &[self.lambda])?;
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.extend([&x_lit, &y_lit, &lr_lit, &lam_lit]);
        let mut outs = self.step_exe.run_literals(&inputs)?;
        let loss_lit = outs.pop().expect("loss");
        let loss = loss_lit.to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0] as f64;
        self.state = outs;
        self.steps_taken += 1;
        Ok(loss)
    }

    pub fn train(
        &mut self,
        data: &Dataset,
        steps: usize,
        sched: LrSchedule,
        log_every: usize,
        seed: u64,
    ) -> Result<LossCurve> {
        let mut iter = BatchIter::new(data, self.batch_size(), seed);
        let mut curve = Vec::new();
        for s in 0..steps {
            let (x, y, _) = iter.next_batch();
            let loss = self.step(&x, &y, sched.at(s))?;
            if s % log_every.max(1) == 0 || s + 1 == steps {
                curve.push((s, loss));
            }
        }
        Ok(curve)
    }

    /// Snapshot the parameters as a named store.
    pub fn params_store(&self) -> ParamStore {
        let mut store = ParamStore::new();
        for (i, (name, shape)) in self.specs.iter().enumerate() {
            let data = self.state[i].to_vec::<f32>().expect("param literal");
            store.insert(name, NpyArray::f32(shape.clone(), data));
        }
        store
    }

    /// (mean loss, accuracy) over the largest multiple of the eval batch.
    pub fn evaluate(&self, data: &Dataset) -> Result<(f64, f64)> {
        let np = self.specs.len();
        let b = self.eval_exe.spec.inputs[np].dims[0];
        let batches = data.len() / b;
        if batches == 0 {
            bail!("eval set smaller than eval batch {b}");
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for i in 0..batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let (x, y) = data.gather(&idx);
            let x_lit = lit_f32(&[b, IMG, IMG, CHANNELS], &x)?;
            let y_lit = HostTensor::I32(vec![b], y).to_literal()?;
            let inputs: Vec<&xla::Literal> =
                self.state[..np].iter().chain([&x_lit, &y_lit]).collect();
            let outs = self.eval_exe.run_literals(&inputs)?;
            loss_sum += outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
            correct += outs[1].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
        }
        let n = (batches * b) as f64;
        Ok((loss_sum / n, correct / n))
    }
}
