//! Training orchestrator: drives the AOT train-step artifacts from rust.
//!
//! The rust side owns all state (parameters, momenta, masks, cluster
//! labels); each step sends the state + a batch through the PJRT
//! executable and receives the updated state + loss. The group-lasso
//! proximal step and the weight-sharing gradient averaging (paper eq.
//! 7-9) happen *inside* the artifact — rust only flips `lam`,
//! `colmask`, `cluster_labels` and `share_flag` between pipeline stages.

mod mlp_trainer;
mod resnet_trainer;

pub use mlp_trainer::MlpTrainer;
pub use resnet_trainer::{ConvGrouping, ResnetTrainer};

/// (step, loss) samples recorded during training.
pub type LossCurve = Vec<(usize, f64)>;

/// Exponential step-decay schedule (paper Sec. IV-A: decay every
/// `every` steps by `factor`).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub every: usize,
    pub factor: f32,
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        if self.every == 0 {
            return self.base;
        }
        self.base * self.factor.powi((step / self.every) as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_decays() {
        let s = LrSchedule { base: 1.0, every: 10, factor: 0.5 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(25), 0.25);
    }

    #[test]
    fn schedule_zero_every_is_constant() {
        let s = LrSchedule { base: 0.1, every: 0, factor: 0.5 };
        assert_eq!(s.at(1000), 0.1);
    }
}
