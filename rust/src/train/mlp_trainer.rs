//! MLP training through the `mlp_train_step` / `mlp_eval` artifacts.
//!
//! Perf note (EXPERIMENTS.md §Perf): the 8 state tensors (params +
//! momenta, ~1.9 MB) stay in `xla::Literal` form between steps — only
//! the batch, the scalars, and the rarely-changing mask/label tensors
//! are converted per step.

use super::{LossCurve, LrSchedule};
use crate::data::{BatchIter, Dataset};
use crate::nn::mlp::{MlpParams, HIDDEN, INPUT, OUTPUT};
use crate::runtime::{Executable, HostTensor, Runtime};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

pub struct MlpTrainer {
    step_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    /// W1, b1, W2, b2, mW1, mb1, mW2, mb2 — artifact state order, kept
    /// as literals across steps
    state: Vec<xla::Literal>,
    /// group-lasso weight for layer 1 (0 disables)
    pub lambda: f32,
    colmask: Vec<f32>,
    cluster_labels: Vec<i32>,
    share_flag: f32,
    /// cached literals for the rarely-changing inputs
    colmask_lit: xla::Literal,
    labels_lit: xla::Literal,
    pub steps_taken: usize,
}

fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    HostTensor::F32(dims.to_vec(), data.to_vec()).to_literal()
}

fn lit_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    HostTensor::I32(dims.to_vec(), data.to_vec()).to_literal()
}

fn lit_to_vec_f32(lit: &xla::Literal) -> Vec<f32> {
    lit.to_vec::<f32>().expect("state literal is f32")
}

impl MlpTrainer {
    pub fn new(rt: &Runtime, params: &MlpParams) -> Result<Self> {
        let step_exe = rt.get("mlp_train_step")?;
        let eval_exe = rt.get("mlp_eval")?;
        let zeros = |d: &[usize]| -> Result<xla::Literal> {
            let n: usize = d.iter().product();
            lit_f32(d, &vec![0.0; n])
        };
        let state = vec![
            lit_f32(&[HIDDEN, INPUT], params.w1.data())?,
            lit_f32(&[HIDDEN], &params.b1)?,
            lit_f32(&[OUTPUT, HIDDEN], params.w2.data())?,
            lit_f32(&[OUTPUT], &params.b2)?,
            zeros(&[HIDDEN, INPUT])?,
            zeros(&[HIDDEN])?,
            zeros(&[OUTPUT, HIDDEN])?,
            zeros(&[OUTPUT])?,
        ];
        let colmask = vec![1.0; INPUT];
        let cluster_labels: Vec<i32> = (0..INPUT as i32).collect();
        Ok(MlpTrainer {
            step_exe,
            eval_exe,
            state,
            lambda: 0.0,
            colmask_lit: lit_f32(&[INPUT], &colmask)?,
            labels_lit: lit_i32(&[INPUT], &cluster_labels)?,
            colmask,
            cluster_labels,
            share_flag: 0.0,
            steps_taken: 0,
        })
    }

    /// Batch size the artifact was lowered with.
    pub fn batch_size(&self) -> usize {
        self.step_exe.spec.inputs[8].dims[0]
    }

    pub fn colmask(&self) -> &[f32] {
        &self.colmask
    }

    pub fn set_colmask(&mut self, mask: Vec<f32>) {
        assert_eq!(mask.len(), INPUT);
        self.colmask_lit = lit_f32(&[INPUT], &mask).expect("colmask literal");
        self.colmask = mask;
    }

    pub fn set_cluster_labels(&mut self, labels: Vec<i32>) {
        assert_eq!(labels.len(), INPUT);
        self.labels_lit = lit_i32(&[INPUT], &labels).expect("labels literal");
        self.cluster_labels = labels;
    }

    pub fn set_share_flag(&mut self, on: bool) {
        self.share_flag = if on { 1.0 } else { 0.0 };
    }

    /// One SGD-momentum + prox step; returns the batch loss.
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32) -> Result<f64> {
        let b = self.batch_size();
        if x.len() != b * INPUT || y.len() != b {
            bail!("bad batch: x {} y {}", x.len(), y.len());
        }
        let x_lit = lit_f32(&[b, INPUT], x)?;
        let y_lit = lit_i32(&[b], y)?;
        let lr_lit = lit_f32(&[1], &[lr])?;
        let lam_lit = lit_f32(&[1], &[self.lambda])?;
        let share_lit = lit_f32(&[1], &[self.share_flag])?;
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.extend([
            &x_lit, &y_lit, &lr_lit, &lam_lit, &self.colmask_lit, &self.labels_lit, &share_lit,
        ]);
        let mut outs = self.step_exe.run_literals(&inputs)?;
        let loss_lit = outs.pop().expect("loss output");
        let loss = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss literal: {e:?}"))?[0] as f64;
        self.state = outs;
        self.steps_taken += 1;
        Ok(loss)
    }

    /// Run `steps` batches with the given schedule; records the loss
    /// every `log_every` steps.
    pub fn train(
        &mut self,
        data: &Dataset,
        steps: usize,
        sched: LrSchedule,
        log_every: usize,
        seed: u64,
    ) -> Result<LossCurve> {
        let mut iter = BatchIter::new(data, self.batch_size(), seed);
        let mut curve = Vec::new();
        for s in 0..steps {
            let (x, y, _) = iter.next_batch();
            let loss = self.step(&x, &y, sched.at(s))?;
            if s % log_every.max(1) == 0 || s + 1 == steps {
                curve.push((s, loss));
            }
        }
        Ok(curve)
    }

    /// Current parameters (copied out of the training state).
    pub fn params(&self) -> MlpParams {
        MlpParams {
            w1: Matrix::from_vec(HIDDEN, INPUT, lit_to_vec_f32(&self.state[0])),
            b1: lit_to_vec_f32(&self.state[1]),
            w2: Matrix::from_vec(OUTPUT, HIDDEN, lit_to_vec_f32(&self.state[2])),
            b2: lit_to_vec_f32(&self.state[3]),
        }
    }

    /// Overwrite W1 in the training state (e.g. after centroid
    /// projection) and reset its momentum.
    pub fn set_w1(&mut self, w1: &Matrix) {
        assert_eq!((w1.rows(), w1.cols()), (HIDDEN, INPUT));
        self.state[0] = lit_f32(&[HIDDEN, INPUT], w1.data()).expect("w1 literal");
        self.state[4] = lit_f32(&[HIDDEN, INPUT], &vec![0.0; HIDDEN * INPUT]).expect("m1 literal");
    }

    /// (mean loss, accuracy) over the largest multiple of the eval batch.
    pub fn evaluate(&self, data: &Dataset) -> Result<(f64, f64)> {
        let b = self.eval_exe.spec.inputs[4].dims[0];
        let batches = data.len() / b;
        if batches == 0 {
            bail!("eval set smaller than eval batch {b}");
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for i in 0..batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let (x, y) = data.gather(&idx);
            let x_lit = lit_f32(&[b, INPUT], &x)?;
            let y_lit = lit_i32(&[b], &y)?;
            let inputs: Vec<&xla::Literal> = self.state[..4]
                .iter()
                .chain([&x_lit, &y_lit])
                .collect();
            let outs = self.eval_exe.run_literals(&inputs)?;
            loss_sum += outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
            correct += outs[1].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
        }
        let n = (batches * b) as f64;
        Ok((loss_sum / n, correct / n))
    }
}
