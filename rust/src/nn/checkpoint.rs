//! Directory-based checkpoints: one `.npy` per named parameter, written
//! and read by the rust coordinator (and loadable from numpy for
//! debugging).

use super::npy::{read_npy, write_npy, NpyArray};
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Ordered name → array map (BTreeMap: deterministic iteration, which the
/// artifact calling convention relies on when flattening).
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    arrays: BTreeMap<String, NpyArray>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, arr: NpyArray) {
        self.arrays.insert(name.to_string(), arr);
    }

    pub fn get(&self, name: &str) -> Option<&NpyArray> {
        self.arrays.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut NpyArray> {
        self.arrays.get_mut(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.arrays.keys()
    }

    pub fn len(&self) -> usize {
        self.arrays.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrays.is_empty()
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("mkdir {}", dir.display()))?;
        for (name, arr) in &self.arrays {
            write_npy(&dir.join(format!("{name}.npy")), arr)?;
        }
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let mut store = ParamStore::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("read_dir {}", dir.display()))?
        {
            let path = entry?.path();
            if path.extension().map(|e| e == "npy").unwrap_or(false) {
                let name = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .context("bad filename")?
                    .to_string();
                store.arrays.insert(name, read_npy(&path)?);
            }
        }
        Ok(store)
    }
}

/// Read a 2-D weight matrix from a `.npy` file or a checkpoint
/// directory holding one (a `weight.npy` entry, or the directory's only
/// 2-D array) — the interchange format runtime model loading and the
/// `compress` CLI share.
pub fn load_weight_matrix(path: &Path) -> Result<Matrix> {
    let arr = if path.is_dir() {
        let store = ParamStore::load(path)?;
        if let Some(a) = store.get("weight") {
            a.clone()
        } else {
            let mut two_d: Vec<&String> = store
                .names()
                .filter(|n| store.get(n).map(|a| a.shape.len() == 2).unwrap_or(false))
                .collect();
            match (two_d.pop(), two_d.is_empty()) {
                (Some(only), true) => store.get(only).cloned().expect("present"),
                (Some(_), false) => bail!(
                    "checkpoint dir has several 2-D arrays and no \"weight\"; \
                     name the served matrix weight.npy"
                ),
                (None, _) => bail!("checkpoint dir holds no 2-D array"),
            }
        }
    } else {
        read_npy(path)?
    };
    if arr.shape.len() != 2 {
        bail!("served weight must be 2-D, got shape {:?}", arr.shape);
    }
    Ok(Matrix::from_vec(arr.shape[0], arr.shape[1], arr.data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lccnn-ckpt-{}", std::process::id()));
        let mut s = ParamStore::new();
        s.insert("w1", NpyArray::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        s.insert("b1", NpyArray::f32(vec![2], vec![0.5, -0.5]));
        s.save(&dir).unwrap();
        let back = ParamStore::load(&dir).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.get("w1").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back.get("b1").unwrap().shape, vec![2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn names_sorted() {
        let mut s = ParamStore::new();
        s.insert("z", NpyArray::f32(vec![1], vec![0.0]));
        s.insert("a", NpyArray::f32(vec![1], vec![0.0]));
        let names: Vec<_> = s.names().cloned().collect();
        assert_eq!(names, vec!["a", "z"]);
    }
}
