//! A CPU-trainable multi-layer perceptron in the LeNet-300-100 shape
//! the paper's MLP experiments (and Deep Compression's) use:
//! 784 → 300 → 100 → 10 with ReLU hidden layers and raw logits out.
//!
//! Unlike [`super::mlp`] (whose training runs through the AOT JAX
//! artifact), this net trains entirely in-process with plain
//! softmax-cross-entropy SGD — deterministic given a seed, fast enough
//! for the CI accuracy gate on `data::synth_mnist` — and converts
//! straight into a [`NetworkCheckpoint`] so the full-network
//! compression path (`compress --network`, `NetworkPipeline`,
//! `NetworkExecutor`) can be gated against the dense baseline it came
//! from.

use crate::compress::{Activation, NetworkCheckpoint, NetworkLayer};
use crate::data::{BatchIter, Dataset};
use crate::tensor::Matrix;
use crate::util::Rng;
use anyhow::Result;

/// Index of the largest logit (ties keep the earliest index).
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = logits.iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// An MLP of arbitrary depth: `dims = [in, h1, ..., out]`, ReLU after
/// every layer but the last.
#[derive(Clone, Debug)]
pub struct Mlp3 {
    dims: Vec<usize>,
    weights: Vec<Matrix>,
    biases: Vec<Vec<f32>>,
}

impl Mlp3 {
    /// He-normal init (scale √(2/fan_in)), zero biases.
    pub fn init(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        let mut rng = Rng::new(seed);
        let mut weights = Vec::with_capacity(dims.len() - 1);
        let mut biases = Vec::with_capacity(dims.len() - 1);
        for pair in dims.windows(2) {
            let (nin, nout) = (pair[0], pair[1]);
            let scale = (2.0f32 / nin as f32).sqrt();
            weights.push(Matrix::randn(nout, nin, scale, &mut rng));
            biases.push(vec![0.0; nout]);
        }
        Mlp3 { dims: dims.to_vec(), weights, biases }
    }

    /// The paper's MLP shape: 784 → 300 → 100 → 10.
    pub fn lenet_300_100(seed: u64) -> Self {
        Self::init(&[784, 300, 100, 10], seed)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn num_layers(&self) -> usize {
        self.weights.len()
    }

    /// Logits for one flattened example.
    pub fn forward_one(&self, x: &[f32]) -> Vec<f32> {
        let l = self.weights.len();
        let mut cur = x.to_vec();
        for (k, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let mut z = w.matvec(&cur);
            for (zv, &bv) in z.iter_mut().zip(b) {
                *zv += bv;
            }
            if k + 1 < l {
                Activation::Relu.apply(&mut z);
            }
            cur = z;
        }
        cur
    }

    /// Top-1 accuracy over a dataset.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..data.len() {
            if argmax(&self.forward_one(data.example(i))) == data.labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / data.len().max(1) as f64
    }

    /// Plain softmax-cross-entropy minibatch SGD, deterministic given
    /// the seed (shared by the shuffle order).
    pub fn train_sgd(&mut self, data: &Dataset, steps: usize, batch: usize, lr: f32, seed: u64) {
        assert_eq!(data.dims, self.dims[0], "dataset dims must match the input layer");
        let l = self.weights.len();
        let mut it = BatchIter::new(data, batch, seed);
        for _ in 0..steps {
            let (xs, ys, _) = it.next_batch();
            let mut gw: Vec<Matrix> =
                self.weights.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
            let mut gb: Vec<Vec<f32>> = self.biases.iter().map(|b| vec![0.0; b.len()]).collect();
            for (x, &label) in xs.chunks(data.dims).zip(&ys) {
                // forward, keeping every post-activation value
                let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l + 1);
                acts.push(x.to_vec());
                for (k, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
                    let mut z = w.matvec(acts.last().expect("input pushed"));
                    for (zv, &bv) in z.iter_mut().zip(b) {
                        *zv += bv;
                    }
                    if k + 1 < l {
                        Activation::Relu.apply(&mut z);
                    }
                    acts.push(z);
                }
                // softmax cross-entropy gradient at the logits
                let mut delta = softmax(acts.last().expect("logits pushed"));
                delta[label as usize] -= 1.0;
                // backprop through the stack
                for k in (0..l).rev() {
                    let a_prev = &acts[k];
                    for (r, &d) in delta.iter().enumerate() {
                        if d != 0.0 {
                            for (g, &a) in gw[k].row_mut(r).iter_mut().zip(a_prev) {
                                *g += d * a;
                            }
                        }
                        gb[k][r] += d;
                    }
                    if k > 0 {
                        let w = &self.weights[k];
                        let mut next = vec![0.0f32; w.cols()];
                        for (r, &d) in delta.iter().enumerate() {
                            if d != 0.0 {
                                for (nv, &wv) in next.iter_mut().zip(w.row(r)) {
                                    *nv += d * wv;
                                }
                            }
                        }
                        // ReLU': zero where the forward pass clamped
                        for (nv, &a) in next.iter_mut().zip(&acts[k]) {
                            if a <= 0.0 {
                                *nv = 0.0;
                            }
                        }
                        delta = next;
                    }
                }
            }
            let scale = lr / batch as f32;
            for k in 0..l {
                for r in 0..self.weights[k].rows() {
                    let grad = gw[k].row(r);
                    for (wv, &g) in self.weights[k].row_mut(r).iter_mut().zip(grad) {
                        *wv -= scale * g;
                    }
                }
                for (bv, &g) in self.biases[k].iter_mut().zip(&gb[k]) {
                    *bv -= scale * g;
                }
            }
        }
    }

    /// Convert into the multi-layer checkpoint the network compression
    /// pipeline consumes: ReLU on hidden layers, identity on the output.
    pub fn to_network_checkpoint(&self) -> Result<NetworkCheckpoint> {
        let l = self.weights.len();
        let layers = self
            .weights
            .iter()
            .zip(&self.biases)
            .enumerate()
            .map(|(k, (w, b))| NetworkLayer {
                weight: w.clone(),
                bias: Some(b.clone()),
                activation: if k + 1 < l { Activation::Relu } else { Activation::Identity },
            })
            .collect();
        NetworkCheckpoint::new(layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two well-separated 2-d Gaussian blobs, one per class.
    fn blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n * 2);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % 2;
            let (cx, cy) = if class == 0 { (1.0, 0.0) } else { (0.0, 1.0) };
            images.push(cx + 0.15 * rng.normal_f32());
            images.push(cy + 0.15 * rng.normal_f32());
            labels.push(class as i32);
        }
        Dataset { images, labels, dims: 2 }
    }

    #[test]
    fn sgd_learns_separable_blobs() {
        let train = blobs(80, 1);
        let test = blobs(40, 2);
        let mut net = Mlp3::init(&[2, 8, 2], 3);
        let before = net.accuracy(&test);
        net.train_sgd(&train, 200, 16, 0.1, 4);
        let after = net.accuracy(&test);
        assert!(after >= 0.9, "accuracy {before} -> {after}");
    }

    #[test]
    fn training_is_deterministic() {
        let train = blobs(40, 5);
        let mut a = Mlp3::init(&[2, 6, 2], 7);
        let mut b = Mlp3::init(&[2, 6, 2], 7);
        a.train_sgd(&train, 30, 8, 0.1, 9);
        b.train_sgd(&train, 30, 8, 0.1, 9);
        let x = [0.4f32, 0.6];
        assert_eq!(a.forward_one(&x), b.forward_one(&x));
    }

    #[test]
    fn checkpoint_conversion_matches_forward() {
        let net = Mlp3::init(&[5, 4, 3], 11);
        let ckpt = net.to_network_checkpoint().unwrap();
        assert_eq!(ckpt.num_layers(), 2);
        assert_eq!(ckpt.input_dim(), 5);
        assert_eq!(ckpt.output_dim(), 3);
        assert_eq!(ckpt.layers()[0].activation, Activation::Relu);
        assert_eq!(ckpt.layers()[1].activation, Activation::Identity);
        // hand-applying the checkpoint layers is bit-identical to forward_one
        let x = vec![0.3f32, -0.2, 0.8, 0.1, -0.5];
        let mut cur = x.clone();
        for l in ckpt.layers() {
            let mut y = l.weight.matvec(&cur);
            if let Some(b) = &l.bias {
                for (v, &bv) in y.iter_mut().zip(b) {
                    *v += bv;
                }
            }
            l.activation.apply(&mut y);
            cur = y;
        }
        assert_eq!(cur, net.forward_one(&x));
    }
}
