//! The compressed MLP: layer 1 replaced by one of the paper's three
//! stages (Fig. 2 series) — pruned-dense, +weight-sharing, +LCC — with
//! exact addition accounting per stage and accuracy evaluation through
//! the *actual* compressed computation (the LCC stage runs the shift-add
//! VM, not a dense stand-in).

use super::mlp::argmax;
use crate::data::Dataset;
use crate::quant::{matrix_csd_adders, FixedPointFormat};
use crate::share::{SharedLayer, SharedLcc};
use crate::tensor::Matrix;

/// Layer-1 evaluation strategy (the three Fig. 2 series).
pub enum Layer1 {
    /// regularized training only: compacted dense matrix, CSD adders
    Dense(Matrix),
    /// + weight sharing: segment sums + centroid matrix via CSD
    Shared(SharedLayer),
    /// + LCC: segment sums + shift-add program
    SharedLcc(SharedLcc),
}

impl Layer1 {
    pub fn apply(&self, x_kept: &[f32]) -> Vec<f32> {
        match self {
            Layer1::Dense(w) => w.matvec(x_kept),
            Layer1::Shared(s) => s.apply(x_kept),
            Layer1::SharedLcc(s) => s.apply(x_kept),
        }
    }

    /// Batched evaluation. The LCC stage routes the whole batch through
    /// the `exec` engine's batch-major kernels; the other stages map the
    /// scalar path per sample (their inner product is already dense).
    pub fn apply_batch(&self, xs_kept: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match self {
            Layer1::Dense(w) => xs_kept.iter().map(|x| w.matvec(x)).collect(),
            Layer1::Shared(s) => xs_kept.iter().map(|x| s.apply(x)).collect(),
            Layer1::SharedLcc(s) => s.apply_batch(xs_kept),
        }
    }

    /// Additions to evaluate layer 1 (the quantity Fig. 2's ratio uses).
    pub fn additions(&self, fmt: FixedPointFormat) -> usize {
        match self {
            Layer1::Dense(w) => matrix_csd_adders(w, fmt),
            Layer1::Shared(s) => s.additions_with_csd(fmt),
            Layer1::SharedLcc(s) => s.additions(),
        }
    }

    pub fn stage_name(&self) -> &'static str {
        match self {
            Layer1::Dense(_) => "reg-training",
            Layer1::Shared(_) => "reg+sharing",
            Layer1::SharedLcc(_) => "reg+sharing+LCC",
        }
    }
}

/// MLP with a compressed first layer. `kept` maps the compacted inputs
/// back to original feature indices (pruned features are never read —
/// on the FPGA they are simply not wired).
pub struct CompressedMlp {
    pub kept: Vec<usize>,
    pub layer1: Layer1,
    pub b1: Vec<f32>,
    pub w2: Matrix,
    pub b2: Vec<f32>,
}

impl CompressedMlp {
    /// Build from a compression-pipeline artifact plus the head
    /// parameters: the artifact's kept-column map and final
    /// representation (dense / shared / shared+LCC) become layer 1.
    pub fn from_compressed(
        artifact: crate::compress::CompressedModel,
        b1: Vec<f32>,
        w2: Matrix,
        b2: Vec<f32>,
    ) -> Self {
        let (kept, layer1) = artifact.into_layer1();
        CompressedMlp { kept, layer1, b1, w2, b2 }
    }

    pub fn forward_one(&self, x: &[f32]) -> Vec<f32> {
        let x_kept: Vec<f32> = self.kept.iter().map(|&i| x[i]).collect();
        let h = self.layer1.apply(&x_kept);
        self.head(h)
    }

    /// Batched forward: gather the kept features per sample, run layer 1
    /// through its batch path (the LCC stage uses the `exec` engine's
    /// lane-major kernels), then the dense head per sample.
    pub fn forward_batch<X: AsRef<[f32]>>(&self, xs: &[X]) -> Vec<Vec<f32>> {
        let kept: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                let x = x.as_ref();
                self.kept.iter().map(|&i| x[i]).collect()
            })
            .collect();
        let hs = self.layer1.apply_batch(&kept);
        hs.into_iter().map(|h| self.head(h)).collect()
    }

    /// Bias + ReLU + second layer + bias (identical for both paths, so
    /// batch and scalar forwards stay bit-identical).
    fn head(&self, mut h: Vec<f32>) -> Vec<f32> {
        for (hv, &b) in h.iter_mut().zip(&self.b1) {
            *hv = (*hv + b).max(0.0);
        }
        let mut out = self.w2.matvec(&h);
        for (ov, &b) in out.iter_mut().zip(&self.b2) {
            *ov += b;
        }
        out
    }

    pub fn accuracy(&self, data: &Dataset) -> f64 {
        const EVAL_CHUNK: usize = 64;
        let mut correct = 0usize;
        let mut start = 0usize;
        while start < data.len() {
            let end = (start + EVAL_CHUNK).min(data.len());
            let xs: Vec<&[f32]> = (start..end).map(|i| data.example(i)).collect();
            for (k, y) in self.forward_batch(&xs).iter().enumerate() {
                if argmax(y) == data.labels[start + k] as usize {
                    correct += 1;
                }
            }
            start = end;
        }
        correct as f64 / data.len().max(1) as f64
    }

    pub fn layer1_additions(&self, fmt: FixedPointFormat) -> usize {
        self.layer1.additions(fmt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::affinity::{cluster_columns, AffinityParams};
    use crate::compress::Pipeline;
    use crate::config::ExecConfig;
    use crate::lcc::LccConfig;
    use crate::prune::compact_columns;
    use crate::share::SharedLayer;
    use crate::util::Rng;

    /// A weight matrix with pruned columns and duplicated column groups.
    fn synthetic_w1(rows: usize) -> Matrix {
        let mut rng = Rng::new(0);
        let mut w = Matrix::zeros(rows, 20);
        // 4 groups of 4 near-identical active columns + 4 pruned columns
        for g in 0..4 {
            let base = rng.normal_vec(rows, 0.8);
            for j in 0..4 {
                let col = g * 5 + j; // every 5th column left at zero
                for r in 0..rows {
                    *w.at_mut(r, col) = base[r] + 0.005 * rng.normal_f32();
                }
            }
        }
        w
    }

    /// Model construction goes through the compression pipeline (the
    /// `compress::Pipeline` API is how layer 1 is built now); engine
    /// tuning reads `LCCNN_EXEC_*` so the CI exec matrix still steers
    /// these tests.
    fn build(stage: usize) -> (CompressedMlp, Matrix) {
        let rows = 16;
        let w1 = synthetic_w1(rows);
        let mut rng = Rng::new(9);
        let w2 = Matrix::randn(4, rows, 0.3, &mut rng);
        let mut b = Pipeline::builder().prune(1e-6);
        if stage >= 1 {
            b = b.share();
        }
        if stage >= 2 {
            b = b.lcc(&LccConfig::fs());
        }
        let artifact = b
            .exec(ExecConfig::from_env())
            .build()
            .expect("valid stage order")
            .run(&w1)
            .expect("pipeline runs");
        (CompressedMlp::from_compressed(artifact, vec![0.0; rows], w2, vec![0.0; 4]), w1)
    }

    /// The pipeline-built model must be bit-identical to the historical
    /// hand-wired construction at every stage.
    #[test]
    fn from_compressed_matches_legacy_hand_wiring() {
        let rows = 16;
        let w1 = synthetic_w1(rows);
        let compact = compact_columns(&w1, 1e-6);
        let mut rng = Rng::new(9);
        let w2 = Matrix::randn(4, rows, 0.3, &mut rng);
        let c = cluster_columns(&compact.weights, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&compact.weights, &c);
        let legacy_layers = [
            Layer1::Dense(compact.weights.clone()),
            Layer1::Shared(sl.clone()),
            Layer1::SharedLcc(sl.with_lcc_exec(&LccConfig::fs(), ExecConfig::from_env())),
        ];
        let mut rng = Rng::new(33);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(20, 1.0)).collect();
        for (stage, layer1) in legacy_layers.into_iter().enumerate() {
            let legacy = CompressedMlp {
                kept: compact.kept.clone(),
                layer1,
                b1: vec![0.0; rows],
                w2: w2.clone(),
                b2: vec![0.0; 4],
            };
            let (piped, _) = build(stage);
            assert_eq!(piped.kept, legacy.kept, "stage {stage}");
            for x in &xs {
                assert_eq!(piped.forward_one(x), legacy.forward_one(x), "stage {stage}");
            }
        }
    }

    #[test]
    fn stages_agree_numerically() {
        // sharing/LCC outputs stay close to the pruned-dense forward
        let mut rng = Rng::new(3);
        let x: Vec<f32> = rng.normal_vec(20, 1.0);
        let (dense, _) = build(0);
        let y0 = dense.forward_one(&x);
        for stage in 1..3 {
            let (m, _) = build(stage);
            let y = m.forward_one(&x);
            for (a, b) in y0.iter().zip(&y) {
                assert!((a - b).abs() < 0.3 + 0.1 * a.abs(), "stage {stage}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn additions_decrease_along_the_pipeline() {
        let fmt = FixedPointFormat::default_weights();
        let (d, _) = build(0);
        let (s, _) = build(1);
        let (l, _) = build(2);
        let (a0, a1, a2) = (
            d.layer1_additions(fmt),
            s.layer1_additions(fmt),
            l.layer1_additions(fmt),
        );
        assert!(a1 < a0, "sharing {a1} !< dense {a0}");
        assert!(a2 < a1, "lcc {a2} !< sharing {a1}");
    }

    #[test]
    fn pruned_inputs_are_ignored() {
        let (m, _) = build(0);
        let mut x = vec![0.0f32; 20];
        // set only pruned columns (indices 4, 9, 14, 19)
        for &i in &[4usize, 9, 14, 19] {
            x[i] = 100.0;
        }
        let y = m.forward_one(&x);
        // all-zero active inputs -> logits == bias path (all zeros here)
        assert!(y.iter().all(|&v| v == 0.0), "{y:?}");
    }

    #[test]
    fn forward_batch_matches_forward_one_every_stage() {
        let mut rng = Rng::new(17);
        let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(20, 1.0)).collect();
        for stage in 0..3 {
            let (m, _) = build(stage);
            let batch = m.forward_batch(&xs);
            for (x, y) in xs.iter().zip(&batch) {
                assert_eq!(*y, m.forward_one(x), "stage {stage}");
            }
        }
    }

    #[test]
    fn stage_names() {
        assert_eq!(build(0).0.layer1.stage_name(), "reg-training");
        assert_eq!(build(1).0.layer1.stage_name(), "reg+sharing");
        assert_eq!(build(2).0.layer1.stage_name(), "reg+sharing+LCC");
    }
}
