//! Neural-network substrate: parameter storage, checkpoint I/O (first-
//! party `.npy`), CPU reference forwards for the MLP and the residual
//! CNN, the full ResNet-34 layer inventory for exact adder accounting,
//! and the compressed-model evaluators that execute the paper's scheme
//! (pruning + sharing + LCC) end to end.

pub mod checkpoint;
pub mod compressed;
pub mod mlp;
pub mod mlp3;
pub mod npy;
pub mod resnet;

pub use checkpoint::{load_weight_matrix, ParamStore};
pub use compressed::{CompressedMlp, Layer1};
pub use mlp::MlpParams;
pub use mlp3::Mlp3;
