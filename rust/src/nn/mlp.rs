//! The paper's MLP (784 → 300 → 10, Sec. IV-A): rust-side parameter
//! container, He init, CPU reference forward and accuracy evaluation.
//! Training itself runs through the AOT JAX artifact (see
//! [`crate::train`]); this forward is the baseline evaluator and the
//! numerical reference the compressed model is compared against.

use super::checkpoint::ParamStore;
use super::npy::NpyArray;
use crate::data::Dataset;
use crate::tensor::Matrix;
use crate::util::Rng;

pub const INPUT: usize = 784;
pub const HIDDEN: usize = 300;
pub const OUTPUT: usize = 10;

#[derive(Clone, Debug)]
pub struct MlpParams {
    pub w1: Matrix, // HIDDEN x INPUT
    pub b1: Vec<f32>,
    pub w2: Matrix, // OUTPUT x HIDDEN
    pub b2: Vec<f32>,
}

impl MlpParams {
    /// He-normal init (scale sqrt(2/fan_in)).
    pub fn init(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let s1 = (2.0f32 / INPUT as f32).sqrt();
        let s2 = (2.0f32 / HIDDEN as f32).sqrt();
        MlpParams {
            w1: Matrix::randn(HIDDEN, INPUT, s1, &mut rng),
            b1: vec![0.0; HIDDEN],
            w2: Matrix::randn(OUTPUT, HIDDEN, s2, &mut rng),
            b2: vec![0.0; OUTPUT],
        }
    }

    /// Logits for one flattened example.
    pub fn forward_one(&self, x: &[f32]) -> Vec<f32> {
        let mut h = self.w1.matvec(x);
        for (hv, &b) in h.iter_mut().zip(&self.b1) {
            *hv = (*hv + b).max(0.0);
        }
        let mut out = self.w2.matvec(&h);
        for (ov, &b) in out.iter_mut().zip(&self.b2) {
            *ov += b;
        }
        out
    }

    /// Top-1 accuracy over a dataset.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..data.len() {
            let logits = self.forward_one(data.example(i));
            let pred = argmax(&logits);
            if pred == data.labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / data.len().max(1) as f64
    }

    /// Flatten into a ParamStore using the artifact naming convention.
    pub fn to_store(&self) -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("W1", NpyArray::f32(vec![HIDDEN, INPUT], self.w1.data().to_vec()));
        s.insert("b1", NpyArray::f32(vec![HIDDEN], self.b1.clone()));
        s.insert("W2", NpyArray::f32(vec![OUTPUT, HIDDEN], self.w2.data().to_vec()));
        s.insert("b2", NpyArray::f32(vec![OUTPUT], self.b2.clone()));
        s
    }

    pub fn from_store(s: &ParamStore) -> Option<Self> {
        Some(MlpParams {
            w1: Matrix::from_vec(HIDDEN, INPUT, s.get("W1")?.data.clone()),
            b1: s.get("b1")?.data.clone(),
            w2: Matrix::from_vec(OUTPUT, HIDDEN, s.get("W2")?.data.clone()),
            b2: s.get("b2")?.data.clone(),
        })
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn forward_shapes() {
        let p = MlpParams::init(0);
        let x = vec![0.1; INPUT];
        assert_eq!(p.forward_one(&x).len(), OUTPUT);
    }

    #[test]
    fn random_init_near_chance() {
        let p = MlpParams::init(1);
        let data = synth_mnist::generate(200, 0);
        let acc = p.accuracy(&data);
        assert!(acc < 0.35, "untrained accuracy suspiciously high: {acc}");
    }

    #[test]
    fn store_roundtrip() {
        let p = MlpParams::init(2);
        let s = p.to_store();
        let q = MlpParams::from_store(&s).unwrap();
        assert_eq!(p.w1, q.w1);
        assert_eq!(p.b2, q.b2);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
