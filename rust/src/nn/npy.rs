//! Minimal `.npy` (format version 1.0) reader/writer for f32 and i32
//! arrays — checkpoint interchange with the python build path without an
//! external dependency.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8] = b"\x93NUMPY";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn descr(&self) -> &'static str {
        match self {
            DType::F32 => "<f4",
            DType::I32 => "<i4",
        }
    }
}

/// An n-dimensional array as (shape, flat f32 data). i32 arrays are
/// converted losslessly for |v| < 2^24; checkpoints only carry weights
/// and small integer labels, well within range.
#[derive(Clone, Debug, PartialEq)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub data: Vec<f32>,
}

impl NpyArray {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyArray { shape, dtype: DType::F32, data }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Write an array to `.npy` v1.0.
pub fn write_npy(path: &Path, arr: &NpyArray) -> Result<()> {
    let shape_str = match arr.shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", arr.shape[0]),
        _ => format!(
            "({})",
            arr.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        arr.dtype.descr(),
        shape_str
    );
    // pad so that magic(6) + ver(2) + len(2) + header is a multiple of 64
    let unpadded = MAGIC.len() + 2 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    match arr.dtype {
        DType::F32 => {
            for v in &arr.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        DType::I32 => {
            for v in &arr.data {
                f.write_all(&(*v as i32).to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Read a `.npy` file (v1.x, little-endian f4/i4, C order).
pub fn read_npy(path: &Path) -> Result<NpyArray> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("not an npy file: {}", path.display());
    }
    let major = buf[6];
    if major != 1 {
        bail!("unsupported npy version {major}");
    }
    let hlen = u16::from_le_bytes([buf[8], buf[9]]) as usize;
    let header = std::str::from_utf8(&buf[10..10 + hlen]).context("header utf8")?;
    let dtype = if header.contains("'<f4'") {
        DType::F32
    } else if header.contains("'<i4'") {
        DType::I32
    } else {
        bail!("unsupported dtype in header: {header}");
    };
    if header.contains("'fortran_order': True") {
        bail!("fortran order unsupported");
    }
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .context("no shape")?
        .split('(')
        .nth(1)
        .context("no shape tuple")?
        .split(')')
        .next()
        .context("unterminated shape")?;
    let shape: Vec<usize> = shape_part
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("shape parse"))
        .collect::<Result<_>>()?;
    let numel: usize = shape.iter().product();
    let body = &buf[10 + hlen..];
    if body.len() < numel * 4 {
        bail!("truncated npy body");
    }
    let data: Vec<f32> = (0..numel)
        .map(|i| {
            let b = [body[i * 4], body[i * 4 + 1], body[i * 4 + 2], body[i * 4 + 3]];
            match dtype {
                DType::F32 => f32::from_le_bytes(b),
                DType::I32 => i32::from_le_bytes(b) as f32,
            }
        })
        .collect();
    Ok(NpyArray { shape, dtype, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lccnn-npy-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn f32_roundtrip() {
        let p = tmpdir().join("a.npy");
        let arr = NpyArray::f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 9.9]);
        write_npy(&p, &arr).unwrap();
        let back = read_npy(&p).unwrap();
        assert_eq!(back, arr);
    }

    #[test]
    fn i32_roundtrip() {
        let p = tmpdir().join("b.npy");
        let arr = NpyArray { shape: vec![4], dtype: DType::I32, data: vec![1.0, -7.0, 0.0, 42.0] };
        write_npy(&p, &arr).unwrap();
        let back = read_npy(&p).unwrap();
        assert_eq!(back.dtype, DType::I32);
        assert_eq!(back.data, arr.data);
    }

    #[test]
    fn vector_shape() {
        let p = tmpdir().join("c.npy");
        let arr = NpyArray::f32(vec![5], vec![0.0; 5]);
        write_npy(&p, &arr).unwrap();
        assert_eq!(read_npy(&p).unwrap().shape, vec![5]);
    }

    #[test]
    fn python_numpy_can_read_ours() {
        // cross-checked via header layout: 64-byte aligned, v1.0
        let p = tmpdir().join("d.npy");
        write_npy(&p, &NpyArray::f32(vec![1], vec![1.0])).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..6], MAGIC);
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0, "header must align to 64");
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpdir().join("e.npy");
        std::fs::write(&p, b"not an npy").unwrap();
        assert!(read_npy(&p).is_err());
    }
}
