//! Residual CNN substrate.
//!
//! Two pieces:
//! 1. The trainable tiny ResNet (exact mirror of
//!    `python/compile/resnet.py`: same parameter names, shapes and
//!    forward semantics) — rust owns init + evaluation; training steps
//!    run through the AOT artifact.
//! 2. The full ResNet-34 layer inventory at TinyImageNet geometry for
//!    exact per-layer adder accounting (the paper's Table-I model; see
//!    DESIGN.md Substitutions for how it is used without ImageNet-scale
//!    training).

use super::checkpoint::ParamStore;
use super::mlp::argmax;
use super::npy::NpyArray;
use crate::data::Dataset;
use crate::tensor::{conv2d, Conv2dParams, Matrix, Padding, Tensor4};
use crate::util::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 40;
pub const STAGES: [usize; 3] = [16, 32, 64];

/// Ordered (name, shape) parameter specs — must match
/// `python/compile/resnet.py::param_specs()` exactly (the artifact
/// calling convention).
pub fn param_specs() -> Vec<(String, Vec<usize>)> {
    let mut specs: Vec<(String, Vec<usize>)> = vec![
        ("stem_w".into(), vec![3, 3, CHANNELS, STAGES[0]]),
        ("stem_b".into(), vec![STAGES[0]]),
    ];
    let mut c_in = STAGES[0];
    for (si, &c) in STAGES.iter().enumerate() {
        for bi in 0..2 {
            let p = format!("s{si}b{bi}");
            let in_ch = if bi == 0 { c_in } else { c };
            specs.push((format!("{p}_c1w"), vec![3, 3, in_ch, c]));
            specs.push((format!("{p}_c1b"), vec![c]));
            specs.push((format!("{p}_c2w"), vec![3, 3, c, c]));
            specs.push((format!("{p}_c2b"), vec![c]));
            if bi == 0 && (si > 0 || c_in != c) {
                specs.push((format!("{p}_projw"), vec![1, 1, c_in, c]));
            }
            specs.push((format!("{p}_alpha"), vec![1]));
        }
        c_in = c;
    }
    specs.push(("fc_w".into(), vec![CLASSES, STAGES[2]]));
    specs.push(("fc_b".into(), vec![CLASSES]));
    specs
}

/// Names of the 3x3 conv kernels that Table I compresses (stem and 1x1
/// projections excluded, matching `resnet.py::CONV_KERNEL_NAMES`).
pub fn conv_kernel_names() -> Vec<String> {
    param_specs()
        .into_iter()
        .filter(|(n, s)| (n.ends_with("c1w") || n.ends_with("c2w")) && s.len() == 4)
        .map(|(n, _)| n)
        .collect()
}

/// He-init parameter store (alphas zero — SkipInit — biases zero).
pub fn init_params(seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut store = ParamStore::new();
    for (name, shape) in param_specs() {
        let numel: usize = shape.iter().product();
        let data = if name.ends_with('w') && shape.len() >= 2 {
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            rng.normal_vec(numel, (2.0 / fan_in as f32).sqrt())
        } else {
            vec![0.0; numel]
        };
        store.insert(&name, NpyArray::f32(shape, data));
    }
    store
}

fn kernel_of(store: &ParamStore, name: &str) -> Tensor4 {
    let arr = store.get(name).unwrap_or_else(|| panic!("missing param {name}"));
    let s = &arr.shape;
    assert_eq!(s.len(), 4, "{name} not 4-d");
    Tensor4::from_vec(s[0], s[1], s[2], s[3], arr.data.clone())
}

fn add_bias(t: &mut Tensor4, b: &[f32]) {
    let (n, h, w, c) = t.shape();
    assert_eq!(b.len(), c);
    for bi in 0..n {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    *t.at_mut(bi, y, x, ch) += b[ch];
                }
            }
        }
    }
}

fn relu(t: &Tensor4) -> Tensor4 {
    let (n, h, w, c) = t.shape();
    let data = t.data().iter().map(|&v| v.max(0.0)).collect();
    Tensor4::from_vec(n, h, w, c, data)
}

/// Forward pass — logits [batch, CLASSES]. Mirrors
/// `python/compile/resnet.py::forward` (pre-activation blocks, SkipInit
/// residual scaling, GAP head).
pub fn forward(store: &ParamStore, x: &Tensor4) -> Matrix {
    let same = |s: usize| Conv2dParams { stride: s, padding: Padding::Same };
    let mut h = conv2d(x, &kernel_of(store, "stem_w"), same(1));
    add_bias(&mut h, &store.get("stem_b").unwrap().data);
    let mut c_in = STAGES[0];
    for (si, &c) in STAGES.iter().enumerate() {
        for bi in 0..2 {
            let p = format!("s{si}b{bi}");
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let r = relu(&h);
            let mut f = conv2d(&r, &kernel_of(store, &format!("{p}_c1w")), same(stride));
            add_bias(&mut f, &store.get(&format!("{p}_c1b")).unwrap().data);
            let mut f = relu(&f);
            f = conv2d(&f, &kernel_of(store, &format!("{p}_c2w")), same(1));
            add_bias(&mut f, &store.get(&format!("{p}_c2b")).unwrap().data);
            let sc = if store.get(&format!("{p}_projw")).is_some() {
                conv2d(&r, &kernel_of(store, &format!("{p}_projw")), same(stride))
            } else {
                h.clone()
            };
            let alpha = store.get(&format!("{p}_alpha")).unwrap().data[0];
            let (n, hh, ww, cc) = sc.shape();
            let mut out = Tensor4::zeros(n, hh, ww, cc);
            for (o, (s, fv)) in out
                .data_mut()
                .iter_mut()
                .zip(sc.data().iter().zip(f.data()))
            {
                *o = s + alpha * fv;
            }
            h = out;
        }
        c_in = c;
    }
    let _ = c_in;
    let h = relu(&h);
    let (n, hh, ww, c) = h.shape();
    let fc_w = store.get("fc_w").unwrap();
    let fc_b = &store.get("fc_b").unwrap().data;
    let w_mat = Matrix::from_vec(CLASSES, c, fc_w.data.clone());
    let mut logits = Matrix::zeros(n, CLASSES);
    let inv = 1.0 / (hh * ww) as f32;
    for b in 0..n {
        let mut feat = vec![0.0f32; c];
        for y in 0..hh {
            for x in 0..ww {
                for ch in 0..c {
                    feat[ch] += h.at(b, y, x, ch);
                }
            }
        }
        for f in feat.iter_mut() {
            *f *= inv;
        }
        let out = w_mat.matvec(&feat);
        for (j, (&o, &bb)) in out.iter().zip(fc_b).enumerate() {
            *logits.at_mut(b, j) = o + bb;
        }
    }
    logits
}

/// Top-1 accuracy over a (flattened NHWC) dataset, in small batches.
pub fn accuracy(store: &ParamStore, data: &Dataset, limit: usize) -> f64 {
    let n = data.len().min(limit);
    let mut correct = 0usize;
    let bs = 16usize;
    let mut i = 0;
    while i < n {
        let m = bs.min(n - i);
        let mut batch = Tensor4::zeros(m, IMG, IMG, CHANNELS);
        for b in 0..m {
            batch.data_mut()[b * data.dims..(b + 1) * data.dims]
                .copy_from_slice(data.example(i + b));
        }
        let logits = forward(store, &batch);
        for b in 0..m {
            if argmax(logits.row(b)) == data.labels[i + b] as usize {
                correct += 1;
            }
        }
        i += m;
    }
    correct as f64 / n.max(1) as f64
}

// ---------------------------------------------------------------------------
// ResNet-34 inventory (TinyImageNet geometry) for exact adder accounting
// ---------------------------------------------------------------------------

/// One conv layer's geometry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvLayerSpec {
    pub name: String,
    pub in_ch: usize,
    pub out_ch: usize,
    pub kernel: usize,
    pub stride: usize,
    /// input spatial side (square)
    pub in_side: usize,
}

impl ConvLayerSpec {
    pub fn out_side(&self) -> usize {
        self.in_side.div_ceil(self.stride)
    }
}

/// The full ResNet-34 conv inventory at 64×64 input (TinyImageNet):
/// 3x3 stem + stages [3,4,6,3] of basic blocks at [64,128,256,512].
pub fn resnet34_spec() -> Vec<ConvLayerSpec> {
    let mut layers = vec![ConvLayerSpec {
        name: "stem".into(),
        in_ch: 3,
        out_ch: 64,
        kernel: 3,
        stride: 1,
        in_side: 64,
    }];
    let stages: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    let mut c_in = 64usize;
    let mut side = 64usize;
    for (si, &(c, blocks)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            layers.push(ConvLayerSpec {
                name: format!("s{si}b{bi}_c1"),
                in_ch: if bi == 0 { c_in } else { c },
                out_ch: c,
                kernel: 3,
                stride,
                in_side: side,
            });
            if stride == 2 {
                side /= 2;
            }
            layers.push(ConvLayerSpec {
                name: format!("s{si}b{bi}_c2"),
                in_ch: c,
                out_ch: c,
                kernel: 3,
                stride: 1,
                in_side: side,
            });
        }
        c_in = c;
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_tiny;

    #[test]
    fn specs_match_python_layout() {
        let specs = param_specs();
        let names: Vec<&str> = specs.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"stem_w"));
        assert!(names.contains(&"s1b0_projw"));
        assert!(!names.contains(&"s0b0_projw")); // same-channel stage 0
        assert_eq!(conv_kernel_names().len(), 12);
        // fc last
        assert_eq!(names.last().unwrap(), &"fc_b");
    }

    #[test]
    fn forward_shape_and_untrained_chance() {
        let store = init_params(0);
        let data = synth_tiny::generate(32, 1);
        let acc = accuracy(&store, &data, 32);
        // alpha=0 => output depends only on stem conv + GAP; near chance
        assert!(acc < 0.25, "untrained acc {acc}");
    }

    #[test]
    fn forward_batch_matches_single() {
        let store = init_params(2);
        let data = synth_tiny::generate(4, 3);
        let mut batch = Tensor4::zeros(2, IMG, IMG, CHANNELS);
        batch.data_mut()[..data.dims].copy_from_slice(data.example(0));
        batch.data_mut()[data.dims..].copy_from_slice(data.example(1));
        let both = forward(&store, &batch);
        let mut single = Tensor4::zeros(1, IMG, IMG, CHANNELS);
        single.data_mut().copy_from_slice(data.example(0));
        let one = forward(&store, &single);
        for j in 0..CLASSES {
            assert!((both.at(0, j) - one.at(0, j)).abs() < 1e-4);
        }
    }

    #[test]
    fn resnet34_inventory() {
        let layers = resnet34_spec();
        // 1 stem + 2*(3+4+6+3) block convs = 33 conv layers (+fc = 34)
        assert_eq!(layers.len(), 33);
        assert_eq!(layers.last().unwrap().out_ch, 512);
        // spatial side shrinks 64 -> 8 across the 3 strided transitions
        assert_eq!(layers.last().unwrap().in_side, 8);
        // parameter count sanity: ~21M for ResNet-34 trunk
        let params: usize = layers.iter().map(|l| l.in_ch * l.out_ch * l.kernel * l.kernel).sum();
        assert!(params > 20_000_000 && params < 23_000_000, "{params}");
    }
}
