//! Canonical signed digit (CSD) recoding [Booth 1951].
//!
//! CSD writes an integer as sum of signed powers of two with no two
//! adjacent nonzero digits — the minimal-weight signed-digit form. The
//! number of additions to multiply by a constant is
//! `(#nonzero digits) - 1`; this is the paper's baseline cost for the
//! uncompressed matrix-vector product.

use super::fixed::{quantize_value, FixedPointFormat};
use crate::tensor::Matrix;

/// One CSD digit: value contribution is `sign * 2^shift` where shift is
/// relative to the *integer mantissa* LSB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsdDigit {
    pub shift: i32,
    pub negative: bool,
}

/// Non-adjacent-form recoding of an integer mantissa. Digits are returned
/// LSB-first. The empty vec encodes zero.
pub fn csd_digits(mantissa: i64) -> Vec<CsdDigit> {
    let mut n = mantissa;
    let mut digits = Vec::new();
    let mut shift = 0i32;
    while n != 0 {
        if n & 1 != 0 {
            // z in {-1, +1}: choose so that (n - z) is divisible by 4
            let z: i64 = 2 - (n.rem_euclid(4));
            digits.push(CsdDigit { shift, negative: z < 0 });
            n -= z;
        }
        n >>= 1;
        shift += 1;
    }
    digits
}

/// Reconstruct the integer mantissa from CSD digits.
pub fn csd_value(digits: &[CsdDigit]) -> i64 {
    digits
        .iter()
        .map(|d| {
            let v = 1i64 << d.shift;
            if d.negative { -v } else { v }
        })
        .sum()
}

/// Number of nonzero CSD digits of a float under the given fixed-point
/// format.
pub fn csd_nonzero_digits(v: f32, fmt: FixedPointFormat) -> usize {
    csd_digits(quantize_value(v, fmt)).len()
}

/// Additions to compute `row . x` with CSD-recoded constants:
/// per entry `digits - 1` adds for the multiple, plus
/// `(#nonzero entries) - 1` adds to accumulate. Equivalently
/// `(total nonzero digits) - 1` when at least one entry is nonzero.
pub fn row_csd_adders(row: &[f32], fmt: FixedPointFormat) -> usize {
    let total: usize = row.iter().map(|&v| csd_nonzero_digits(v, fmt)).sum();
    total.saturating_sub(1)
}

/// Baseline adders for the full matrix-vector product `W x` (paper
/// Sec. IV): sum of per-row costs.
pub fn matrix_csd_adders(w: &Matrix, fmt: FixedPointFormat) -> usize {
    (0..w.rows()).map(|r| row_csd_adders(w.row(r), fmt)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn csd_roundtrip_small_integers() {
        for n in -1000i64..=1000 {
            assert_eq!(csd_value(&csd_digits(n)), n, "n={n}");
        }
    }

    #[test]
    fn csd_nonadjacent_property() {
        let mut rng = Rng::new(0);
        for _ in 0..500 {
            let n = (rng.next_u64() % 100_000) as i64 - 50_000;
            let digits = csd_digits(n);
            for w in digits.windows(2) {
                assert!(
                    (w[1].shift - w[0].shift) >= 2,
                    "adjacent digits in CSD of {n}: {digits:?}"
                );
            }
        }
    }

    #[test]
    fn csd_weight_not_worse_than_binary() {
        for n in 1..4096i64 {
            let csd = csd_digits(n).len();
            let bin = n.count_ones() as usize;
            assert!(csd <= bin, "n={n} csd={csd} bin={bin}");
        }
    }

    #[test]
    fn csd_known_examples() {
        // 15 = 16 - 1: two digits in CSD, four in binary
        assert_eq!(csd_digits(15).len(), 2);
        // 0.375 * 8 = 3 = 4 - 1
        assert_eq!(csd_digits(3).len(), 2);
        // powers of two have a single digit
        assert_eq!(csd_digits(64).len(), 1);
        assert!(csd_digits(0).is_empty());
    }

    #[test]
    fn paper_eq2_example_costs() {
        // W = [[2, 0.375], [3.75, 1]] (paper eq. 2):
        // 2 -> 1 digit; 0.375 -> 2 digits (2^-1 - 2^-3);
        // 3.75 -> 2 digits (4 - 0.25); 1 -> 1 digit.
        // Row 0: 3 digits -> 2 adds; row 1: 3 digits -> 2 adds; total 4
        // (matches the "two additions, two subtractions" of eq. 2).
        let fmt = FixedPointFormat::new(3, 8);
        let w = Matrix::from_rows(&[&[2.0, 0.375], &[3.75, 1.0]]);
        assert_eq!(matrix_csd_adders(&w, fmt), 4);
    }

    #[test]
    fn zero_rows_cost_nothing() {
        let fmt = FixedPointFormat::default_weights();
        let w = Matrix::zeros(4, 4);
        assert_eq!(matrix_csd_adders(&w, fmt), 0);
    }
}
