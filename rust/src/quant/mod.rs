//! Quantization substrate: fixed-point representation and canonical
//! signed digit (CSD) recoding.
//!
//! The paper's baseline cost model (Sec. IV): the uncompressed network is
//! quantized and each weight is written in CSD form; multiplying by a
//! weight with `d` nonzero CSD digits costs `d - 1` additions (plus
//! bitshifts, which are free on FPGAs), and accumulating `K` partial
//! products per output row costs another `K - 1` additions.

mod csd;
mod fixed;

pub use csd::{
    csd_digits, csd_nonzero_digits, csd_value, matrix_csd_adders, row_csd_adders, CsdDigit,
};
pub use fixed::{quantize_matrix, quantize_value, FixedPointFormat};
