//! Fixed-point quantization: round-to-nearest onto a signed grid with a
//! configurable number of fractional bits.

use crate::tensor::Matrix;

/// Signed fixed-point format: values are integer multiples of 2^-frac_bits
/// with magnitude below 2^int_bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedPointFormat {
    /// bits left of the binary point (excluding sign)
    pub int_bits: u32,
    /// bits right of the binary point
    pub frac_bits: u32,
}

impl FixedPointFormat {
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        FixedPointFormat { int_bits, frac_bits }
    }

    /// The paper's 8-bit-ish default for weight matrices (range ±4).
    pub const fn default_weights() -> Self {
        FixedPointFormat { int_bits: 2, frac_bits: 8 }
    }

    pub fn step(&self) -> f64 {
        (2.0f64).powi(-(self.frac_bits as i32))
    }

    pub fn max_value(&self) -> f64 {
        (2.0f64).powi(self.int_bits as i32) - self.step()
    }
}

/// Round `v` to the nearest representable value (saturating), returning
/// the integer mantissa: value = mantissa * 2^-frac_bits.
pub fn quantize_value(v: f32, fmt: FixedPointFormat) -> i64 {
    let scale = (2.0f64).powi(fmt.frac_bits as i32);
    let max_m = (fmt.max_value() * scale).round() as i64;
    let m = (v as f64 * scale).round() as i64;
    m.clamp(-max_m, max_m)
}

/// Quantize every entry; returns (mantissas, dequantized matrix).
pub fn quantize_matrix(w: &Matrix, fmt: FixedPointFormat) -> (Vec<i64>, Matrix) {
    let step = fmt.step() as f32;
    let mut mantissas = Vec::with_capacity(w.rows() * w.cols());
    let mut deq = Matrix::zeros(w.rows(), w.cols());
    for r in 0..w.rows() {
        for c in 0..w.cols() {
            let m = quantize_value(w.at(r, c), fmt);
            mantissas.push(m);
            *deq.at_mut(r, c) = m as f32 * step;
        }
    }
    (mantissas, deq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_grid_values_roundtrip() {
        let fmt = FixedPointFormat::new(2, 3); // step 0.125
        assert_eq!(quantize_value(0.375, fmt), 3);
        assert_eq!(quantize_value(-1.5, fmt), -12);
        assert_eq!(quantize_value(0.0, fmt), 0);
    }

    #[test]
    fn saturates_at_range() {
        let fmt = FixedPointFormat::new(1, 2); // max 2 - 0.25 = 1.75 -> m 7
        assert_eq!(quantize_value(100.0, fmt), 7);
        assert_eq!(quantize_value(-100.0, fmt), -7);
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        let fmt = FixedPointFormat::default_weights();
        let mut rng = Rng::new(0);
        let w = Matrix::randn(20, 20, 0.5, &mut rng);
        let (_, deq) = quantize_matrix(&w, fmt);
        let half = fmt.step() as f32 / 2.0;
        for i in 0..w.data().len() {
            let err = (w.data()[i] - deq.data()[i]).abs();
            assert!(err <= half + 1e-7, "err {err} > {half}");
        }
    }
}
