//! Weight sharing (paper Sec. III-C, eq. 10).
//!
//! After clustering ties similar columns to shared centroids,
//! `W x = Σ_i g_i Σ_{j∈I_i} x_j`: first sum the inputs of every cluster
//! (scalar additions), then multiply the small centroid matrix `G`
//! (N × C, C ≪ K) — which is what LCC then decomposes. This module holds
//! the shared representation, its exact addition accounting and its
//! composition with an LCC graph.

use crate::cluster::Clustering;
use crate::config::ExecConfig;
use crate::exec::{BatchEngine, Executor};
use crate::graph::AdderGraph;
use crate::lcc::{decompose, LccConfig, LccDecomposition};
use crate::quant::{matrix_csd_adders, FixedPointFormat};
use crate::tensor::Matrix;

/// A dense layer after weight sharing: y = G * segsum(x).
#[derive(Clone, Debug)]
pub struct SharedLayer {
    /// centroid matrix G (N x C)
    pub centroids: Matrix,
    /// cluster id per input column (length K)
    pub labels: Vec<usize>,
}

impl SharedLayer {
    pub fn from_clustering(w: &Matrix, c: &Clustering) -> Self {
        SharedLayer { centroids: c.centroids(w), labels: c.labels.clone() }
    }

    pub fn num_inputs(&self) -> usize {
        self.labels.len()
    }

    pub fn num_clusters(&self) -> usize {
        self.centroids.cols()
    }

    /// Segment sums: s_i = Σ_{j ∈ I_i} x_j.
    pub fn segment_sums(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.labels.len(), "input dim mismatch");
        let mut s = vec![0.0f32; self.num_clusters()];
        for (&l, &xv) in self.labels.iter().zip(x) {
            s[l] += xv;
        }
        s
    }

    /// Exact additions for the segment-sum stage: one add per input beyond
    /// the first in each cluster, i.e. K_active - C.
    pub fn segment_additions(&self) -> usize {
        self.num_inputs() - self.num_clusters()
    }

    /// y = G segsum(x) — the eq. (10) evaluation.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.centroids.matvec(&self.segment_sums(x))
    }

    /// Equivalent expanded dense matrix (centroid per column).
    pub fn expand(&self) -> Matrix {
        let mut out = Matrix::zeros(self.centroids.rows(), self.labels.len());
        for (col, &l) in self.labels.iter().enumerate() {
            for r in 0..self.centroids.rows() {
                *out.at_mut(r, col) = self.centroids.at(r, l);
            }
        }
        out
    }

    /// Total additions when the centroid product uses CSD (no LCC).
    pub fn additions_with_csd(&self, fmt: FixedPointFormat) -> usize {
        self.segment_additions() + matrix_csd_adders(&self.centroids, fmt)
    }

    /// Decompose the centroid matrix with LCC; returns the combined
    /// shared+LCC representation, with engine tuning from the
    /// `LCCNN_EXEC_*` environment.
    #[deprecated(
        since = "0.3.0",
        note = "compose stages with `crate::compress::Pipeline` (recipe-driven, reported), \
                or call `with_lcc_exec` with explicit engine tuning"
    )]
    pub fn with_lcc(&self, cfg: &LccConfig) -> SharedLcc {
        self.with_lcc_exec(cfg, ExecConfig::from_env())
    }

    /// Like [`SharedLayer::with_lcc`] with explicit engine tuning.
    pub fn with_lcc_exec(&self, cfg: &LccConfig, exec: ExecConfig) -> SharedLcc {
        let decomposition = decompose(&self.centroids, cfg);
        let engine = BatchEngine::with_config(decomposition.graph(), exec);
        SharedLcc { layer: self.clone(), decomposition, engine }
    }
}

/// Weight sharing composed with an LCC decomposition of the centroid
/// matrix — the paper's full compression stack for one layer.
#[derive(Clone, Debug)]
pub struct SharedLcc {
    pub layer: SharedLayer,
    pub decomposition: LccDecomposition,
    /// batch-major execution engine over the LCC graph (the serving /
    /// accuracy hot path — see EXPERIMENTS.md §Perf)
    engine: BatchEngine,
}

impl SharedLcc {
    /// Total additions: segment sums + LCC program.
    pub fn additions(&self) -> usize {
        self.layer.segment_additions() + self.decomposition.additions()
    }

    /// Evaluate y = LCC(G) segsum(x) through the execution engine.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.engine.execute_one(&self.layer.segment_sums(x))
    }

    /// Batched evaluation: segment-sum every sample, then run the whole
    /// batch through the engine's lane-major kernels.
    pub fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let sums: Vec<Vec<f32>> = xs.iter().map(|x| self.layer.segment_sums(x)).collect();
        self.engine.execute_batch(&sums)
    }

    /// The engine executing the LCC program.
    pub fn engine(&self) -> &BatchEngine {
        &self.engine
    }

    /// Decompose into `(layer, decomposition, engine)` without cloning —
    /// for consumers that replace the engine (e.g. a sharded one) and
    /// must not keep the unsharded engine resident.
    pub fn into_parts(self) -> (SharedLayer, LccDecomposition, BatchEngine) {
        let SharedLcc { layer, decomposition, engine } = self;
        (layer, decomposition, engine)
    }

    /// The LCC program over the centroid inputs.
    pub fn graph(&self) -> &AdderGraph {
        self.decomposition.graph()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::affinity::{cluster_columns, AffinityParams};
    use crate::util::Rng;

    /// Matrix with duplicated column groups (ideal sharing conditions).
    fn grouped_matrix(rows: usize, groups: usize, per: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, groups * per);
        for g in 0..groups {
            let base = rng.normal_vec(rows, 1.0);
            for j in 0..per {
                for r in 0..rows {
                    *w.at_mut(r, g * per + j) = base[r] + 0.01 * rng.normal_f32();
                }
            }
        }
        w
    }

    #[test]
    fn apply_matches_expanded_dense() {
        let w = grouped_matrix(8, 3, 4, 0);
        let c = cluster_columns(&w, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&w, &c);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = rng.normal_vec(12, 1.0);
        let y_shared = sl.apply(&x);
        let y_dense = sl.expand().matvec(&x);
        for (a, b) in y_shared.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sharing_reduces_additions() {
        let w = grouped_matrix(16, 4, 8, 2);
        let c = cluster_columns(&w, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&w, &c);
        assert!(sl.num_clusters() < w.cols(), "no sharing found");
        let fmt = FixedPointFormat::default_weights();
        let baseline = matrix_csd_adders(&w, fmt);
        assert!(sl.additions_with_csd(fmt) < baseline,
                "{} !< {}", sl.additions_with_csd(fmt), baseline);
    }

    #[test]
    fn segment_additions_formula() {
        let sl = SharedLayer { centroids: Matrix::zeros(4, 3), labels: vec![0, 1, 2, 0, 1, 0] };
        assert_eq!(sl.segment_additions(), 3);
    }

    #[test]
    fn segment_sums_known() {
        let sl = SharedLayer { centroids: Matrix::zeros(1, 2), labels: vec![0, 1, 0] };
        assert_eq!(sl.segment_sums(&[1.0, 10.0, 2.0]), vec![3.0, 10.0]);
    }

    #[test]
    fn shared_lcc_apply_close_to_dense() {
        let w = grouped_matrix(32, 4, 6, 3);
        let c = cluster_columns(&w, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&w, &c);
        let slcc = sl.with_lcc_exec(&LccConfig::fs(), ExecConfig::from_env());
        let mut rng = Rng::new(4);
        let x: Vec<f32> = rng.normal_vec(w.cols(), 1.0);
        let y_ref = sl.apply(&x);
        let y_lcc = slcc.apply(&x);
        let num: f64 = y_ref.iter().zip(&y_lcc).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = y_ref.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(num / den.max(1e-12) < 1e-2, "rel err {}", num / den);
    }

    #[test]
    fn shared_lcc_apply_batch_matches_apply() {
        let w = grouped_matrix(16, 3, 5, 7);
        let c = cluster_columns(&w, &AffinityParams::default());
        let slcc = SharedLayer::from_clustering(&w, &c)
            .with_lcc_exec(&LccConfig::fs(), ExecConfig::serial());
        let mut rng = Rng::new(8);
        let xs: Vec<Vec<f32>> = (0..11).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let batch = slcc.apply_batch(&xs);
        assert_eq!(batch.len(), xs.len());
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(*y, slcc.apply(x), "batch path must match scalar path");
        }
        assert_eq!(slcc.engine().num_inputs(), slcc.layer.num_clusters());
    }

    #[test]
    fn shared_lcc_cheaper_than_shared_csd() {
        let w = grouped_matrix(64, 5, 6, 5);
        let c = cluster_columns(&w, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&w, &c);
        let fmt = FixedPointFormat::default_weights();
        let slcc = sl.with_lcc_exec(&LccConfig::fs(), ExecConfig::from_env());
        assert!(slcc.additions() < sl.additions_with_csd(fmt),
                "{} !< {}", slcc.additions(), sl.additions_with_csd(fmt));
    }

    /// The deprecated env-reading shim must stay equivalent to the
    /// explicit form it forwards to.
    #[test]
    #[allow(deprecated)]
    fn with_lcc_shim_matches_with_lcc_exec() {
        let w = grouped_matrix(16, 3, 4, 9);
        let c = cluster_columns(&w, &AffinityParams::default());
        let sl = SharedLayer::from_clustering(&w, &c);
        let a = sl.with_lcc(&LccConfig::fs());
        let b = sl.with_lcc_exec(&LccConfig::fs(), ExecConfig::from_env());
        let mut rng = Rng::new(10);
        let x: Vec<f32> = rng.normal_vec(w.cols(), 1.0);
        assert_eq!(a.apply(&x), b.apply(&x));
        assert_eq!(a.additions(), b.additions());
    }
}
