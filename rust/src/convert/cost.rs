//! Addition accounting for reformulated conv layers.
//!
//! Identical structure is charged to the CSD baseline and the compressed
//! versions (matvec adders are injected per channel), so compression
//! ratios compare like with like. Recombination is *structure-aware*:
//! channels whose matrix row is entirely zero (pruned kernels) contribute
//! no partial product, so they cost no recombination adds either — this
//! is exactly what pruning buys on the FPGA. PK assumes stride-1
//! line-buffer reuse: one column product per output position (amortized),
//! the evaluation scheme implemented (and tested) in
//! [`super::conv_forward_pk`].

use crate::tensor::{Conv2dParams, Matrix};

/// Number of output positions (oh * ow) of a conv layer.
pub fn conv_positions(h: usize, w: usize, kh: usize, kw: usize, params: Conv2dParams) -> usize {
    let (oh, ow, _, _) = super::conv_geometry(h, w, kh, kw, params);
    oh * ow
}

/// Per-layer addition accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvCost {
    /// adds in the per-channel matvecs, per output position
    pub matvec_per_position: usize,
    /// partial-output + cross-channel recombination, per output position
    pub recombine_per_position: usize,
    /// number of output positions
    pub positions: usize,
}

fn row_nonzero(m: &Matrix, r: usize) -> bool {
    m.row(r).iter().any(|&v| v != 0.0)
}

impl ConvCost {
    /// FK: matrices[k] is `co x (kh*kw)`; output n sums one partial per
    /// channel whose row n is nonzero -> `active(n) - 1` adds each.
    pub fn fk(
        positions: usize,
        matrices: &[Matrix],
        co: usize,
        cost_fn: &mut dyn FnMut(&Matrix) -> usize,
    ) -> Self {
        let matvec: usize = matrices.iter().map(|m| cost_fn(m)).sum();
        let mut recombine = 0usize;
        for n in 0..co {
            let active = matrices.iter().filter(|m| row_nonzero(m, n)).count();
            recombine += active.saturating_sub(1);
        }
        ConvCost { matvec_per_position: matvec, recombine_per_position: recombine, positions }
    }

    /// PK: matrices[k] is `(co*kw) x kh`; output n sums one partial per
    /// nonzero (channel, kernel-column) row -> `active(n) - 1` adds.
    pub fn pk(
        positions: usize,
        matrices: &[Matrix],
        co: usize,
        kw: usize,
        cost_fn: &mut dyn FnMut(&Matrix) -> usize,
    ) -> Self {
        let matvec: usize = matrices.iter().map(|m| cost_fn(m)).sum();
        let mut recombine = 0usize;
        for n in 0..co {
            let mut active = 0usize;
            for m in matrices {
                for c in 0..kw {
                    if row_nonzero(m, n * kw + c) {
                        active += 1;
                    }
                }
            }
            recombine += active.saturating_sub(1);
        }
        ConvCost { matvec_per_position: matvec, recombine_per_position: recombine, positions }
    }

    pub fn total(&self) -> usize {
        self.positions * (self.matvec_per_position + self.recombine_per_position)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Padding;
    use crate::util::Rng;

    fn dense(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn positions_same_stride1() {
        let p = Conv2dParams { stride: 1, padding: Padding::Same };
        assert_eq!(conv_positions(8, 8, 3, 3, p), 64);
    }

    #[test]
    fn positions_valid_stride2() {
        let p = Conv2dParams { stride: 2, padding: Padding::Valid };
        assert_eq!(conv_positions(7, 7, 3, 3, p), 9);
    }

    #[test]
    fn fk_cost_dense() {
        let mats = vec![dense(4, 9, 0), dense(4, 9, 1), dense(4, 9, 2)];
        let mut unit = |_: &Matrix| 7usize;
        let c = ConvCost::fk(10, &mats, 4, &mut unit);
        assert_eq!(c.matvec_per_position, 21);
        assert_eq!(c.recombine_per_position, (3 - 1) * 4);
        assert_eq!(c.total(), 10 * 29);
    }

    #[test]
    fn fk_cost_skips_pruned_rows() {
        let mut m0 = dense(4, 9, 3);
        let m1 = dense(4, 9, 4);
        // channel 0's kernel for output 2 pruned entirely
        for v in m0.row_mut(2) {
            *v = 0.0;
        }
        let mut zero = |_: &Matrix| 0usize;
        let c = ConvCost::fk(1, &[m0, m1], 4, &mut zero);
        // outputs 0,1,3: 2 partials -> 1 add; output 2: 1 partial -> 0
        assert_eq!(c.recombine_per_position, 3);
    }

    #[test]
    fn pk_cost_counts_partials() {
        // co=2, kw=3: matrices rows = 6
        let mats = vec![dense(6, 3, 5)];
        let mut zero = |_: &Matrix| 0usize;
        let c = ConvCost::pk(10, &mats, 2, 3, &mut zero);
        // each output: 3 partials -> 2 adds
        assert_eq!(c.recombine_per_position, 4);
        assert_eq!(c.total(), 40);
    }

    #[test]
    fn pk_cost_skips_pruned_columns() {
        let mut m = dense(6, 3, 6);
        // output 0, kernel-column 1 pruned
        for v in m.row_mut(1) {
            *v = 0.0;
        }
        let mut zero = |_: &Matrix| 0usize;
        let c = ConvCost::pk(1, &[m], 2, 3, &mut zero);
        assert_eq!(c.recombine_per_position, 1 + 2); // output0: 2 partials, output1: 3
    }

    #[test]
    fn fully_pruned_channel_costs_nothing() {
        let zero_m = Matrix::zeros(4, 9);
        let mut cost = |m: &Matrix| m.nnz();
        let c = ConvCost::fk(5, &[zero_m], 4, &mut cost);
        assert_eq!(c.total(), 0);
    }
}
