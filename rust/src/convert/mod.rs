//! Convolution → matrix-vector reformulations (paper Sec. III-D).
//!
//! A conv layer with K input maps and N kernels of size O×O becomes, per
//! input channel k, a constant matrix:
//!
//! * **FK (full kernel)**: `W_k ∈ R^{N × O²}` — row n is kernel (k, n)
//!   flattened; one matvec per output position per channel against the
//!   flattened receptive field.
//! * **PK (partial kernel)**: `W_k ∈ R^{N·O × O}` — row (n, c) is column
//!   c of kernel (k, n); one matvec per *image column* of the receptive
//!   field, partial outputs recombined across the O column offsets. The
//!   matrix is O× taller and O× narrower — the aspect ratio LCC wants.
//!
//! Both forwards are tested for exact equivalence against
//! [`crate::tensor::conv2d`], and [`ConvCost`] gives the addition
//! accounting used by the Table-I bench (identical structure for the CSD
//! baseline and the LCC-compressed versions, so ratios are consistent).

mod cost;
mod fk;
mod pk;

pub use cost::{conv_positions, ConvCost};
pub use fk::{conv_forward_fk, fk_matrices};
pub use pk::{conv_forward_pk, pk_matrices};

use crate::tensor::{Conv2dParams, Padding};

/// Output spatial dims + padding offsets for a conv (SAME/VALID).
pub(crate) fn conv_geometry(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    params: Conv2dParams,
) -> (usize, usize, isize, isize) {
    let s = params.stride;
    match params.padding {
        Padding::Same => {
            let oh = h.div_ceil(s);
            let ow = w.div_ceil(s);
            let ph = (((oh - 1) * s + kh).saturating_sub(h) / 2) as isize;
            let pw = (((ow - 1) * s + kw).saturating_sub(w) / 2) as isize;
            (oh, ow, ph, pw)
        }
        Padding::Valid => ((h - kh) / s + 1, (w - kw) / s + 1, 0, 0),
    }
}
