//! Partial-kernel (PK) reformulation: one `(N·kw) × kh` matrix per input
//! channel; rows are single kernel columns (paper Sec. III-D, footnote 4:
//! columns are used here, rows work equally).
//!
//! The same image column feeds the kernel columns of `kw` adjacent output
//! positions, so the forward pass computes each column product once per
//! (row-strip, image-column) and recombines — the line-buffer evaluation
//! an FPGA implementation would use.

use super::conv_geometry;
use crate::tensor::{Conv2dParams, Matrix, Tensor4};
use std::collections::HashMap;

/// Extract PK matrices from an HWIO kernel: element `[n*kw + c, r]` of
/// matrix k is `kernel[r, c, k, n]` (kernel column c of output n).
pub fn pk_matrices(kernel: &Tensor4) -> Vec<Matrix> {
    let (kh, kw, ci, co) = kernel.shape();
    (0..ci)
        .map(|k| {
            let mut m = Matrix::zeros(co * kw, kh);
            for n in 0..co {
                for c in 0..kw {
                    for r in 0..kh {
                        *m.at_mut(n * kw + c, r) = kernel.at(r, c, k, n);
                    }
                }
            }
            m
        })
        .collect()
}

/// Forward pass through the PK formulation. `apply(k, col)` multiplies
/// the channel-k PK matrix by one kh-long image column, returning the
/// `co*kw` partial products; results are cached per image column within a
/// row strip and recombined across the kw offsets.
pub fn conv_forward_pk(
    input: &Tensor4,
    kernel_shape: (usize, usize, usize, usize),
    params: Conv2dParams,
    mut apply: impl FnMut(usize, &[f32]) -> Vec<f32>,
) -> Tensor4 {
    let (n, h, w, ci) = input.shape();
    let (kh, kw, kci, co) = kernel_shape;
    assert_eq!(ci, kci, "channel mismatch");
    let (oh, ow, ph, pw) = conv_geometry(h, w, kh, kw, params);
    let s = params.stride;
    let mut out = Tensor4::zeros(n, oh, ow, co);
    let mut col = vec![0.0f32; kh];
    for b in 0..n {
        for oy in 0..oh {
            let iy0 = (oy * s) as isize - ph;
            for k in 0..ci {
                // column products for this (batch, row strip, channel)
                let mut cache: HashMap<isize, Vec<f32>> = HashMap::new();
                for ox in 0..ow {
                    for c in 0..kw {
                        let ix = (ox * s) as isize - pw + c as isize;
                        let partials = cache.entry(ix).or_insert_with(|| {
                            for (r, cv) in col.iter_mut().enumerate() {
                                *cv = input.at_padded(b, iy0 + r as isize, ix, k);
                            }
                            apply(k, &col)
                        });
                        debug_assert_eq!(partials.len(), co * kw);
                        for n_out in 0..co {
                            *out.at_mut(b, oy, ox, n_out) += partials[n_out * kw + c];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv2d, Padding};
    use crate::util::Rng;

    fn rand_t4(n: usize, h: usize, w: usize, c: usize, seed: u64) -> Tensor4 {
        let mut rng = Rng::new(seed);
        Tensor4::from_vec(n, h, w, c, rng.normal_vec(n * h * w * c, 1.0))
    }

    #[test]
    fn pk_matrix_layout() {
        let mut kernel = Tensor4::zeros(3, 3, 1, 2);
        *kernel.at_mut(2, 1, 0, 1) = 7.0; // r=2, c=1, k=0, n=1
        let mats = pk_matrices(&kernel);
        assert_eq!(mats[0].rows(), 6); // co*kw = 2*3
        assert_eq!(mats[0].cols(), 3); // kh
        assert_eq!(mats[0].at(1 * 3 + 1, 2), 7.0);
    }

    #[test]
    fn pk_taller_than_fk() {
        let kernel = rand_t4(3, 3, 4, 8, 0);
        let fkm = super::super::fk_matrices(&kernel);
        let pkm = pk_matrices(&kernel);
        assert_eq!(fkm[0].rows(), 8);
        assert_eq!(fkm[0].cols(), 9);
        assert_eq!(pkm[0].rows(), 24);
        assert_eq!(pkm[0].cols(), 3);
        // same number of entries, steeper aspect ratio
        assert_eq!(fkm[0].rows() * fkm[0].cols(), pkm[0].rows() * pkm[0].cols());
    }

    #[test]
    fn pk_forward_matches_direct_conv_same() {
        let input = rand_t4(2, 6, 6, 3, 1);
        let kernel = rand_t4(3, 3, 3, 4, 2);
        let params = Conv2dParams { stride: 1, padding: Padding::Same };
        let want = conv2d(&input, &kernel, params);
        let mats = pk_matrices(&kernel);
        let got = conv_forward_pk(&input, kernel.shape(), params, |k, x| mats[k].matvec(x));
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn pk_forward_matches_direct_conv_stride2() {
        let input = rand_t4(1, 8, 8, 2, 3);
        let kernel = rand_t4(3, 3, 2, 3, 4);
        let params = Conv2dParams { stride: 2, padding: Padding::Same };
        let want = conv2d(&input, &kernel, params);
        let mats = pk_matrices(&kernel);
        let got = conv_forward_pk(&input, kernel.shape(), params, |k, x| mats[k].matvec(x));
        assert_eq!(want.shape(), got.shape());
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn column_products_are_reused_at_stride1() {
        let input = rand_t4(1, 5, 5, 1, 5);
        let kernel = rand_t4(3, 3, 1, 2, 6);
        let params = Conv2dParams { stride: 1, padding: Padding::Valid };
        let mats = pk_matrices(&kernel);
        let mut calls = 0usize;
        let _ = conv_forward_pk(&input, kernel.shape(), params, |k, x| {
            calls += 1;
            mats[k].matvec(x)
        });
        // valid 5x5 / 3x3 -> oh=ow=3; per strip 5 unique columns, 3 strips
        assert_eq!(calls, 15, "expected column reuse");
    }
}
