//! Full-kernel (FK) reformulation: one `N × (kh·kw)` matrix per input
//! channel.

use super::conv_geometry;
use crate::tensor::{Conv2dParams, Matrix, Tensor4};

/// Extract the FK matrices from an HWIO kernel: element `[n, ky*kw+kx]`
/// of matrix k is `kernel[ky, kx, k, n]`.
pub fn fk_matrices(kernel: &Tensor4) -> Vec<Matrix> {
    let (kh, kw, ci, co) = kernel.shape();
    (0..ci)
        .map(|k| {
            let mut m = Matrix::zeros(co, kh * kw);
            for n in 0..co {
                for ky in 0..kh {
                    for kx in 0..kw {
                        *m.at_mut(n, ky * kw + kx) = kernel.at(ky, kx, k, n);
                    }
                }
            }
            m
        })
        .collect()
}

/// Forward pass through the FK formulation:
/// `y[:, p] = Σ_k W_k x_k(p)` with `x_k(p)` the flattened receptive field.
///
/// `apply` evaluates one per-channel matvec — inject `|k, x| mats[k].matvec(x)`
/// for the dense path or an adder-graph execution for the compressed path.
pub fn conv_forward_fk(
    input: &Tensor4,
    kernel_shape: (usize, usize, usize, usize),
    params: Conv2dParams,
    mut apply: impl FnMut(usize, &[f32]) -> Vec<f32>,
) -> Tensor4 {
    let (n, h, w, ci) = input.shape();
    let (kh, kw, kci, co) = kernel_shape;
    assert_eq!(ci, kci, "channel mismatch");
    let (oh, ow, ph, pw) = conv_geometry(h, w, kh, kw, params);
    let s = params.stride;
    let mut out = Tensor4::zeros(n, oh, ow, co);
    let mut patch = vec![0.0f32; kh * kw];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for k in 0..ci {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * s + ky) as isize - ph;
                            let ix = (ox * s + kx) as isize - pw;
                            patch[ky * kw + kx] = input.at_padded(b, iy, ix, k);
                        }
                    }
                    let y = apply(k, &patch);
                    debug_assert_eq!(y.len(), co);
                    for (c_out, &v) in y.iter().enumerate() {
                        *out.at_mut(b, oy, ox, c_out) += v;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv2d, Padding};
    use crate::util::Rng;

    fn rand_t4(n: usize, h: usize, w: usize, c: usize, seed: u64) -> Tensor4 {
        let mut rng = Rng::new(seed);
        Tensor4::from_vec(n, h, w, c, rng.normal_vec(n * h * w * c, 1.0))
    }

    #[test]
    fn fk_matrix_layout() {
        let mut kernel = Tensor4::zeros(2, 2, 1, 3);
        *kernel.at_mut(1, 0, 0, 2) = 5.0; // ky=1,kx=0,k=0,n=2
        let mats = fk_matrices(&kernel);
        assert_eq!(mats.len(), 1);
        assert_eq!(mats[0].rows(), 3);
        assert_eq!(mats[0].cols(), 4);
        assert_eq!(mats[0].at(2, 2), 5.0); // row n=2, col ky*kw+kx = 2
    }

    #[test]
    fn fk_forward_matches_direct_conv_same() {
        let input = rand_t4(2, 6, 6, 3, 0);
        let kernel = rand_t4(3, 3, 3, 4, 1); // (kh,kw,ci,co) reuse of T4
        let params = Conv2dParams { stride: 1, padding: Padding::Same };
        let want = conv2d(&input, &kernel, params);
        let mats = fk_matrices(&kernel);
        let got = conv_forward_fk(&input, kernel.shape(), params, |k, x| mats[k].matvec(x));
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn fk_forward_matches_direct_conv_stride2_valid() {
        let input = rand_t4(1, 7, 7, 2, 2);
        let kernel = rand_t4(3, 3, 2, 5, 3);
        let params = Conv2dParams { stride: 2, padding: Padding::Valid };
        let want = conv2d(&input, &kernel, params);
        let mats = fk_matrices(&kernel);
        let got = conv_forward_fk(&input, kernel.shape(), params, |k, x| mats[k].matvec(x));
        assert_eq!(want.shape(), got.shape());
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
