//! [`ReplicatedExecutor`]: N same-range replicas behind one
//! [`Executor`], with client-side failover.
//!
//! A replica set is just another `Arc<dyn Executor>` for one output
//! range, so [`crate::exec::ShardedExecutor::from_executors`] needs no
//! replica awareness: the gather path sees one engine per range, and
//! this wrapper walks its replicas in order until one serves the batch.
//!
//! Failover policy:
//! * [`ExecError::Unavailable`] from a replica (dead, cooling down, or
//!   draining) → try the next replica. Each [`super::RemoteExecutor`]
//!   replica keeps its own dead-cooldown, so a down replica costs one
//!   fast typed error — not a dial timeout — on every later batch until
//!   its half-open probe recovers it.
//! * [`ExecError::Failed`] (the worker *rejected* the batch or its
//!   engine failed) → returned immediately; another replica would give
//!   the same answer for the same request.
//! * All replicas unavailable → one summarizing
//!   [`ExecError::Unavailable`], so the shard sheds exactly like an
//!   unreplicated one.
//!
//! A batch served by any replica is bit-identical to any other: every
//! replica runs the same artifact range and the wire's `f32` lanes
//! round-trip losslessly.

use crate::exec::{ExecError, ExecHealth, Executor};
use crate::metrics::Metrics;
use std::sync::Arc;

/// One output range served by N interchangeable replicas, tried in
/// order with failover on unavailability.
pub struct ReplicatedExecutor {
    replicas: Vec<Arc<dyn Executor>>,
    num_inputs: usize,
    num_outputs: usize,
    metrics: Option<Arc<Metrics>>,
    metric_prefix: String,
}

impl ReplicatedExecutor {
    /// Wrap `replicas` (at least one; all must agree on shape).
    pub fn from_replicas(replicas: Vec<Arc<dyn Executor>>) -> anyhow::Result<ReplicatedExecutor> {
        let Some(first) = replicas.first() else {
            anyhow::bail!("a replica set needs at least one replica");
        };
        let (num_inputs, num_outputs) = (first.num_inputs(), first.num_outputs());
        for (j, r) in replicas.iter().enumerate() {
            anyhow::ensure!(
                (r.num_inputs(), r.num_outputs()) == (num_inputs, num_outputs),
                "replica {j} serves {}x{}, replica 0 serves {num_inputs}x{num_outputs}",
                r.num_inputs(),
                r.num_outputs()
            );
        }
        Ok(ReplicatedExecutor {
            replicas,
            num_inputs,
            num_outputs,
            metrics: None,
            metric_prefix: String::new(),
        })
    }

    /// Count `<prefix>failover` on `metrics` whenever a batch is served
    /// by a non-primary replica.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>, prefix: &str) -> Self {
        self.metrics = Some(metrics);
        self.metric_prefix = prefix.to_string();
        self
    }

    /// Number of replicas in the set.
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn bump(&self, series: &str) {
        if let Some(m) = &self.metrics {
            m.incr(&format!("{}{series}", self.metric_prefix), 1);
        }
    }
}

impl std::fmt::Debug for ReplicatedExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplicatedExecutor").field("replicas", &self.replicas.len()).finish()
    }
}

impl Executor for ReplicatedExecutor {
    fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    fn num_outputs(&self) -> usize {
        self.num_outputs
    }

    fn name(&self) -> &'static str {
        "replica-set"
    }

    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        let mut out = Vec::new();
        for (j, r) in self.replicas.iter().enumerate() {
            for (label, h) in r.health_report() {
                let key = if label.is_empty() {
                    format!("replica.{j}")
                } else {
                    format!("replica.{j}.{label}")
                };
                out.push((key, h));
            }
        }
        out
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        if let Err(e) = self.try_execute_batch_into(xs, ys) {
            panic!("replica set: {e}");
        }
    }

    fn try_execute_batch_into(
        &self,
        xs: &[Vec<f32>],
        ys: &mut Vec<Vec<f32>>,
    ) -> Result<(), ExecError> {
        let mut last: Option<ExecError> = None;
        for (j, r) in self.replicas.iter().enumerate() {
            match r.try_execute_batch_into(xs, ys) {
                Ok(()) => {
                    if j > 0 {
                        self.bump("failover");
                    }
                    return Ok(());
                }
                Err(e @ ExecError::Failed { .. }) => return Err(e),
                Err(e) => last = Some(e),
            }
        }
        match last.expect("at least one replica was attempted") {
            ExecError::Unavailable { shard, message } => {
                let message =
                    format!("all {} replica(s) unavailable; last: {message}", self.replicas.len());
                Err(ExecError::Unavailable { shard, message })
            }
            e => Err(e),
        }
    }
}
