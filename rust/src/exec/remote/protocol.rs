//! The remote-shard wire protocol: hand-rolled length-prefixed frames
//! over a byte stream. The tree is offline-vendored (no tokio, no
//! serde), so the framing is explicit little-endian structs:
//!
//! ```text
//! header (20 bytes, LE): magic "LCCR" | version u16 | kind u8 | lanes u8
//!                        | req_id u64 | payload_len u32
//! ```
//!
//! Kinds: `Hello`/`HelloOk` handshake (the worker reports its input
//! arity, output count, owned output-column range and exec mode),
//! `Exec`/`ExecOk` batch round-trips, `Ping`/`PingOk` health probes
//! (the worker answers with a one-byte serving/draining status),
//! `Drain` (the worker finishes in-flight batches and refuses new
//! ones with [`ERR_DRAINING`]) and a typed `Err` frame (`u16` code +
//! UTF-8 message). Batch payloads are `rows u32 | width u32 |
//! rows×width` lane values — **`f32` lanes are the only batch dtype
//! spoken on the wire**, for both `exec_mode = float|fixed` (an `f32`
//! round-trips losslessly, so remote results stay bit-identical to
//! local execution). The `i32` lane tag and its codec exist but are
//! *reserved*: nothing sends them today, the worker refuses `i32`
//! request lanes with a typed `ERR_BAD_REQUEST`, and the client
//! rejects `i32` reply lanes with [`ProtocolError::UnsupportedLanes`].
//!
//! Robustness contract: every decoder returns a typed
//! [`ProtocolError`] — never a panic — and the payload length is
//! checked against [`MAX_FRAME`] *before* any allocation, so a hostile
//! or corrupt length prefix cannot drive unbounded memory growth.

use std::io::{Read, Write};

/// Frame magic, `b"LCCR"` read little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"LCCR");
/// Protocol version spoken by this build; mismatches are rejected.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Hard cap on a payload: a corrupt length prefix must bound, not
/// drive, the allocation it implies.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Error-frame code: the request itself was malformed (bad arity,
/// undecodable batch). Not retriable.
pub const ERR_BAD_REQUEST: u16 = 1;
/// Error-frame code: the worker's engine failed. Not retriable.
pub const ERR_EXEC: u16 = 2;
/// Error-frame code: the stream desynchronized (garbage frame); the
/// worker closes the connection after sending this.
pub const ERR_PROTOCOL: u16 = 3;
/// Error-frame code: the worker is draining — it finishes batches
/// already executing but refuses new ones. Retrying the *same* worker
/// cannot help; the client treats the shard as unavailable (failover
/// to a replica, or shed) and lets the cooldown probe rediscover it.
pub const ERR_DRAINING: u16 = 4;

/// Frame kind tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// client → worker: request the shard's shape.
    Hello = 1,
    /// worker → client: [`ShardInfo`] payload.
    HelloOk = 2,
    /// client → worker: one batch of input rows.
    Exec = 3,
    /// worker → client: the batch's output rows.
    ExecOk = 4,
    /// worker → client: typed failure (`u16` code + message).
    Err = 5,
    /// client → worker: liveness/health probe (empty payload).
    Ping = 6,
    /// worker → client: one-byte worker status (see
    /// [`encode_worker_status`]). Also the ack for a `Drain` frame.
    PingOk = 7,
    /// client → worker: enter drain mode — finish in-flight batches,
    /// refuse new ones with [`ERR_DRAINING`]. Acked with `PingOk`.
    Drain = 8,
}

impl Kind {
    fn parse(v: u8) -> Option<Kind> {
        match v {
            1 => Some(Kind::Hello),
            2 => Some(Kind::HelloOk),
            3 => Some(Kind::Exec),
            4 => Some(Kind::ExecOk),
            5 => Some(Kind::Err),
            6 => Some(Kind::Ping),
            7 => Some(Kind::PingOk),
            8 => Some(Kind::Drain),
            _ => None,
        }
    }
}

/// Lane dtype tag for batch payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lanes {
    /// No lane payload (handshake and error frames).
    None = 0,
    /// Little-endian `f32` values.
    F32 = 1,
    /// Little-endian `i32` values (raw fixed-point mantissas).
    I32 = 2,
}

impl Lanes {
    fn parse(v: u8) -> Option<Lanes> {
        match v {
            0 => Some(Lanes::None),
            1 => Some(Lanes::F32),
            2 => Some(Lanes::I32),
            _ => None,
        }
    }
}

/// Typed failure of the wire layer. Every decode path lands here —
/// never a panic, never an unbounded allocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtocolError {
    /// The stream does not start with [`MAGIC`].
    BadMagic(u32),
    /// The peer speaks a different [`VERSION`].
    UnsupportedVersion(u16),
    /// Unknown [`Kind`] tag.
    UnknownKind(u8),
    /// Unknown [`Lanes`] tag.
    UnknownLanes(u8),
    /// A *known* lane tag that this build does not speak for the frame
    /// at hand (batches are `f32`-only on the wire; `i32` is reserved).
    UnsupportedLanes(u8),
    /// The length prefix exceeds the configured cap.
    FrameTooLarge { len: u32, max: u32 },
    /// The stream ended mid-frame (also: clean EOF between frames).
    Truncated,
    /// A read or write hit the socket timeout.
    TimedOut,
    /// The frame parsed but its payload is inconsistent.
    BadPayload(String),
    /// The peer answered with a typed error frame.
    Remote { code: u16, message: String },
    /// Any other transport failure.
    Io(String),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            ProtocolError::UnsupportedVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            ProtocolError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            ProtocolError::UnknownLanes(l) => write!(f, "unknown lane dtype {l}"),
            ProtocolError::UnsupportedLanes(l) => {
                write!(f, "unsupported lane dtype {l} (batches are f32-only on the wire)")
            }
            ProtocolError::FrameTooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max}-byte cap")
            }
            ProtocolError::Truncated => write!(f, "stream ended mid-frame"),
            ProtocolError::TimedOut => write!(f, "socket timed out"),
            ProtocolError::BadPayload(msg) => write!(f, "bad payload: {msg}"),
            ProtocolError::Remote { code, message } => write!(f, "remote error {code}: {message}"),
            ProtocolError::Io(msg) => write!(f, "transport: {msg}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

fn io_err(e: std::io::Error) -> ProtocolError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::UnexpectedEof => ProtocolError::Truncated,
        ErrorKind::WouldBlock | ErrorKind::TimedOut => ProtocolError::TimedOut,
        _ => ProtocolError::Io(e.to_string()),
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: Kind,
    pub lanes: Lanes,
    pub req_id: u64,
    pub payload: Vec<u8>,
}

/// Write one frame (header + payload) and flush.
pub fn write_frame(
    w: &mut impl Write,
    kind: Kind,
    lanes: Lanes,
    req_id: u64,
    payload: &[u8],
) -> Result<(), ProtocolError> {
    if payload.len() > MAX_FRAME as usize {
        let len = payload.len().min(u32::MAX as usize) as u32;
        return Err(ProtocolError::FrameTooLarge { len, max: MAX_FRAME });
    }
    let len = payload.len() as u32;
    let mut hdr = [0u8; HEADER_LEN];
    hdr[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    hdr[4..6].copy_from_slice(&VERSION.to_le_bytes());
    hdr[6] = kind as u8;
    hdr[7] = lanes as u8;
    hdr[8..16].copy_from_slice(&req_id.to_le_bytes());
    hdr[16..20].copy_from_slice(&len.to_le_bytes());
    w.write_all(&hdr).map_err(io_err)?;
    w.write_all(payload).map_err(io_err)?;
    w.flush().map_err(io_err)
}

/// Read one frame. `max_frame` (clamped to [`MAX_FRAME`]) bounds the
/// payload allocation; the check runs before any buffer is created.
pub fn read_frame(r: &mut impl Read, max_frame: u32) -> Result<Frame, ProtocolError> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).map_err(io_err)?;
    let magic = u32::from_le_bytes(hdr[0..4].try_into().expect("4-byte slice"));
    if magic != MAGIC {
        return Err(ProtocolError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(hdr[4..6].try_into().expect("2-byte slice"));
    if version != VERSION {
        return Err(ProtocolError::UnsupportedVersion(version));
    }
    let kind = Kind::parse(hdr[6]).ok_or(ProtocolError::UnknownKind(hdr[6]))?;
    let lanes = Lanes::parse(hdr[7]).ok_or(ProtocolError::UnknownLanes(hdr[7]))?;
    let req_id = u64::from_le_bytes(hdr[8..16].try_into().expect("8-byte slice"));
    let len = u32::from_le_bytes(hdr[16..20].try_into().expect("4-byte slice"));
    let cap = max_frame.min(MAX_FRAME);
    if len > cap {
        return Err(ProtocolError::FrameTooLarge { len, max: cap });
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(io_err)?;
    Ok(Frame { kind, lanes, req_id, payload })
}

/// The shard shape a worker reports in its `HelloOk` payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    /// Input arity every request row must match.
    pub num_inputs: u32,
    /// Rows produced per sample — the width of the owned range.
    pub num_outputs: u32,
    /// First output column of the full model this shard owns.
    pub range_start: u32,
    /// One past the last owned output column.
    pub range_end: u32,
    /// 0 = float, 1 = fixed (informational; the wire carries `f32`
    /// lanes either way).
    pub mode: u8,
}

/// Encode a [`ShardInfo`] as a `HelloOk` payload (17 bytes).
pub fn encode_shard_info(info: &ShardInfo) -> Vec<u8> {
    let mut out = Vec::with_capacity(17);
    out.extend_from_slice(&info.num_inputs.to_le_bytes());
    out.extend_from_slice(&info.num_outputs.to_le_bytes());
    out.extend_from_slice(&info.range_start.to_le_bytes());
    out.extend_from_slice(&info.range_end.to_le_bytes());
    out.push(info.mode);
    out
}

/// Decode a `HelloOk` payload.
pub fn decode_shard_info(p: &[u8]) -> Result<ShardInfo, ProtocolError> {
    if p.len() != 17 {
        return Err(ProtocolError::BadPayload(format!("shard info is 17 bytes, got {}", p.len())));
    }
    let u = |i: usize| u32::from_le_bytes(p[i..i + 4].try_into().expect("4-byte slice"));
    let info = ShardInfo {
        num_inputs: u(0),
        num_outputs: u(4),
        range_start: u(8),
        range_end: u(12),
        mode: p[16],
    };
    if info.range_start >= info.range_end || info.range_end - info.range_start != info.num_outputs {
        return Err(ProtocolError::BadPayload(format!(
            "range {}..{} disagrees with {} outputs",
            info.range_start, info.range_end, info.num_outputs
        )));
    }
    Ok(info)
}

/// Wire size of a `rows`×`width` batch (`8`-byte dims + 4 bytes per
/// value), or `None` when the claim overflows u64 — hostile dims must
/// fail the size check, not wrap past it.
fn batch_bytes(rows: usize, width: usize) -> Option<u64> {
    (rows as u64)
        .checked_mul(width as u64)
        .and_then(|v| v.checked_mul(4))
        .and_then(|v| v.checked_add(8))
}

fn check_batch_size(rows: usize, width: usize) -> Result<(), ProtocolError> {
    match batch_bytes(rows, width) {
        Some(bytes) if bytes <= MAX_FRAME as u64 => Ok(()),
        bytes => {
            let len = bytes.unwrap_or(u64::MAX).min(u32::MAX as u64) as u32;
            Err(ProtocolError::FrameTooLarge { len, max: MAX_FRAME })
        }
    }
}

fn decode_batch_dims(p: &[u8]) -> Result<(usize, usize), ProtocolError> {
    if p.len() < 8 {
        let msg = format!("batch payload of {} bytes has no dims", p.len());
        return Err(ProtocolError::BadPayload(msg));
    }
    let rows = u32::from_le_bytes(p[0..4].try_into().expect("4-byte slice")) as usize;
    let width = u32::from_le_bytes(p[4..8].try_into().expect("4-byte slice")) as usize;
    // The expected size is computed in checked u64 arithmetic and
    // compared against the (already frame-capped) payload length before
    // any row allocation, so a hostile rows×width claim can neither
    // allocate anything nor wrap around the check.
    if batch_bytes(rows, width) != Some(p.len() as u64) {
        return Err(ProtocolError::BadPayload(format!(
            "batch claims {rows}x{width}, payload is {} bytes",
            p.len()
        )));
    }
    Ok((rows, width))
}

/// Encode a rectangular batch of `f32` rows (`rows u32 | width u32 |
/// values`). Ragged batches are rejected.
pub fn encode_rows_f32(rows: &[Vec<f32>]) -> Result<Vec<u8>, ProtocolError> {
    let width = rows.first().map(Vec::len).unwrap_or(0);
    check_batch_size(rows.len(), width)?;
    let mut out = Vec::with_capacity(8 + rows.len() * width * 4);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    out.extend_from_slice(&(width as u32).to_le_bytes());
    for row in rows {
        if row.len() != width {
            return Err(ProtocolError::BadPayload(format!(
                "ragged batch: row of {} values in a width-{width} batch",
                row.len()
            )));
        }
        for v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(out)
}

/// Decode a batch of `f32` rows.
pub fn decode_rows_f32(p: &[u8]) -> Result<Vec<Vec<f32>>, ProtocolError> {
    let (rows, width) = decode_batch_dims(p)?;
    let mut out = Vec::with_capacity(rows);
    let mut off = 8;
    for _ in 0..rows {
        let mut row = Vec::with_capacity(width);
        for _ in 0..width {
            row.push(f32::from_le_bytes(p[off..off + 4].try_into().expect("4-byte slice")));
            off += 4;
        }
        out.push(row);
    }
    Ok(out)
}

/// Encode a rectangular batch of `i32` rows (raw fixed mantissas).
pub fn encode_rows_i32(rows: &[Vec<i32>]) -> Result<Vec<u8>, ProtocolError> {
    let width = rows.first().map(Vec::len).unwrap_or(0);
    check_batch_size(rows.len(), width)?;
    let mut out = Vec::with_capacity(8 + rows.len() * width * 4);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    out.extend_from_slice(&(width as u32).to_le_bytes());
    for row in rows {
        if row.len() != width {
            return Err(ProtocolError::BadPayload(format!(
                "ragged batch: row of {} values in a width-{width} batch",
                row.len()
            )));
        }
        for v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(out)
}

/// Decode a batch of `i32` rows.
pub fn decode_rows_i32(p: &[u8]) -> Result<Vec<Vec<i32>>, ProtocolError> {
    let (rows, width) = decode_batch_dims(p)?;
    let mut out = Vec::with_capacity(rows);
    let mut off = 8;
    for _ in 0..rows {
        let mut row = Vec::with_capacity(width);
        for _ in 0..width {
            row.push(i32::from_le_bytes(p[off..off + 4].try_into().expect("4-byte slice")));
            off += 4;
        }
        out.push(row);
    }
    Ok(out)
}

/// Encode an `Err`-frame payload (`code u16 | UTF-8 message`).
pub fn encode_error(code: u16, message: &str) -> Vec<u8> {
    let msg = message.as_bytes();
    let take = msg.len().min(MAX_FRAME as usize - 2);
    let mut out = Vec::with_capacity(2 + take);
    out.extend_from_slice(&code.to_le_bytes());
    out.extend_from_slice(&msg[..take]);
    out
}

/// Decode an `Err`-frame payload.
pub fn decode_error(p: &[u8]) -> Result<(u16, String), ProtocolError> {
    if p.len() < 2 {
        let msg = format!("error payload of {} bytes has no code", p.len());
        return Err(ProtocolError::BadPayload(msg));
    }
    let code = u16::from_le_bytes(p[0..2].try_into().expect("2-byte slice"));
    Ok((code, String::from_utf8_lossy(&p[2..]).into_owned()))
}

/// Encode a `PingOk` payload: one status byte, `0` = serving, `1` =
/// draining.
pub fn encode_worker_status(draining: bool) -> Vec<u8> {
    vec![u8::from(draining)]
}

/// Decode a `PingOk` payload; returns `true` when the worker is
/// draining.
pub fn decode_worker_status(p: &[u8]) -> Result<bool, ProtocolError> {
    match p {
        [0] => Ok(false),
        [1] => Ok(true),
        [b] => Err(ProtocolError::BadPayload(format!("unknown worker status {b}"))),
        _ => Err(ProtocolError::BadPayload(format!("worker status is 1 byte, got {}", p.len()))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::io::Cursor;

    fn frame_bytes(kind: Kind, lanes: Lanes, req_id: u64, payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind, lanes, req_id, payload).unwrap();
        buf
    }

    #[test]
    fn frame_round_trips() {
        let bytes = frame_bytes(Kind::Exec, Lanes::F32, 42, b"payload");
        let f = read_frame(&mut Cursor::new(&bytes), MAX_FRAME).unwrap();
        assert_eq!(f.kind, Kind::Exec);
        assert_eq!(f.lanes, Lanes::F32);
        assert_eq!(f.req_id, 42);
        assert_eq!(f.payload, b"payload");
    }

    #[test]
    fn truncated_frames_are_typed_errors() {
        let bytes = frame_bytes(Kind::Exec, Lanes::F32, 7, &[1, 2, 3, 4]);
        for cut in 0..bytes.len() {
            let err = read_frame(&mut Cursor::new(&bytes[..cut]), MAX_FRAME).unwrap_err();
            assert_eq!(err, ProtocolError::Truncated, "cut at {cut}");
        }
    }

    #[test]
    fn bad_magic_version_kind_lanes_are_rejected() {
        let parse = |bytes: &[u8]| read_frame(&mut Cursor::new(bytes), MAX_FRAME).unwrap_err();
        let good = frame_bytes(Kind::Hello, Lanes::None, 0, &[]);
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(parse(&bad), ProtocolError::BadMagic(_)));
        let mut bad = good.clone();
        bad[4] = 9;
        assert_eq!(parse(&bad), ProtocolError::UnsupportedVersion(9));
        let mut bad = good.clone();
        bad[6] = 200;
        assert_eq!(parse(&bad), ProtocolError::UnknownKind(200));
        let mut bad = good;
        bad[7] = 77;
        assert_eq!(parse(&bad), ProtocolError::UnknownLanes(77));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        // A frame whose header claims a multi-GB payload: the reader
        // must reject on the prefix alone (nothing past the header
        // exists to read, and no buffer may be sized from the claim).
        let mut bytes = frame_bytes(Kind::Exec, Lanes::F32, 1, &[]);
        bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&bytes), MAX_FRAME).unwrap_err();
        assert_eq!(err, ProtocolError::FrameTooLarge { len: u32::MAX, max: MAX_FRAME });
        // A caller-chosen tighter cap also holds.
        let mut bytes = frame_bytes(Kind::Exec, Lanes::F32, 1, &[0u8; 64]);
        bytes[16..20].copy_from_slice(&64u32.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&bytes), 16).unwrap_err();
        assert_eq!(err, ProtocolError::FrameTooLarge { len: 64, max: 16 });
    }

    #[test]
    fn random_bytes_never_panic_the_reader() {
        let mut rng = Rng::new(0xF00D);
        for round in 0..2000 {
            let len = rng.below(64);
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            // Typed error or (vanishingly unlikely) a parsed frame —
            // but never a panic and never an oversized allocation.
            let _ = read_frame(&mut Cursor::new(&bytes), MAX_FRAME);
            let _ = decode_shard_info(&bytes);
            let _ = decode_rows_f32(&bytes);
            let _ = decode_rows_i32(&bytes);
            let _ = decode_error(&bytes);
            let _ = decode_worker_status(&bytes);
            let _ = round;
        }
    }

    #[test]
    fn f32_batch_round_trips() {
        let rows = vec![vec![1.0f32, -2.5, 3.25], vec![0.0, f32::MIN_POSITIVE, -0.0]];
        let decoded = decode_rows_f32(&encode_rows_f32(&rows).unwrap()).unwrap();
        assert_eq!(decoded.len(), rows.len());
        for (d, r) in decoded.iter().zip(&rows) {
            for (a, b) in d.iter().zip(r) {
                assert_eq!(a.to_bits(), b.to_bits(), "lossless to the bit");
            }
        }
        let empty = decode_rows_f32(&encode_rows_f32(&[]).unwrap()).unwrap();
        assert!(empty.is_empty(), "empty batch round-trips");
    }

    #[test]
    fn i32_batch_round_trips() {
        let rows = vec![vec![i32::MIN, -1, 0, 1, i32::MAX]];
        assert_eq!(decode_rows_i32(&encode_rows_i32(&rows).unwrap()).unwrap(), rows);
    }

    #[test]
    fn overflowing_batch_dims_are_rejected_without_panicking() {
        // rows=2^31, width=2^31: 8 + rows*width*4 wraps u64 to exactly
        // 8, the payload length of a dims-only batch — wrapping
        // arithmetic would pass validation and then try a ~48 GiB
        // allocation. The checked path must reject it as a typed error.
        let mut p = Vec::new();
        p.extend_from_slice(&(1u32 << 31).to_le_bytes());
        p.extend_from_slice(&(1u32 << 31).to_le_bytes());
        assert!(matches!(decode_rows_f32(&p), Err(ProtocolError::BadPayload(_))));
        assert!(matches!(decode_rows_i32(&p), Err(ProtocolError::BadPayload(_))));
        // Max-dims claim (u32::MAX × u32::MAX) also lands typed.
        let mut p = vec![0xFFu8; 8];
        p.extend_from_slice(&[0u8; 16]);
        assert!(matches!(decode_rows_f32(&p), Err(ProtocolError::BadPayload(_))));
    }

    #[test]
    fn ragged_and_lying_batches_are_rejected() {
        let ragged = vec![vec![1.0f32, 2.0], vec![3.0]];
        assert!(matches!(encode_rows_f32(&ragged), Err(ProtocolError::BadPayload(_))));
        let mut lying = encode_rows_f32(&[vec![1.0f32, 2.0]]).unwrap();
        lying[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_rows_f32(&lying), Err(ProtocolError::BadPayload(_))));
    }

    #[test]
    fn shard_info_and_error_payloads_round_trip() {
        let info =
            ShardInfo { num_inputs: 784, num_outputs: 5, range_start: 10, range_end: 15, mode: 1 };
        assert_eq!(decode_shard_info(&encode_shard_info(&info)).unwrap(), info);
        let mut bad = info;
        bad.range_end = 14;
        assert!(decode_shard_info(&encode_shard_info(&bad)).is_err(), "range/width disagreement");
        let (code, msg) = decode_error(&encode_error(ERR_EXEC, "boom")).unwrap();
        assert_eq!((code, msg.as_str()), (ERR_EXEC, "boom"));
        assert!(decode_error(&[1]).is_err());
    }

    #[test]
    fn health_frames_round_trip() {
        for kind in [Kind::Ping, Kind::PingOk, Kind::Drain] {
            let bytes = frame_bytes(kind, Lanes::None, 9, &[]);
            assert_eq!(read_frame(&mut Cursor::new(&bytes), MAX_FRAME).unwrap().kind, kind);
        }
        assert!(!decode_worker_status(&encode_worker_status(false)).unwrap());
        assert!(decode_worker_status(&encode_worker_status(true)).unwrap());
        assert!(decode_worker_status(&[]).is_err());
        assert!(decode_worker_status(&[2]).is_err());
        assert!(decode_worker_status(&[0, 0]).is_err());
    }
}
