//! [`ShardWorker`]: the serve side of a remote shard. It binds a TCP
//! listener and answers the wire protocol over any local
//! [`Executor`] — in production the range-restricted engine a
//! `shard-worker` process builds from an artifact dir, in tests any
//! in-process engine on an ephemeral port.
//!
//! The worker is defensive by construction: every connection runs in
//! its own thread, garbage frames get a best-effort typed error frame
//! and a close (a desynchronized stream cannot be re-synced), engine
//! failures become error frames, and nothing a client sends can panic
//! the process or allocate past [`protocol::MAX_FRAME`].
//!
//! Lifecycle: [`ShardWorker::drain`] (or a wire `Drain` frame) puts
//! the worker in drain mode — batches already executing finish and
//! their replies are sent, new `Exec` frames get a typed
//! [`protocol::ERR_DRAINING`], and `Ping` reports the draining status
//! — so an operator can retire a worker with zero dropped batches
//! (`shard-worker --drain-on <file>` polls for the hook file and exits
//! once [`ShardWorker::in_flight`] hits zero). [`ShardWorker::stop`]
//! is the hard variant: close the port and join every thread. Reads
//! distinguish *idle* from *mid-frame*: a timeout with zero bytes of
//! the current frame consumed just re-polls the stop flag, while a
//! frame that has started may stall (e.g. a large batch trickling in)
//! for up to [`FRAME_DEADLINE`] before the connection is declared
//! desynchronized — so a legitimate slow client is never cut off
//! mid-transfer, and a slow-loris peer is bounded by the deadline and
//! stalls only its own connection, never the accept loop or other
//! clients.

use super::protocol::{self, Frame, Kind, Lanes, ProtocolError, ShardInfo};
use crate::config::ExecMode;
use crate::exec::Executor;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often a blocked read wakes to poll the stop flag.
const IDLE_POLL: Duration = Duration::from_millis(100);
/// Once a frame has started arriving, how long the whole frame may
/// take before the connection is declared desynchronized. Generous so
/// a live-but-slow client can finish a large (up to 16 MiB) frame.
const FRAME_DEADLINE: Duration = Duration::from_secs(5);

/// State shared between the worker handle, the accept loop and every
/// connection handler.
struct Shared {
    engine: Arc<dyn Executor>,
    range: Range<usize>,
    mode: ExecMode,
    stop: AtomicBool,
    drain: AtomicBool,
    in_flight: AtomicUsize,
}

/// Decrements the in-flight batch counter on drop, so an engine panic
/// in one handler thread cannot wedge [`ShardWorker::drained`].
struct InFlight<'a>(&'a AtomicUsize);

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running shard server; dropping (or [`ShardWorker::stop`]) shuts
/// it down and joins every thread.
pub struct ShardWorker {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl ShardWorker {
    /// Bind `bind` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// `engine` as the shard owning output columns `range` of the full
    /// model. `mode` is reported to clients in the handshake.
    pub fn spawn(
        engine: Arc<dyn Executor>,
        range: Range<usize>,
        mode: ExecMode,
        bind: &str,
    ) -> anyhow::Result<ShardWorker> {
        anyhow::ensure!(
            engine.num_outputs() == range.len(),
            "engine serves {} outputs, range {range:?} spans {}",
            engine.num_outputs(),
            range.len()
        );
        let listener =
            TcpListener::bind(bind).map_err(|e| anyhow::anyhow!("bind shard worker {bind}: {e}"))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            engine,
            range,
            mode,
            stop: AtomicBool::new(false),
            drain: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
        });
        let state = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("lccnn-shard-accept".into())
            .spawn(move || accept_loop(listener, state))?;
        Ok(ShardWorker { addr, shared, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Enter drain mode: batches already executing finish and their
    /// replies are sent; new `Exec` frames get a typed
    /// [`protocol::ERR_DRAINING`]; pings report draining. The listener
    /// stays up so clients see the typed refusal instead of a connect
    /// error. Irreversible for the lifetime of this worker.
    pub fn drain(&self) {
        self.shared.drain.store(true, Ordering::SeqCst);
    }

    /// Whether drain mode is active (set by [`ShardWorker::drain`] or
    /// a wire `Drain` frame).
    pub fn is_draining(&self) -> bool {
        self.shared.drain.load(Ordering::SeqCst)
    }

    /// Batches currently executing on the engine.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Draining and no batch left on the engine — safe to exit.
    pub fn drained(&self) -> bool {
        self.is_draining() && self.in_flight() == 0
    }

    /// Stop accepting, close every connection and join the threads.
    /// After this returns the port is closed: in-flight client requests
    /// fail with a transport error — the failover path under test.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let state = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("lccnn-shard-conn".into())
                    .spawn(move || handle_conn(stream, state));
                match spawned {
                    Ok(h) => handlers.push(h),
                    Err(e) => log::warn!("shard worker: spawn connection handler: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("shard worker accept: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
        handlers.retain(|h| !h.is_finished());
    }
    // Close the listening socket before joining handlers, so the port
    // is provably dead by the time `stop()` returns.
    drop(listener);
    for h in handlers {
        let _ = h.join();
    }
}

/// A [`Read`] adapter over the connection socket that makes frame
/// reads timeout-safe. The socket's own read timeout is the short
/// [`IDLE_POLL`]; this wrapper turns those wakeups into three distinct
/// behaviors so `read_exact` never loses partially-consumed bytes:
///
/// * zero bytes of the current frame consumed → surface the timeout
///   (the caller treats it as idle and re-polls the stop flag);
/// * mid-frame and under [`FRAME_DEADLINE`] → keep reading, so a slow
///   client's stalled-but-live transfer resumes instead of restarting
///   frame parsing mid-stream;
/// * mid-frame past the deadline (or the worker is stopping) →
///   surface the timeout; the caller closes the connection, which is
///   the only safe answer once a frame is truly abandoned.
struct FrameReader<'a> {
    stream: &'a TcpStream,
    stop: &'a AtomicBool,
    /// When the first byte of the current frame arrived; `None` while
    /// idle between frames.
    started_at: Option<Instant>,
}

impl Read for FrameReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        use std::io::ErrorKind;
        loop {
            match self.stream.read(buf) {
                Ok(0) => return Ok(0),
                Ok(n) => {
                    self.started_at.get_or_insert_with(Instant::now);
                    return Ok(n);
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    let past_deadline =
                        self.started_at.is_some_and(|t0| t0.elapsed() >= FRAME_DEADLINE);
                    if self.started_at.is_none()
                        || past_deadline
                        || self.stop.load(Ordering::SeqCst)
                    {
                        return Err(e);
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    stream.set_nodelay(true).ok();
    // Short socket timeout so blocked reads wake to poll the stop
    // flag; FrameReader layers the idle/mid-frame policy on top.
    stream.set_read_timeout(Some(IDLE_POLL)).ok();
    stream.set_write_timeout(Some(Duration::from_secs(5))).ok();
    let mut stream = &stream;
    while !shared.stop.load(Ordering::SeqCst) {
        let mut reader = FrameReader { stream, stop: &shared.stop, started_at: None };
        let frame = match protocol::read_frame(&mut reader, protocol::MAX_FRAME) {
            Ok(f) => f,
            Err(ProtocolError::TimedOut) if reader.started_at.is_none() => continue,
            Err(ProtocolError::TimedOut) => {
                // A frame started but stalled past FRAME_DEADLINE (or
                // the worker is stopping): the stream is mid-frame and
                // cannot be re-synced — answer typed and close.
                let msg = "frame stalled mid-transfer past the deadline";
                let payload = protocol::encode_error(protocol::ERR_PROTOCOL, msg);
                let _ = protocol::write_frame(&mut stream, Kind::Err, Lanes::None, 0, &payload);
                return;
            }
            Err(ProtocolError::Truncated) => return,
            Err(e) => {
                // Garbage on the wire: answer typed, then close — after
                // a framing error the stream cannot be re-synced.
                let payload = protocol::encode_error(protocol::ERR_PROTOCOL, &e.to_string());
                let _ = protocol::write_frame(&mut stream, Kind::Err, Lanes::None, 0, &payload);
                return;
            }
        };
        let (kind, lanes, payload, close_after) = match frame.kind {
            Kind::Hello => {
                let info = ShardInfo {
                    num_inputs: shared.engine.num_inputs() as u32,
                    num_outputs: shared.engine.num_outputs() as u32,
                    range_start: shared.range.start as u32,
                    range_end: shared.range.end as u32,
                    mode: match shared.mode {
                        ExecMode::Float => 0,
                        ExecMode::Fixed => 1,
                    },
                };
                (Kind::HelloOk, Lanes::None, protocol::encode_shard_info(&info), false)
            }
            Kind::Exec if shared.drain.load(Ordering::SeqCst) => {
                let msg = "worker is draining; batch refused";
                let payload = protocol::encode_error(protocol::ERR_DRAINING, msg);
                (Kind::Err, Lanes::None, payload, false)
            }
            Kind::Exec => {
                shared.in_flight.fetch_add(1, Ordering::SeqCst);
                let _guard = InFlight(&shared.in_flight);
                match exec_reply(&shared.engine, &frame) {
                    Ok(payload) => (Kind::ExecOk, Lanes::F32, payload, false),
                    Err((code, msg)) => {
                        (Kind::Err, Lanes::None, protocol::encode_error(code, &msg), false)
                    }
                }
            }
            Kind::Ping => {
                let draining = shared.drain.load(Ordering::SeqCst);
                (Kind::PingOk, Lanes::None, protocol::encode_worker_status(draining), false)
            }
            Kind::Drain => {
                shared.drain.store(true, Ordering::SeqCst);
                (Kind::PingOk, Lanes::None, protocol::encode_worker_status(true), false)
            }
            // Server-to-client kinds arriving at the server: protocol
            // violation; answer typed and close.
            Kind::HelloOk | Kind::ExecOk | Kind::Err | Kind::PingOk => {
                let msg = format!("unexpected {:?} frame at the worker", frame.kind);
                let payload = protocol::encode_error(protocol::ERR_PROTOCOL, &msg);
                (Kind::Err, Lanes::None, payload, true)
            }
        };
        let sent = protocol::write_frame(&mut stream, kind, lanes, frame.req_id, &payload);
        if sent.is_err() || close_after {
            return;
        }
    }
}

fn exec_reply(engine: &Arc<dyn Executor>, frame: &Frame) -> Result<Vec<u8>, (u16, String)> {
    let xs = match frame.lanes {
        Lanes::F32 => protocol::decode_rows_f32(&frame.payload)
            .map_err(|e| (protocol::ERR_BAD_REQUEST, e.to_string()))?,
        Lanes::I32 => {
            let msg = "i32 request lanes are reserved, send f32".to_string();
            return Err((protocol::ERR_BAD_REQUEST, msg));
        }
        Lanes::None => return Err((protocol::ERR_BAD_REQUEST, "exec frame without lanes".into())),
    };
    for (i, x) in xs.iter().enumerate() {
        if x.len() != engine.num_inputs() {
            let msg =
                format!("request {i}: {} inputs, engine wants {}", x.len(), engine.num_inputs());
            return Err((protocol::ERR_BAD_REQUEST, msg));
        }
    }
    let mut ys = Vec::new();
    engine
        .try_execute_batch_into(&xs, &mut ys)
        .map_err(|e| (protocol::ERR_EXEC, e.to_string()))?;
    protocol::encode_rows_f32(&ys).map_err(|e| (protocol::ERR_EXEC, e.to_string()))
}
