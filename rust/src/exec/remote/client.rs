//! [`RemoteExecutor`]: the gather side of a remote shard. One value of
//! this type owns one TCP connection to one `shard-worker` process and
//! implements [`Executor`] over it, so
//! [`crate::exec::ShardedExecutor::from_executors`] can mix local
//! engines and remote shards interchangeably.
//!
//! Failure policy — a down shard must *shed, never hang*:
//! * every dial is bounded by `connect_timeout`, every response read by
//!   `read_timeout` (writes by `write_timeout`);
//! * a transport failure drops the connection and retries up to
//!   `retries` more times with exponential backoff (reconnecting and
//!   resending the batch — requests are idempotent pure functions);
//! * when every attempt fails the shard enters a `cooldown` window in
//!   which calls fail immediately (no re-dial), and the caller gets a
//!   typed [`ExecError::Unavailable`] either way;
//! * when the cooldown lapses the next call is a **half-open probe**:
//!   one cheap attempt, no retry ladder and no backoff sleeps on the
//!   serving thread. Success un-deads the shard (counting
//!   `<prefix>recovered`); failure re-arms the cooldown immediately;
//! * a worker that answers `ERR_DRAINING` is healthy but refusing new
//!   batches: the call fails over as [`ExecError::Unavailable`] and
//!   the cooldown is armed so subsequent batches shed fast until the
//!   probe rediscovers the worker;
//! * any other typed error *frame* from the worker (bad request,
//!   engine failure) is not retried — it surfaces as
//!   [`ExecError::Failed`].

use super::protocol::{self, Frame, Kind, Lanes, ProtocolError, ShardInfo, MAX_FRAME};
use crate::config::RemoteConfig;
use crate::exec::{ExecError, ExecHealth, Executor};
use crate::metrics::Metrics;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Transport tuning for one remote shard connection.
#[derive(Clone, Copy, Debug)]
pub struct RemoteOptions {
    /// TCP dial budget per attempt.
    pub connect_timeout: Duration,
    /// Per-response read budget.
    pub read_timeout: Duration,
    /// Per-request write budget.
    pub write_timeout: Duration,
    /// Additional attempts after the first transport failure.
    pub retries: u32,
    /// Backoff before retry `k` is `backoff << (k - 1)`.
    pub backoff: Duration,
    /// After all retries fail, calls shed immediately (no re-dial) for
    /// this long; the first call after the window runs a single
    /// half-open probe attempt instead of the full retry ladder.
    pub cooldown: Duration,
    /// Per-frame payload cap (clamped to [`protocol::MAX_FRAME`]).
    pub max_frame: u32,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            connect_timeout: Duration::from_millis(1000),
            read_timeout: Duration::from_millis(5000),
            write_timeout: Duration::from_millis(5000),
            retries: 2,
            backoff: Duration::from_millis(50),
            cooldown: Duration::from_millis(250),
            max_frame: MAX_FRAME,
        }
    }
}

impl RemoteOptions {
    /// Options from the deployment config (`[serve.remote]` TOML and
    /// `LCCNN_REMOTE_*` env — see [`RemoteConfig`]).
    pub fn from_config(c: &RemoteConfig) -> Self {
        RemoteOptions {
            connect_timeout: Duration::from_millis(c.connect_timeout_ms.max(1)),
            read_timeout: Duration::from_millis(c.read_timeout_ms.max(1)),
            write_timeout: Duration::from_millis(c.read_timeout_ms.max(1)),
            retries: c.retries,
            backoff: Duration::from_millis(c.backoff_ms),
            cooldown: Duration::from_millis(c.cooldown_ms.max(1)),
            ..RemoteOptions::default()
        }
    }
}

struct ConnState {
    stream: Option<TcpStream>,
    dead_until: Option<Instant>,
}

/// An [`Executor`] served by a remote `shard-worker` over TCP.
pub struct RemoteExecutor {
    addr: String,
    opts: RemoteOptions,
    info: ShardInfo,
    next_id: AtomicU64,
    conn: Mutex<ConnState>,
    metrics: Option<Arc<Metrics>>,
    metric_prefix: String,
}

impl std::fmt::Debug for RemoteExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteExecutor")
            .field("addr", &self.addr)
            .field("range", &self.range())
            .finish()
    }
}

impl RemoteExecutor {
    /// Dial `addr` and handshake: the worker reports its input arity,
    /// output count and owned output-column range. Bounded — the dial
    /// by `connect_timeout`, the handshake by `read_timeout` — and the
    /// failure is typed, never a hang.
    pub fn connect(addr: &str, opts: RemoteOptions) -> Result<Self, ExecError> {
        let (stream, info) = dial(addr, &opts).map_err(|e| ExecError::Unavailable {
            shard: addr.to_string(),
            message: e.to_string(),
        })?;
        Ok(RemoteExecutor {
            addr: addr.to_string(),
            opts,
            info,
            next_id: AtomicU64::new(1),
            conn: Mutex::new(ConnState { stream: Some(stream), dead_until: None }),
            metrics: None,
            metric_prefix: String::new(),
        })
    }

    /// Count `<prefix>retries` on `metrics` (e.g. `shard.0.` for the
    /// gather path's per-shard series).
    pub fn with_metrics(mut self, metrics: Arc<Metrics>, prefix: &str) -> Self {
        self.metrics = Some(metrics);
        self.metric_prefix = prefix.to_string();
        self
    }

    /// The worker address this executor is bound to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The output-column range of the full model the worker owns.
    pub fn range(&self) -> Range<usize> {
        self.info.range_start as usize..self.info.range_end as usize
    }

    fn bump(&self, series: &str) {
        if let Some(m) = &self.metrics {
            m.incr(&format!("{}{series}", self.metric_prefix), 1);
        }
    }

    /// Probe the worker with a `Ping` round-trip over the existing
    /// connection (dialing first if there is none). `Ok(true)` means
    /// the worker is draining. Bounded by the configured timeouts.
    pub fn ping(&self) -> Result<bool, ExecError> {
        let mut state = self.conn.lock().expect("remote conn lock");
        if state.stream.is_none() {
            let (s, _info) = dial(&self.addr, &self.opts).map_err(|e| ExecError::Unavailable {
                shard: self.addr.clone(),
                message: e.to_string(),
            })?;
            state.stream = Some(s);
        }
        let stream = state.stream.as_mut().expect("stream connected above");
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        ping_once(stream, req_id, self.opts.max_frame).map_err(|e| {
            state.stream = None;
            ExecError::Unavailable { shard: self.addr.clone(), message: e.to_string() }
        })
    }

    /// Passive health snapshot: dead-cooldown state first, then a ping
    /// over the existing connection only — no dial, so a down worker
    /// costs nothing beyond the read timeout on a stale stream.
    pub fn health(&self) -> ExecHealth {
        let mut state = self.conn.lock().expect("remote conn lock");
        if let Some(t) = state.dead_until {
            if Instant::now() < t {
                return ExecHealth::Dead;
            }
        }
        let Some(stream) = state.stream.as_mut() else {
            return ExecHealth::Unknown;
        };
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match ping_once(stream, req_id, self.opts.max_frame) {
            Ok(true) => ExecHealth::Draining,
            Ok(false) => ExecHealth::Ready,
            Err(_) => {
                state.stream = None;
                ExecHealth::Unknown
            }
        }
    }
}

fn io_str(what: &str, addr: &str, e: std::io::Error) -> ProtocolError {
    ProtocolError::Io(format!("{what} {addr}: {e}"))
}

fn dial(addr: &str, opts: &RemoteOptions) -> Result<(TcpStream, ShardInfo), ProtocolError> {
    let sockets: Vec<SocketAddr> =
        addr.to_socket_addrs().map_err(|e| io_str("resolve", addr, e))?.collect();
    // Resolution can yield several addresses (e.g. IPv6 first while the
    // worker listens on IPv4): try each in order, keeping the first
    // successful connect and the last failure for the error path.
    let mut dialed: Result<TcpStream, ProtocolError> =
        Err(ProtocolError::Io(format!("resolve {addr}: no addresses")));
    for sa in &sockets {
        match TcpStream::connect_timeout(sa, opts.connect_timeout) {
            Ok(s) => {
                dialed = Ok(s);
                break;
            }
            Err(e) => dialed = Err(io_str("connect", addr, e)),
        }
    }
    let mut stream = dialed?;
    stream.set_read_timeout(Some(opts.read_timeout)).map_err(|e| io_str("configure", addr, e))?;
    stream.set_write_timeout(Some(opts.write_timeout)).map_err(|e| io_str("configure", addr, e))?;
    stream.set_nodelay(true).ok();
    protocol::write_frame(&mut stream, Kind::Hello, Lanes::None, 0, &[])?;
    let resp = protocol::read_frame(&mut stream, opts.max_frame)?;
    match resp.kind {
        Kind::HelloOk => Ok((stream, protocol::decode_shard_info(&resp.payload)?)),
        Kind::Err => {
            let (code, message) = protocol::decode_error(&resp.payload)?;
            Err(ProtocolError::Remote { code, message })
        }
        k => Err(ProtocolError::BadPayload(format!("unexpected {k:?} reply to hello"))),
    }
}

/// One attempt's failure: retriable transport trouble vs a worker's
/// typed error frame (final — retrying an error frame cannot help).
enum Attempt {
    Retriable(ProtocolError),
    Fatal(ExecError),
}

fn ping_once(stream: &mut TcpStream, req_id: u64, max_frame: u32) -> Result<bool, ProtocolError> {
    protocol::write_frame(stream, Kind::Ping, Lanes::None, req_id, &[])?;
    let resp = protocol::read_frame(stream, max_frame)?;
    match resp.kind {
        Kind::PingOk if resp.req_id == req_id => protocol::decode_worker_status(&resp.payload),
        Kind::Err => {
            let (code, message) = protocol::decode_error(&resp.payload)?;
            Err(ProtocolError::Remote { code, message })
        }
        k => Err(ProtocolError::BadPayload(format!("unexpected {k:?} reply to ping"))),
    }
}

fn exec_once(
    stream: &mut TcpStream,
    addr: &str,
    req_id: u64,
    payload: &[u8],
    max_frame: u32,
) -> Result<Vec<Vec<f32>>, Attempt> {
    protocol::write_frame(stream, Kind::Exec, Lanes::F32, req_id, payload)
        .map_err(Attempt::Retriable)?;
    let resp: Frame = protocol::read_frame(stream, max_frame).map_err(Attempt::Retriable)?;
    if resp.req_id != req_id {
        let msg = format!("response for request {} to request {req_id}", resp.req_id);
        return Err(Attempt::Retriable(ProtocolError::BadPayload(msg)));
    }
    match resp.kind {
        Kind::ExecOk => match resp.lanes {
            Lanes::F32 => protocol::decode_rows_f32(&resp.payload).map_err(Attempt::Retriable),
            lanes => {
                // Typed: i32 (and any future) reply lanes are not spoken
                // by this build — fatal, a retry would get the same answer.
                let message = ProtocolError::UnsupportedLanes(lanes as u8).to_string();
                Err(Attempt::Fatal(ExecError::Failed { message }))
            }
        },
        Kind::Err => {
            let (code, message) =
                protocol::decode_error(&resp.payload).map_err(Attempt::Retriable)?;
            if code == protocol::ERR_DRAINING {
                // The worker is healthy but refusing new batches: fail
                // over (replica or shed) instead of failing the model.
                let shard = addr.to_string();
                return Err(Attempt::Fatal(ExecError::Unavailable { shard, message }));
            }
            let message = format!("remote error {code}: {message}");
            Err(Attempt::Fatal(ExecError::Failed { message }))
        }
        k => {
            let msg = format!("unexpected {k:?} reply to exec");
            Err(Attempt::Retriable(ProtocolError::BadPayload(msg)))
        }
    }
}

impl Executor for RemoteExecutor {
    fn num_inputs(&self) -> usize {
        self.info.num_inputs as usize
    }

    fn num_outputs(&self) -> usize {
        self.info.num_outputs as usize
    }

    fn name(&self) -> &'static str {
        "remote-shard"
    }

    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        vec![(String::new(), self.health())]
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        if let Err(e) = self.try_execute_batch_into(xs, ys) {
            panic!("remote shard {}: {e}", self.addr);
        }
    }

    fn try_execute_batch_into(
        &self,
        xs: &[Vec<f32>],
        ys: &mut Vec<Vec<f32>>,
    ) -> Result<(), ExecError> {
        if xs.is_empty() {
            ys.clear();
            return Ok(());
        }
        for (i, x) in xs.iter().enumerate() {
            if x.len() != self.info.num_inputs as usize {
                let message = format!(
                    "request {i}: {} inputs, shard {} wants {}",
                    x.len(),
                    self.addr,
                    self.info.num_inputs
                );
                return Err(ExecError::Failed { message });
            }
        }
        let payload = protocol::encode_rows_f32(xs).map_err(|e| ExecError::Failed {
            message: format!("encode batch for {}: {e}", self.addr),
        })?;
        let mut state = self.conn.lock().expect("remote conn lock");
        // Half-open probe: while the cooldown runs, shed instantly.
        // Once it lapses, keep `dead_until` armed and allow exactly one
        // cheap attempt (no retry ladder, no backoff sleeps) — success
        // below clears the flag, failure re-arms the window. This keeps
        // a still-dead worker from stalling the serving thread for the
        // whole exponential-backoff storm on every cooldown lapse.
        let half_open = match state.dead_until {
            Some(t) if Instant::now() < t => {
                let message = "shard in dead cooldown after exhausted retries".to_string();
                return Err(ExecError::Unavailable { shard: self.addr.clone(), message });
            }
            Some(_) => true,
            None => false,
        };
        let attempts = if half_open { 1 } else { self.opts.retries + 1 };
        let mut last = String::from("no attempt made");
        for attempt in 0..attempts {
            if attempt > 0 {
                self.bump("retries");
                std::thread::sleep(self.opts.backoff * (1 << (attempt - 1).min(8)));
            }
            if state.stream.is_none() {
                match dial(&self.addr, &self.opts) {
                    Ok((s, info)) => {
                        if (info.num_inputs, info.num_outputs)
                            != (self.info.num_inputs, self.info.num_outputs)
                        {
                            let message =
                                format!("shard {} changed shape across reconnect", self.addr);
                            return Err(ExecError::Failed { message });
                        }
                        state.stream = Some(s);
                    }
                    Err(e) => {
                        last = e.to_string();
                        continue;
                    }
                }
            }
            let stream = state.stream.as_mut().expect("stream connected above");
            let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
            match exec_once(stream, &self.addr, req_id, &payload, self.opts.max_frame) {
                Ok(rows) => {
                    let w = self.info.num_outputs as usize;
                    if rows.len() != xs.len() || rows.iter().any(|r| r.len() != w) {
                        state.stream = None;
                        last = format!("shard {} returned a malformed batch", self.addr);
                        continue;
                    }
                    if half_open {
                        state.dead_until = None;
                        self.bump("recovered");
                    }
                    *ys = rows;
                    return Ok(());
                }
                Err(Attempt::Fatal(e)) => {
                    if matches!(e, ExecError::Unavailable { .. }) {
                        // Draining worker: arm the cooldown so later
                        // batches fast-fail to a replica until the
                        // probe sees this worker serving again.
                        state.dead_until = Some(Instant::now() + self.opts.cooldown);
                    }
                    return Err(e);
                }
                Err(Attempt::Retriable(e)) => {
                    state.stream = None;
                    last = e.to_string();
                }
            }
        }
        // Exhausted (or the probe failed): (re-)arm the cooldown window
        // so a hot serving loop sheds instantly instead of paying the
        // full timeout per batch. (`shard.<i>.dead` is counted once per
        // shed batch by the gather path, not here.)
        state.dead_until = Some(Instant::now() + self.opts.cooldown);
        Err(ExecError::Unavailable { shard: self.addr.clone(), message: last })
    }
}
