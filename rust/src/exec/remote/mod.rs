//! Remote shard execution: the paper's adder sub-graphs scattered
//! across *processes* instead of threads.
//!
//! PR 5 built [`crate::exec::ShardedExecutor::from_executors`] as the
//! remote-shard seam — any `(output range, Arc<dyn Executor>)` list
//! gathers into one executor. This module supplies the executors that
//! cross a process boundary:
//!
//! * [`protocol`] — the hand-rolled length-prefixed binary framing
//!   (std TCP, no tokio; versioned header, request ids, `f32` batch
//!   lanes — the `i32` lane tag is reserved and refused typed on both
//!   ends — typed error frames, hard frame-size cap, `Ping`/`Drain`
//!   health frames).
//! * [`RemoteExecutor`] — the client: one connection to one worker,
//!   bounded timeouts, retry-with-backoff, dead-shard cooldown with a
//!   half-open recovery probe (`shard.<i>.recovered`).
//! * [`ShardWorker`] — the server: serves any local [`Executor`] as
//!   one output-column range (the `shard-worker` CLI subcommand wraps
//!   this around an artifact dir's range-restricted engine), with a
//!   graceful drain mode that finishes in-flight batches and refuses
//!   new ones typed.
//! * [`ReplicatedExecutor`] — N same-range replicas behind one
//!   executor with in-order failover, so killing one replica sheds
//!   nothing.
//! * [`remote_sharded_executor`] — connect a list of `host:port`
//!   workers, discover each shard's range from its handshake (workers
//!   reporting the *same* range become replicas of it), and gather
//!   them behind a [`ShardedExecutor`] with per-shard
//!   `shard.<i>.dead` / `shard.<i>.retries` / `shard.<i>.recovered` /
//!   `shard.<i>.failover` metrics.
//!
//! Bit-identicality: the wire carries `f32` lanes for both
//! `exec_mode = float|fixed` and an `f32` round-trips losslessly, so a
//! remote gather is bit-identical to the same shards executed
//! in-process — `rust/tests/remote_shards.rs` pins this against the
//! local `ShardedExecutor` and the `NaiveExecutor` oracle.

mod client;
pub mod protocol;
mod replica;
mod worker;

pub use client::{RemoteExecutor, RemoteOptions};
pub use replica::ReplicatedExecutor;
pub use worker::ShardWorker;

use crate::config::ExecConfig;
use crate::exec::{Executor, ShardedExecutor};
use crate::metrics::Metrics;
use std::ops::Range;
use std::sync::Arc;

/// Connect to every worker address, learn each shard's output range
/// from its handshake, and gather them behind one [`ShardedExecutor`].
/// Shards are ordered by range start (the address list's order does
/// not matter). Workers that report the *same* output range are
/// grouped into a [`ReplicatedExecutor`] with in-order failover; an
/// address entry may also list replicas explicitly as
/// `host:port|host:port`. Indexed metric series land on `metrics`:
/// `shard.<i>.dead` from the gather path, `shard.<i>.failover` from
/// the replica set, and `shard.<i>.retries` / `shard.<i>.recovered`
/// from the clients (replicas get a `shard.<i>.replica.<j>.` prefix).
pub fn remote_sharded_executor(
    addrs: &[String],
    opts: RemoteOptions,
    cfg: ExecConfig,
    metrics: Arc<Metrics>,
) -> anyhow::Result<ShardedExecutor> {
    let flat: Vec<&str> =
        addrs.iter().flat_map(|a| a.split('|')).map(str::trim).filter(|s| !s.is_empty()).collect();
    anyhow::ensure!(!flat.is_empty(), "no remote shard addresses given");
    let mut clients = Vec::with_capacity(flat.len());
    for addr in &flat {
        clients.push(RemoteExecutor::connect(addr, opts)?);
    }
    clients.sort_by_key(|c| (c.range().start, c.range().end));
    // Consecutive clients with an identical range are replicas of that
    // range (the sort keeps connect order within a group, so the first
    // listed replica stays primary). Distinct-but-overlapping ranges
    // fall through to `from_executors`, which rejects them typed.
    let mut groups: Vec<Vec<RemoteExecutor>> = Vec::new();
    for c in clients {
        match groups.last_mut() {
            Some(g) if g[0].range() == c.range() => g.push(c),
            _ => groups.push(vec![c]),
        }
    }
    let parts: Vec<(Range<usize>, Arc<dyn Executor>)> = groups
        .into_iter()
        .enumerate()
        .map(|(i, group)| -> anyhow::Result<(Range<usize>, Arc<dyn Executor>)> {
            let range = group[0].range();
            if group.len() == 1 {
                let c = group.into_iter().next().expect("one client in a singleton group");
                let c = c.with_metrics(Arc::clone(&metrics), &format!("shard.{i}."));
                return Ok((range, Arc::new(c) as Arc<dyn Executor>));
            }
            let replicas: Vec<Arc<dyn Executor>> = group
                .into_iter()
                .enumerate()
                .map(|(j, c)| {
                    let prefix = format!("shard.{i}.replica.{j}.");
                    let c = c.with_metrics(Arc::clone(&metrics), &prefix);
                    Arc::new(c) as Arc<dyn Executor>
                })
                .collect();
            let set = ReplicatedExecutor::from_replicas(replicas)?
                .with_metrics(Arc::clone(&metrics), &format!("shard.{i}."));
            Ok((range, Arc::new(set) as Arc<dyn Executor>))
        })
        .collect::<anyhow::Result<_>>()?;
    Ok(ShardedExecutor::from_executors(parts, cfg)?.with_metrics(metrics))
}
