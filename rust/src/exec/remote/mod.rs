//! Remote shard execution: the paper's adder sub-graphs scattered
//! across *processes* instead of threads.
//!
//! PR 5 built [`crate::exec::ShardedExecutor::from_executors`] as the
//! remote-shard seam — any `(output range, Arc<dyn Executor>)` list
//! gathers into one executor. This module supplies the executors that
//! cross a process boundary:
//!
//! * [`protocol`] — the hand-rolled length-prefixed binary framing
//!   (std TCP, no tokio; versioned header, request ids, `f32`/`i32`
//!   lane payloads, typed error frames, hard frame-size cap).
//! * [`RemoteExecutor`] — the client: one connection to one worker,
//!   bounded timeouts, retry-with-backoff, dead-shard cooldown.
//! * [`ShardWorker`] — the server: serves any local [`Executor`] as
//!   one output-column range (the `shard-worker` CLI subcommand wraps
//!   this around an artifact dir's range-restricted engine).
//! * [`remote_sharded_executor`] — connect a list of `host:port`
//!   workers, discover each shard's range from its handshake, and
//!   gather them behind a [`ShardedExecutor`] with per-shard
//!   `shard.<i>.dead` / `shard.<i>.retries` metrics.
//!
//! Bit-identicality: the wire carries `f32` lanes for both
//! `exec_mode = float|fixed` and an `f32` round-trips losslessly, so a
//! remote gather is bit-identical to the same shards executed
//! in-process — `rust/tests/remote_shards.rs` pins this against the
//! local `ShardedExecutor` and the `NaiveExecutor` oracle.

mod client;
pub mod protocol;
mod worker;

pub use client::{RemoteExecutor, RemoteOptions};
pub use worker::ShardWorker;

use crate::config::ExecConfig;
use crate::exec::{Executor, ShardedExecutor};
use crate::metrics::Metrics;
use std::ops::Range;
use std::sync::Arc;

/// Connect to every worker address, learn each shard's output range
/// from its handshake, and gather them behind one [`ShardedExecutor`].
/// Shards are ordered by range start (the address list's order does
/// not matter), indexed metric series (`shard.<i>.retries` from the
/// clients, `shard.<i>.dead` from the gather path) land on `metrics`.
pub fn remote_sharded_executor(
    addrs: &[String],
    opts: RemoteOptions,
    cfg: ExecConfig,
    metrics: Arc<Metrics>,
) -> anyhow::Result<ShardedExecutor> {
    anyhow::ensure!(!addrs.is_empty(), "no remote shard addresses given");
    let mut clients = Vec::with_capacity(addrs.len());
    for addr in addrs {
        clients.push(RemoteExecutor::connect(addr, opts)?);
    }
    clients.sort_by_key(|c| c.range().start);
    let parts: Vec<(Range<usize>, Arc<dyn Executor>)> = clients
        .into_iter()
        .enumerate()
        .map(|(i, c)| {
            let c = c.with_metrics(Arc::clone(&metrics), &format!("shard.{i}."));
            (c.range(), Arc::new(c) as Arc<dyn Executor>)
        })
        .collect();
    Ok(ShardedExecutor::from_executors(parts, cfg)?.with_metrics(metrics))
}
