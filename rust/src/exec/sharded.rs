//! Sharded execution: partition one plan across independent engines.
//!
//! The paper's adder graphs parallelize the way EIE partitions its
//! compressed matrices: disjoint output ranges are independent programs,
//! so a matrix-vector engine scales by giving each processing element a
//! slice of the rows. [`ShardPlan`] cuts an [`ExecPlan`] into per-shard
//! sub-plans along output-column ranges (each keeps the full input arity
//! and exactly the ops backward-reachable from its outputs), and
//! [`ShardedExecutor`] is the [`Executor`] that scatters a batch to the
//! per-shard engines, runs them (serially, or concurrently on the shared
//! [`WorkerPool`] / scoped threads per `pool_mode`), and gathers the
//! column slices back into batch-major rows — bit-identical to the
//! unsharded engine, because every kept op evaluates the identical
//! expression on identical operand values.
//!
//! Shard engines are held as `Arc<dyn Executor>`:
//! [`ShardedExecutor::from_executors`] accepts any executor per range —
//! the seam where remote shards plug in without touching the
//! scatter/gather layer. Since PR 7 `exec::remote` actually crosses the
//! process boundary (`RemoteExecutor` over TCP), and the gather path
//! sheds typed [`ExecError`]s with per-shard failure metrics instead of
//! assuming infallible engines.

use super::engine::BatchEngine;
use super::fixed::FixedEngine;
use super::plan::ExecPlan;
use super::workers::{self, WorkerPool};
use super::{ExecError, ExecHealth, Executor};
use crate::config::{ExecConfig, ExecMode, PoolMode, ShardMode};
use crate::graph::AdderGraph;
use crate::metrics::Metrics;
use anyhow::{bail, Result};
use std::ops::Range;
use std::sync::{Arc, Mutex};

/// One batch of per-shard output rows.
type ShardRows = Vec<Vec<f32>>;

/// Contiguous output ranges splitting `n` outputs into `shards` parts as
/// evenly as possible (the first `n % shards` ranges get one extra
/// column). `shards` is clamped to `1..=n` so no range is empty; `n = 0`
/// degenerates to a single empty range.
pub fn even_ranges(n: usize, shards: usize) -> Vec<Range<usize>> {
    let k = shards.clamp(1, n.max(1));
    let (q, r) = (n / k, n % k);
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = q + usize::from(i < r);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// An [`ExecPlan`] partitioned by output-column ranges into independent
/// sub-plans — the unit a shard ships as. Ops feeding more than one
/// range are replicated into every shard that needs them (the price of
/// independence; [`ShardPlan::total_additions`] exposes it).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    num_inputs: usize,
    num_outputs: usize,
    parts: Vec<(Range<usize>, ExecPlan)>,
}

impl ShardPlan {
    /// Partition into `shards` even contiguous output ranges.
    pub fn even(plan: &ExecPlan, shards: usize) -> Self {
        Self::from_ranges(plan, even_ranges(plan.num_outputs(), shards))
    }

    /// Partition at explicit interior cut points (uneven splits): cuts
    /// must be strictly increasing and inside `0..num_outputs`, giving
    /// `cuts.len() + 1` non-empty ranges.
    pub fn with_cuts(plan: &ExecPlan, cuts: &[usize]) -> Result<Self> {
        let n = plan.num_outputs();
        let mut bounds = Vec::with_capacity(cuts.len() + 2);
        bounds.push(0);
        for &c in cuts {
            if c == 0 || c >= n {
                bail!("cut {c} outside 1..{n}");
            }
            if *bounds.last().unwrap() >= c {
                bail!("cuts must be strictly increasing, got {cuts:?}");
            }
            bounds.push(c);
        }
        bounds.push(n);
        let ranges = bounds.windows(2).map(|w| w[0]..w[1]).collect();
        Ok(Self::from_ranges(plan, ranges))
    }

    fn from_ranges(plan: &ExecPlan, ranges: Vec<Range<usize>>) -> Self {
        let parts = ranges
            .into_iter()
            .map(|r| (r.clone(), plan.extract_output_range(r.start, r.end)))
            .collect();
        ShardPlan { num_inputs: plan.num_inputs(), num_outputs: plan.num_outputs(), parts }
    }

    pub fn num_shards(&self) -> usize {
        self.parts.len()
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.num_outputs
    }

    /// The output range each shard owns, in gather order.
    pub fn ranges(&self) -> Vec<Range<usize>> {
        self.parts.iter().map(|(r, _)| r.clone()).collect()
    }

    pub fn plans(&self) -> impl Iterator<Item = &ExecPlan> {
        self.parts.iter().map(|(_, p)| p)
    }

    /// Sum of per-shard additions. At least the unsharded count; the
    /// excess is the replication cost of cutting shared subexpressions.
    pub fn total_additions(&self) -> usize {
        self.parts.iter().map(|(_, p)| p.additions()).sum()
    }
}

struct Shard {
    range: Range<usize>,
    engine: Arc<dyn Executor>,
}

/// Scatter/gather executor over per-shard engines.
///
/// `execute_batch_into` broadcasts the batch to every shard engine
/// (sub-plans keep the full input arity, so the scatter is a broadcast),
/// runs them per [`ShardMode`] — `Serial` on the calling thread,
/// `Parallel` on the shared worker pool (`pool_mode = persistent`) or
/// per-call scoped threads (`scoped`) — and gathers each shard's rows
/// into its output-column slice of the batch-major result. Gather
/// scratch is recycled, so steady-state sharded serving allocates no
/// per-shard row buffers.
///
/// Failover: [`Executor::try_execute_batch_into`] collects a typed
/// result per shard. If any shard fails, the whole batch sheds with the
/// first error — partial rows are never gathered — and the failure is
/// counted on the executor's [`Metrics`] (`shard.<i>.dead` for an
/// unavailable shard, `shard.<i>.errors` otherwise). The remote client
/// bounds every attempt with timeouts, so a dead shard sheds the batch
/// instead of hanging it; surviving shards are untouched and serve the
/// next batch normally.
pub struct ShardedExecutor {
    shards: Vec<Shard>,
    num_inputs: usize,
    num_outputs: usize,
    mode: ShardMode,
    pool_mode: PoolMode,
    workers: Arc<WorkerPool>,
    scratch: Mutex<Vec<Vec<ShardRows>>>,
    metrics: Arc<Metrics>,
}

impl ShardedExecutor {
    /// Shard a lowered plan into `cfg.shards` local [`BatchEngine`]s
    /// (each built with `cfg`, shards reset to 1, sharing the
    /// process-wide worker pool).
    pub fn from_plan(plan: &ExecPlan, cfg: ExecConfig) -> Self {
        Self::from_shard_plan(ShardPlan::even(plan, cfg.shards), cfg)
    }

    /// Lower a graph and shard it per `cfg.shards`.
    pub fn from_graph(g: &AdderGraph, cfg: ExecConfig) -> Self {
        Self::from_plan(&ExecPlan::new(g), cfg)
    }

    /// Wrap an already-partitioned [`ShardPlan`] in local engines
    /// (float or fixed per `cfg.exec_mode`; each sub-plan lowers
    /// independently, so sharded-fixed stays bit-identical to
    /// unsharded-fixed — the integer lanes leave no scheduling freedom).
    pub fn from_shard_plan(sp: ShardPlan, cfg: ExecConfig) -> Self {
        let engine_cfg = ExecConfig { shards: 1, ..cfg };
        let ShardPlan { num_inputs, num_outputs, parts } = sp;
        let shards = parts
            .into_iter()
            .map(|(range, plan)| Shard { range, engine: engine_for_plan(plan, engine_cfg) })
            .collect();
        ShardedExecutor {
            shards,
            num_inputs,
            num_outputs,
            mode: cfg.shard_mode,
            pool_mode: cfg.pool_mode,
            workers: workers::global_pool(),
            scratch: Mutex::new(Vec::new()),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Build from externally supplied engines — the remote-shard seam.
    /// `parts` maps each output range to the executor computing it;
    /// ranges must be contiguous ascending from 0, every engine must
    /// accept `num_inputs` and produce exactly its range's width.
    pub fn from_executors(
        parts: Vec<(Range<usize>, Arc<dyn Executor>)>,
        cfg: ExecConfig,
    ) -> Result<Self> {
        let Some((first, _)) = parts.first() else {
            bail!("sharded executor needs at least one shard");
        };
        if first.start != 0 {
            bail!("first shard must start at output 0, got {}", first.start);
        }
        let num_inputs = parts[0].1.num_inputs();
        let mut next = 0;
        for (range, engine) in &parts {
            if range.start != next {
                bail!("shard ranges must be contiguous: expected start {next}, got {range:?}");
            }
            if engine.num_outputs() != range.len() {
                bail!(
                    "shard {range:?}: engine {} produces {} outputs, range wants {}",
                    engine.name(),
                    engine.num_outputs(),
                    range.len()
                );
            }
            if engine.num_inputs() != num_inputs {
                bail!(
                    "shard {range:?}: engine {} wants {} inputs, shard 0 wants {num_inputs}",
                    engine.name(),
                    engine.num_inputs()
                );
            }
            next = range.end;
        }
        let shards = parts.into_iter().map(|(range, engine)| Shard { range, engine }).collect();
        Ok(ShardedExecutor {
            shards,
            num_inputs,
            num_outputs: next,
            mode: cfg.shard_mode,
            pool_mode: cfg.pool_mode,
            workers: workers::global_pool(),
            scratch: Mutex::new(Vec::new()),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Count per-shard failures (`shard.<i>.dead` / `shard.<i>.errors`)
    /// on an externally owned sink instead of the private default —
    /// the serve CLI exposes this next to the router's metrics.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = metrics;
        self
    }

    /// The failure-counter sink (shared if set via `with_metrics`).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    fn note_failure(&self, i: usize, e: &ExecError) {
        let series = match e {
            ExecError::Unavailable { .. } => "dead",
            ExecError::Failed { .. } => "errors",
        };
        self.metrics.incr(&format!("shard.{i}.{series}"), 1);
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn mode(&self) -> ShardMode {
        self.mode
    }

    /// The output range each shard owns, in gather order.
    pub fn ranges(&self) -> Vec<Range<usize>> {
        self.shards.iter().map(|s| s.range.clone()).collect()
    }

    fn take_scratch(&self) -> Vec<ShardRows> {
        let mut parts = self.scratch.lock().unwrap().pop().unwrap_or_default();
        parts.resize_with(self.shards.len(), Vec::new);
        parts
    }

    fn put_scratch(&self, parts: Vec<ShardRows>) {
        let mut cache = self.scratch.lock().unwrap();
        if cache.len() < 64 {
            cache.push(parts);
        }
    }
}

impl Executor for ShardedExecutor {
    fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    fn num_outputs(&self) -> usize {
        self.num_outputs
    }

    fn name(&self) -> &'static str {
        "sharded-exec"
    }

    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        let mut out = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            for (label, h) in shard.engine.health_report() {
                let key = if label.is_empty() {
                    format!("shard.{i}")
                } else {
                    format!("shard.{i}.{label}")
                };
                out.push((key, h));
            }
        }
        out
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        if let Err(e) = self.try_execute_batch_into(xs, ys) {
            panic!("sharded execute failed: {e}");
        }
    }

    fn try_execute_batch_into(
        &self,
        xs: &[Vec<f32>],
        ys: &mut Vec<Vec<f32>>,
    ) -> Result<(), ExecError> {
        let b = xs.len();
        ys.resize_with(b, Vec::new);
        if b == 0 {
            return Ok(());
        }
        if self.shards.len() == 1 {
            // degenerate single shard: no scatter/gather layer needed
            let res = self.shards[0].engine.try_execute_batch_into(xs, ys);
            if let Err(e) = &res {
                self.note_failure(0, e);
            }
            return res;
        }
        let mut parts = self.take_scratch();
        let mut results: Vec<Result<(), ExecError>> = Vec::new();
        results.resize_with(self.shards.len(), || Ok(()));
        if self.mode == ShardMode::Serial {
            for ((shard, out), res) in
                self.shards.iter().zip(parts.iter_mut()).zip(results.iter_mut())
            {
                *res = shard.engine.try_execute_batch_into(xs, out);
            }
        } else {
            match self.pool_mode {
                PoolMode::Persistent => {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(self.shards.len());
                    for ((shard, out), res) in
                        self.shards.iter().zip(parts.iter_mut()).zip(results.iter_mut())
                    {
                        tasks.push(Box::new(move || {
                            *res = shard.engine.try_execute_batch_into(xs, out);
                        }));
                    }
                    if let Err(e) = self.workers.run_scoped(tasks) {
                        panic!("sharded exec worker pool: {e}");
                    }
                }
                PoolMode::Scoped => {
                    std::thread::scope(|scope| {
                        for ((shard, out), res) in
                            self.shards.iter().zip(parts.iter_mut()).zip(results.iter_mut())
                        {
                            scope.spawn(move || {
                                *res = shard.engine.try_execute_batch_into(xs, out);
                            });
                        }
                    });
                }
            }
        }
        // Failover accounting before any gather: if any shard failed,
        // the whole batch sheds with the first error — partial rows are
        // never served — and every failed shard is counted.
        let mut first: Option<ExecError> = None;
        for (i, res) in results.into_iter().enumerate() {
            if let Err(e) = res {
                self.note_failure(i, &e);
                if first.is_none() {
                    first = Some(e);
                }
            }
        }
        if let Some(e) = first {
            self.put_scratch(parts);
            return Err(e);
        }
        // gather: each shard's rows land in its output-column slice. No
        // zero-fill: the ranges tile 0..num_outputs exactly (validated
        // at construction), so every position is overwritten below.
        for y in ys.iter_mut() {
            y.resize(self.num_outputs, 0.0);
        }
        for (shard, out) in self.shards.iter().zip(parts.iter()) {
            // hard check: a short batch from a (possibly remote) shard
            // engine must fail loudly, never serve stale/zero columns
            assert_eq!(out.len(), b, "shard {:?} returned a short batch", shard.range);
            for (y, row) in ys.iter_mut().zip(out) {
                y[shard.range.clone()].copy_from_slice(row);
            }
        }
        self.put_scratch(parts);
        Ok(())
    }
}

impl std::fmt::Debug for ShardedExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedExecutor")
            .field("shards", &self.ranges())
            .field("num_inputs", &self.num_inputs)
            .field("num_outputs", &self.num_outputs)
            .field("mode", &self.mode)
            .finish()
    }
}

/// Build the executor for one lowered plan per `cfg.exec_mode`. The
/// construction seams calling this are infallible, so a plan the fixed
/// datapath rejects (non-`±2^k` coefficients, out-of-range shifts) falls
/// back to the float engine with a warning instead of failing the build.
pub(crate) fn engine_for_plan(plan: ExecPlan, cfg: ExecConfig) -> Arc<dyn Executor> {
    if cfg.exec_mode == ExecMode::Fixed {
        match FixedEngine::from_plan(&plan, cfg) {
            Ok(e) => return Arc::new(e),
            Err(e) => log::warn!("fixed lowering failed, serving float engine instead: {e}"),
        }
    }
    Arc::new(BatchEngine::from_plan(plan, cfg))
}

/// The one graph-to-engine entry point that honors `cfg.shards` and
/// `cfg.exec_mode`: a [`ShardedExecutor`] when sharding is requested and
/// the graph has more than one output to split, otherwise a plain
/// [`BatchEngine`] or [`FixedEngine`] per mode. The registry and CLI
/// build their engines through this.
pub fn engine_for_graph(g: &AdderGraph, cfg: ExecConfig) -> Arc<dyn Executor> {
    if cfg.shards > 1 && g.num_outputs() > 1 {
        Arc::new(ShardedExecutor::from_graph(g, cfg))
    } else {
        engine_for_plan(ExecPlan::new(g), cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NaiveExecutor;
    use crate::graph::{Operand, OutputSpec};
    use crate::util::Rng;

    fn wide_graph(inputs: usize, nodes: usize, outputs: usize, seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..nodes {
            let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..outputs)
            .map(|_| {
                if rng.f32() < 0.1 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn even_ranges_cover_and_balance() {
        assert_eq!(even_ranges(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(even_ranges(4, 4), vec![0..1, 1..2, 2..3, 3..4]);
        assert_eq!(even_ranges(3, 7), vec![0..1, 1..2, 2..3], "clamped to the output count");
        assert_eq!(even_ranges(5, 1), vec![0..5]);
        assert_eq!(even_ranges(0, 3), vec![0..0], "no outputs: one empty range");
    }

    #[test]
    fn with_cuts_validates() {
        let g = wide_graph(4, 20, 6, 0);
        let plan = ExecPlan::new(&g);
        let sp = ShardPlan::with_cuts(&plan, &[1, 4]).unwrap();
        assert_eq!(sp.ranges(), vec![0..1, 1..4, 4..6]);
        assert!(ShardPlan::with_cuts(&plan, &[0]).is_err(), "cut at 0");
        assert!(ShardPlan::with_cuts(&plan, &[6]).is_err(), "cut at n");
        assert!(ShardPlan::with_cuts(&plan, &[3, 3]).is_err(), "non-increasing");
    }

    #[test]
    fn shard_plan_replicates_only_whats_needed() {
        let g = wide_graph(6, 40, 8, 1);
        let plan = ExecPlan::new(&g);
        let sp = ShardPlan::even(&plan, 4);
        assert_eq!(sp.num_shards(), 4);
        assert_eq!(sp.num_inputs(), plan.num_inputs());
        assert_eq!(sp.num_outputs(), plan.num_outputs());
        let per_shard: usize = sp.plans().map(ExecPlan::additions).sum();
        assert_eq!(sp.total_additions(), per_shard, "accounting sums the shard programs");
        for p in sp.plans() {
            assert!(p.additions() <= plan.additions(), "a shard is never the whole plus more");
        }
    }

    #[test]
    fn sharded_executor_bit_identical_across_modes() {
        let mut rng = Rng::new(7);
        let g = wide_graph(5, 60, 9, 2);
        let oracle = NaiveExecutor::new(g.clone());
        for &b in &[0usize, 1, 3, 17] {
            let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let want = oracle.execute_batch(&xs);
            for mode in [ShardMode::Serial, ShardMode::Parallel] {
                for pool in [PoolMode::Scoped, PoolMode::Persistent] {
                    for shards in [1usize, 2, 3, 7] {
                        let cfg = ExecConfig {
                            threads: 2,
                            shards,
                            shard_mode: mode,
                            pool_mode: pool,
                            ..ExecConfig::default()
                        };
                        let sharded = ShardedExecutor::from_graph(&g, cfg);
                        assert_eq!(sharded.num_inputs(), g.num_inputs());
                        assert_eq!(sharded.num_outputs(), g.num_outputs());
                        let got = sharded.execute_batch(&xs);
                        assert_eq!(got, want, "b {b} mode {mode:?} pool {pool:?} x{shards}");
                    }
                }
            }
        }
    }

    #[test]
    fn steady_state_reuses_gather_scratch() {
        let g = wide_graph(4, 30, 6, 3);
        let sharded = ShardedExecutor::from_graph(
            &g,
            ExecConfig { threads: 1, shards: 3, ..ExecConfig::default() },
        );
        let xs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 4]).collect();
        let mut ys = Vec::new();
        sharded.execute_batch_into(&xs, &mut ys);
        assert_eq!(sharded.scratch.lock().unwrap().len(), 1, "scratch must be recycled");
        let first = ys.clone();
        sharded.execute_batch_into(&xs, &mut ys);
        assert_eq!(first, ys);
        assert_eq!(sharded.scratch.lock().unwrap().len(), 1);
    }

    #[test]
    fn from_executors_is_the_remote_seam() {
        let g = wide_graph(4, 25, 5, 4);
        let plan = ExecPlan::new(&g);
        let oracle = NaiveExecutor::new(g.clone());
        // hand-built shards over explicitly extracted sub-plans — the
        // same call a remote worker would make on a shipped range
        let parts: Vec<(Range<usize>, Arc<dyn Executor>)> = vec![
            (
                0..2,
                Arc::new(BatchEngine::from_plan(
                    plan.extract_output_range(0, 2),
                    ExecConfig::serial(),
                )),
            ),
            (
                2..5,
                Arc::new(BatchEngine::from_plan(
                    plan.extract_output_range(2, 5),
                    ExecConfig::serial(),
                )),
            ),
        ];
        let sharded = ShardedExecutor::from_executors(parts, ExecConfig::serial()).unwrap();
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f32>> = (0..7).map(|_| rng.normal_vec(4, 1.0)).collect();
        assert_eq!(sharded.execute_batch(&xs), oracle.execute_batch(&xs));

        // validation: gaps, wrong widths and empty part lists are rejected
        let gap: Vec<(Range<usize>, Arc<dyn Executor>)> = vec![(
            1..5,
            Arc::new(BatchEngine::from_plan(
                plan.extract_output_range(1, 5),
                ExecConfig::serial(),
            )),
        )];
        assert!(ShardedExecutor::from_executors(gap, ExecConfig::serial()).is_err());
        assert!(ShardedExecutor::from_executors(Vec::new(), ExecConfig::serial()).is_err());
    }

    #[test]
    fn fixed_mode_sharded_bit_identical_to_unsharded_fixed() {
        let g = wide_graph(5, 40, 8, 6);
        let fixed_cfg =
            ExecConfig { threads: 2, exec_mode: ExecMode::Fixed, ..ExecConfig::default() };
        let unsharded = engine_for_graph(&g, fixed_cfg);
        assert_eq!(unsharded.name(), "fixed-engine", "exec_mode must pick the fixed datapath");
        let mut rng = Rng::new(21);
        let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(5, 1.0)).collect();
        let want = unsharded.execute_batch(&xs);
        for mode in [ShardMode::Serial, ShardMode::Parallel] {
            for shards in [2usize, 3, 7] {
                let cfg = ExecConfig { shards, shard_mode: mode, ..fixed_cfg };
                let sharded = engine_for_graph(&g, cfg);
                assert_eq!(sharded.name(), "sharded-exec");
                // integer lanes: sharding must not perturb a single bit
                assert_eq!(sharded.execute_batch(&xs), want, "mode {mode:?} x{shards}");
            }
        }
    }

    #[test]
    fn engine_for_graph_honors_shards() {
        let g = wide_graph(3, 15, 4, 5);
        let plain = engine_for_graph(&g, ExecConfig::serial());
        assert_eq!(plain.name(), "batch-engine");
        let sharded = engine_for_graph(&g, ExecConfig { shards: 2, ..ExecConfig::serial() });
        assert_eq!(sharded.name(), "sharded-exec");
        let mut rng = Rng::new(11);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(3, 1.0)).collect();
        assert_eq!(plain.execute_batch(&xs), sharded.execute_batch(&xs));
    }
}
