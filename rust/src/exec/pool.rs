//! Reusable lane-buffer pool: steady-state batch execution allocates no
//! values buffers (the serving hot path calls `execute_batch` per
//! request batch; buffers grown once are recycled forever).

use std::sync::Mutex;

/// Upper bound on cached buffers. Matches the engine's hard thread cap
/// (`BatchEngine`'s `MAX_THREADS = 1024`): caching everything that was
/// simultaneously in flight never raises peak memory, while the bound
/// keeps a buggy put-loop from hoarding unbounded buffers.
const MAX_CACHED: usize = 1024;

/// Thread-safe free list of `f32` scratch buffers.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<f32>>>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer. Contents are unspecified (stale data from the last
    /// user) — every caller fully overwrites before reading, which is
    /// what keeps steady state free of redundant zeroing.
    pub fn take(&self) -> Vec<f32> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a buffer for reuse (length and contents kept as-is).
    pub fn put(&self, buf: Vec<f32>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < MAX_CACHED {
            free.push(buf);
        }
    }

    /// Number of currently cached buffers (for tests/metrics).
    pub fn cached(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles_storage() {
        let pool = BufferPool::new();
        let mut b = pool.take();
        assert!(b.is_empty(), "fresh buffer");
        b.resize(1024, 0.0);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.cached(), 1);
        let b2 = pool.take();
        assert_eq!(b2.len(), 1024, "length kept as-is (contents unspecified)");
        assert!(b2.capacity() >= cap, "capacity must be retained");
        assert_eq!(pool.cached(), 0);
    }

    #[test]
    fn pool_is_bounded() {
        let pool = BufferPool::new();
        for _ in 0..(MAX_CACHED + 10) {
            pool.put(Vec::new());
        }
        assert_eq!(pool.cached(), MAX_CACHED);
    }
}
