//! Reusable lane-buffer pool: steady-state batch execution allocates no
//! values buffers (the serving hot path calls `execute_batch` per
//! request batch; buffers grown once are recycled forever).

use std::sync::Mutex;

/// Upper bound on cached buffers. Matches the engine's hard thread cap
/// (`BatchEngine`'s `MAX_THREADS = 1024`): caching everything that was
/// simultaneously in flight never raises peak memory, while the bound
/// keeps a buggy put-loop from hoarding unbounded buffers.
const MAX_CACHED: usize = 1024;

/// Thread-safe free list of `f32` scratch buffers.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<f32>>>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer. Contents are unspecified (stale data from the last
    /// user) — every caller fully overwrites before reading, which is
    /// what keeps steady state free of redundant zeroing.
    pub fn take(&self) -> Vec<f32> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a buffer for reuse (length and contents kept as-is).
    pub fn put(&self, buf: Vec<f32>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < MAX_CACHED {
            free.push(buf);
        }
    }

    /// Number of currently cached buffers (for tests/metrics).
    pub fn cached(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles_storage() {
        let pool = BufferPool::new();
        let mut b = pool.take();
        assert!(b.is_empty(), "fresh buffer");
        b.resize(1024, 0.0);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.cached(), 1);
        let b2 = pool.take();
        assert_eq!(b2.len(), 1024, "length kept as-is (contents unspecified)");
        assert!(b2.capacity() >= cap, "capacity must be retained");
        assert_eq!(pool.cached(), 0);
    }

    #[test]
    fn pool_is_bounded() {
        let pool = BufferPool::new();
        for _ in 0..(MAX_CACHED + 10) {
            pool.put(Vec::new());
        }
        assert_eq!(pool.cached(), MAX_CACHED);
    }

    #[test]
    fn concurrent_take_put_conserves_buffers() {
        // N threads each hold at most one buffer at a time, and the pool
        // is seeded with N distinct marked buffers — so `take` can never
        // come up empty, and at the end the exact original set must be
        // back: nothing lost, nothing duplicated, nothing minted.
        const N: usize = 8;
        const LEN: usize = 16;
        const ITERS: usize = 500;
        let pool = BufferPool::new();
        for i in 0..N {
            let mut b = vec![0.0f32; LEN];
            b[0] = i as f32;
            pool.put(b);
        }
        std::thread::scope(|s| {
            for _ in 0..N {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..ITERS {
                        let b = pool.take();
                        assert_eq!(b.len(), LEN, "pool minted a fresh buffer under contention");
                        let id = b[0] as usize;
                        assert!(id < N, "corrupted marker {id}");
                        pool.put(b);
                    }
                });
            }
        });
        assert_eq!(pool.cached(), N, "buffers lost or duplicated");
        let mut seen = [false; N];
        for _ in 0..N {
            let b = pool.take();
            assert_eq!(b.len(), LEN);
            let id = b[0] as usize;
            assert!(!seen[id], "buffer {id} duplicated");
            seen[id] = true;
        }
        assert!(seen.iter().all(|&x| x), "a seeded buffer went missing");
    }
}
