//! Persistent worker pool for the execution engine.
//!
//! PR 1's parallel kernels spawned and joined `std::thread::scope`
//! workers on every `execute_batch` call; for the small per-level
//! kernels that LCC/weight-sharing produce, that spawn tax dominates.
//! This pool keeps workers hot instead (EIE-style: statically sized,
//! fed through one queue), parked on a condvar between batches:
//!
//! * **Lazily started** — constructing a pool (or merely touching the
//!   process-wide [`global_pool`]) spawns nothing; worker threads start
//!   on the first dispatched task, so serial configurations never pay
//!   for threads they do not use.
//! * **Scoped dispatch on unscoped threads** — [`WorkerPool::run_scoped`]
//!   accepts tasks borrowing the caller's stack (the engine's tasks
//!   borrow the batch being executed) and does not return until every
//!   task has run, which is what makes the lifetime erasure below sound.
//! * **Caller participation** — the submitting thread drains *its own
//!   call's* jobs while it waits (never another caller's, so a
//!   low-latency batch is never held hostage by a concurrent bulk
//!   batch), which means a zero-worker or shut-down pool still
//!   completes every call inline, and an engine asking for `T`-way
//!   parallelism gets the caller as one of the lanes.
//! * **Panic isolation** — a panicking task is caught on the worker,
//!   counted, and reported as an `Err` from `run_scoped`: the one batch
//!   fails (the engine re-raises), the pool and any concurrent callers'
//!   tasks are unaffected.
//! * **Stats** — tasks run, inline (caller-side) runs, worker wakeups,
//!   busy time, spawn/join counts; snapshot via [`WorkerPool::stats`],
//!   published into a [`Metrics`] registry via [`WorkerPool::publish`].
//!
//! No crossbeam / rayon: a `Mutex<VecDeque>` injector plus a `Condvar`,
//! with a spin-then-park idle discipline tuned by
//! `ExecConfig::{pool_spin_us, pool_park_ms}`.

use crate::config::ExecConfig;
use crate::metrics::Metrics;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Resolve a configured thread count (0 = one per available core) to a
/// concrete one. Hard-capped so a misconfigured count can never turn
/// into unbounded OS threads.
pub(crate) fn resolve_threads(threads: usize) -> usize {
    const MAX_THREADS: usize = 1024;
    let t = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    t.clamp(1, MAX_THREADS)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

#[derive(Default)]
struct Stats {
    threads_spawned: AtomicU64,
    threads_joined: AtomicU64,
    tasks_run: AtomicU64,
    inline_runs: AtomicU64,
    panics: AtomicU64,
    wakeups: AtomicU64,
    busy_ns: AtomicU64,
}

/// Snapshot of a pool's counters (all monotone except `workers`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// configured worker-thread count
    pub workers: usize,
    /// OS threads ever spawned by this pool (steady state: constant)
    pub threads_spawned: u64,
    /// OS threads joined back (== spawned after `shutdown`)
    pub threads_joined: u64,
    /// tasks executed to completion (includes inline runs)
    pub tasks_run: u64,
    /// tasks the submitting threads ran themselves while waiting
    pub inline_runs: u64,
    /// tasks that panicked (caught; their batch failed, the pool did not)
    pub panics: u64,
    /// times a parked worker woke (timeout or notify)
    pub wakeups: u64,
    /// cumulative task execution time, microseconds
    pub busy_us: u64,
}

impl PoolStats {
    /// Publish into a metrics registry under `exec_pool.*`. Counters use
    /// raise-to-value semantics so republishing is idempotent.
    pub fn publish(&self, m: &Metrics) {
        m.gauge("exec_pool.workers", self.workers as f64);
        m.counter_to("exec_pool.threads_spawned", self.threads_spawned);
        m.counter_to("exec_pool.threads_joined", self.threads_joined);
        m.counter_to("exec_pool.tasks_run", self.tasks_run);
        m.counter_to("exec_pool.inline_runs", self.inline_runs);
        m.counter_to("exec_pool.panics", self.panics);
        m.counter_to("exec_pool.wakeups", self.wakeups);
        m.counter_to("exec_pool.busy_us", self.busy_us);
    }
}

/// One or more tasks of a `run_scoped` call panicked. The panics were
/// contained: sibling tasks, concurrent callers and the workers
/// themselves are unaffected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolPanic {
    /// how many of the call's tasks panicked
    pub tasks: usize,
}

impl fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} pooled task(s) panicked (batch failed; pool unaffected)", self.tasks)
    }
}

/// Completion latch for one `run_scoped` call.
struct Latch {
    /// (tasks remaining, tasks panicked)
    state: Mutex<(usize, usize)>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { state: Mutex::new((n, 0)), done: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.0 -= 1;
        if panicked {
            s.1 += 1;
        }
        if s.0 == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    /// Block until every task completed; returns the panic count.
    fn wait(&self) -> usize {
        let mut s = self.state.lock().unwrap();
        while s.0 > 0 {
            s = self.done.wait(s).unwrap();
        }
        s.1
    }
}

struct Inner {
    /// jobs tagged with their `run_scoped` call id, so a waiting caller
    /// can drain its own call's jobs without absorbing another caller's
    queue: Mutex<VecDeque<(u64, Job)>>,
    available: Condvar,
    /// queue length mirror, readable without the lock (spin phase)
    pending: AtomicUsize,
    shutdown: AtomicBool,
    next_call: AtomicU64,
    spin_us: u64,
    park_ms: u64,
    stats: Arc<Stats>,
}

impl Inner {
    fn push_jobs(&self, call: u64, jobs: Vec<Job>) {
        let mut q = self.queue.lock().unwrap();
        for job in jobs {
            q.push_back((call, job));
            self.pending.fetch_add(1, Ordering::Release);
        }
        drop(q);
        self.available.notify_all();
    }

    /// Pop a job belonging to `call` only. Callers help with their own
    /// work while they wait — never with another caller's, so a
    /// low-latency batch cannot be held hostage by a concurrent bulk
    /// batch it happens to dequeue (and a caller can always finish its
    /// own call even on a zero-worker or shut-down pool).
    fn try_pop_call(&self, call: u64) -> Option<Job> {
        let mut q = self.queue.lock().unwrap();
        let pos = q.iter().position(|(c, _)| *c == call)?;
        self.pending.fetch_sub(1, Ordering::Release);
        q.remove(pos).map(|(_, job)| job)
    }

    /// Worker idle discipline: spin briefly on the lock-free pending
    /// counter, then park on the condvar. The park is bounded by
    /// `park_ms`, so even a missed notification only delays a worker,
    /// never wedges it. Returns `None` on shutdown.
    fn next_job(&self) -> Option<Job> {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            if self.spin_us > 0 {
                let deadline = Instant::now() + Duration::from_micros(self.spin_us);
                while self.pending.load(Ordering::Acquire) == 0
                    && !self.shutdown.load(Ordering::SeqCst)
                    && Instant::now() < deadline
                {
                    std::hint::spin_loop();
                }
            }
            let mut q = self.queue.lock().unwrap();
            if let Some((_, job)) = q.pop_front() {
                self.pending.fetch_sub(1, Ordering::Release);
                return Some(job);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let park = Duration::from_millis(self.park_ms.max(1));
            let (guard, _timed_out) = self.available.wait_timeout(q, park).unwrap();
            drop(guard);
            self.stats.wakeups.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn worker_loop(inner: &Inner) {
    while let Some(job) = inner.next_job() {
        job();
    }
}

/// Persistent, lazily-started worker pool for the exec engine's parallel
/// kernels. See the module docs for the dispatch/shutdown contract.
pub struct WorkerPool {
    inner: Arc<Inner>,
    workers: usize,
    started: AtomicBool,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// A pool of `workers` threads (0 is allowed: every task then runs
    /// inline on the submitting thread), spinning `spin_us` before
    /// parking and re-checking a park every `park_ms`.
    pub fn new(workers: usize, spin_us: u64, park_ms: u64) -> Self {
        WorkerPool {
            inner: Arc::new(Inner {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                pending: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
                next_call: AtomicU64::new(0),
                spin_us,
                park_ms,
                stats: Arc::new(Stats::default()),
            }),
            workers,
            started: AtomicBool::new(false),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Pool sized and tuned for an engine configuration.
    pub fn for_config(cfg: &ExecConfig) -> Self {
        WorkerPool::new(resolve_threads(cfg.threads), cfg.pool_spin_us, cfg.pool_park_ms)
    }

    /// Configured worker count (threads actually spawn on first use).
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn ensure_started(&self) {
        if self.started.load(Ordering::Acquire) {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        if self.started.load(Ordering::Acquire) || self.inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        for i in 0..self.workers {
            let inner = Arc::clone(&self.inner);
            let h = std::thread::Builder::new()
                .name(format!("lccnn-exec-{i}"))
                .spawn(move || worker_loop(&inner))
                .expect("spawn exec pool worker");
            self.inner.stats.threads_spawned.fetch_add(1, Ordering::Relaxed);
            handles.push(h);
        }
        self.started.store(true, Ordering::Release);
    }

    /// Run every task to completion, then return. The caller drains its
    /// own call's jobs while waiting, so the call completes even on a
    /// zero-worker or already-shut-down pool. `Err` means one or more
    /// tasks panicked; the panic is confined to this call.
    pub fn run_scoped<'scope>(
        &self,
        tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>,
    ) -> Result<(), PoolPanic> {
        let n = tasks.len();
        if n == 0 {
            return Ok(());
        }
        self.ensure_started();
        let latch = Arc::new(Latch::new(n));
        let jobs: Vec<Job> = tasks
            .into_iter()
            .map(|task| {
                let latch = Arc::clone(&latch);
                let stats = Arc::clone(&self.inner.stats);
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    let start = Instant::now();
                    let result = catch_unwind(AssertUnwindSafe(move || task()));
                    stats.busy_ns.fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    stats.tasks_run.fetch_add(1, Ordering::Relaxed);
                    if result.is_err() {
                        stats.panics.fetch_add(1, Ordering::Relaxed);
                    }
                    latch.complete(result.is_err());
                });
                // SAFETY: the wrapper always completes the latch (panics
                // are caught first), and this function only returns after
                // `latch.wait()` sees all `n` completions — so every
                // borrow captured for 'scope strictly outlives every
                // access the erased task makes.
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(wrapped) }
            })
            .collect();
        let call = self.inner.next_call.fetch_add(1, Ordering::Relaxed);
        self.inner.push_jobs(call, jobs);
        // Help drain this call's own jobs while waiting: bounds the
        // inline work to what was submitted here (another caller's bulk
        // batch is never absorbed) while still guaranteeing completion
        // without any worker at all.
        while !latch.is_done() {
            match self.inner.try_pop_call(call) {
                Some(job) => {
                    self.inner.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
                    job();
                }
                None => break,
            }
        }
        let panicked = latch.wait();
        if panicked > 0 {
            Err(PoolPanic { tasks: panicked })
        } else {
            Ok(())
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PoolStats {
        let s = &self.inner.stats;
        PoolStats {
            workers: self.workers,
            threads_spawned: s.threads_spawned.load(Ordering::Relaxed),
            threads_joined: s.threads_joined.load(Ordering::Relaxed),
            tasks_run: s.tasks_run.load(Ordering::Relaxed),
            inline_runs: s.inline_runs.load(Ordering::Relaxed),
            panics: s.panics.load(Ordering::Relaxed),
            wakeups: s.wakeups.load(Ordering::Relaxed),
            busy_us: s.busy_ns.load(Ordering::Relaxed) / 1_000,
        }
    }

    /// Publish this pool's stats into a metrics registry (`exec_pool.*`).
    pub fn publish(&self, m: &Metrics) {
        self.stats().publish(m);
    }

    /// Stop and join every worker. Graceful: tasks of concurrent
    /// `run_scoped` calls still complete (their callers drain inline),
    /// and later calls keep working caller-side. Idempotent; `Drop`
    /// calls it.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Pair with the workers' check-then-park under the queue lock: by
        // taking the lock before notifying, no worker can be between "saw
        // no shutdown" and "parked" when the notification fires.
        drop(self.inner.queue.lock().unwrap());
        self.inner.available.notify_all();
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            if h.join().is_ok() {
                self.inner.stats.threads_joined.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("stats", &self.stats())
            .finish()
    }
}

static GLOBAL_POOL: OnceLock<Arc<WorkerPool>> = OnceLock::new();

/// The process-wide pool every engine shares unless given its own
/// (`BatchEngine::with_workers`). Sized from `LCCNN_EXEC_*` env at first
/// touch; threads spawn only when parallel work is actually dispatched.
pub fn global_pool() -> Arc<WorkerPool> {
    Arc::clone(
        GLOBAL_POOL.get_or_init(|| Arc::new(WorkerPool::for_config(&ExecConfig::from_env()))),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn counting_tasks(counter: &AtomicUsize, n: usize) -> Vec<Box<dyn FnOnce() + Send + '_>> {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n);
        for _ in 0..n {
            tasks.push(Box::new(|| {
                counter.fetch_add(1, Ordering::SeqCst);
            }));
        }
        tasks
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3, 0, 20);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(counting_tasks(&counter, 17)).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 17);
        let s = pool.stats();
        assert_eq!(s.tasks_run, 17);
        assert!(s.threads_spawned <= 3);
    }

    #[test]
    fn tasks_can_borrow_the_callers_stack() {
        let pool = WorkerPool::new(2, 0, 20);
        let mut outputs = vec![0usize; 8];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, slot) in outputs.iter_mut().enumerate() {
                tasks.push(Box::new(move || *slot = i * i));
            }
            pool.run_scoped(tasks).unwrap();
        }
        assert_eq!(outputs, (0..8).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0, 0, 20);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(counting_tasks(&counter, 5)).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 5);
        let s = pool.stats();
        assert_eq!(s.threads_spawned, 0, "lazy pool must not spawn for inline work");
        assert_eq!(s.inline_runs, 5);
    }

    #[test]
    fn lazily_started_until_first_dispatch() {
        let pool = WorkerPool::new(4, 0, 20);
        assert_eq!(pool.stats().threads_spawned, 0);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(counting_tasks(&counter, 1)).unwrap();
        assert!(pool.stats().threads_spawned <= 4);
    }

    #[test]
    fn panic_is_isolated_to_the_call() {
        let pool = WorkerPool::new(2, 0, 20);
        let counter = AtomicUsize::new(0);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        tasks.push(Box::new(|| panic!("injected task failure")));
        for _ in 0..3 {
            tasks.push(Box::new(|| {
                counter.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let err = pool.run_scoped(tasks).unwrap_err();
        assert_eq!(err.tasks, 1);
        assert_eq!(counter.load(Ordering::SeqCst), 3, "siblings still ran");
        assert_eq!(pool.stats().panics, 1);
        // the pool still works afterwards
        pool.run_scoped(counting_tasks(&counter, 4)).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn shutdown_joins_all_spawned_threads_and_stays_usable() {
        let pool = WorkerPool::new(3, 0, 10);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(counting_tasks(&counter, 6)).unwrap();
        pool.shutdown();
        let s = pool.stats();
        assert_eq!(s.threads_joined, s.threads_spawned, "leaked worker threads");
        pool.shutdown(); // idempotent
        // post-shutdown calls complete inline on the caller
        pool.run_scoped(counting_tasks(&counter, 2)).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        assert_eq!(pool.stats().threads_spawned, s.threads_spawned, "no respawn");
    }

    #[test]
    fn concurrent_callers_share_one_pool() {
        let pool = WorkerPool::new(2, 0, 20);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = &pool;
                let total = &total;
                s.spawn(move || {
                    for _ in 0..10 {
                        pool.run_scoped(counting_tasks(total, 3)).unwrap();
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 10 * 3);
        assert_eq!(pool.stats().tasks_run, 4 * 10 * 3);
    }

    #[test]
    fn resolve_threads_clamps() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(7), 7);
        assert_eq!(resolve_threads(1_000_000), 1024);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = global_pool();
        let b = global_pool();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
