//! Fixed-point CSD shift-add execution — the hardware-faithful mode.
//!
//! The float engines simulate an adder datapath with multiplies: every
//! `±2^k` coefficient becomes an `exp2` float factor. This module lowers
//! an [`ExecPlan`] the rest of the way to what the paper's hardware
//! actually does: activations quantized to integer mantissas on a
//! `2^-frac_bits` grid, every coefficient recovered as a
//! `(shift, negate)` pair from its CSD digit form, and each adder node
//! evaluated as two arithmetic shifts plus one integer add — no
//! multiplier anywhere in the datapath.
//!
//! Semantics are deliberately faithful rather than convenient:
//!
//! - right shifts **truncate** (arithmetic shift, round toward −∞), the
//!   way a wired shifter does — not round-to-nearest;
//! - the accumulator has a configured width (`AccWidth`) and overflow
//!   policy (`Saturation`): saturate like a guarded DSP slice, or wrap
//!   like a bare two's-complement adder;
//! - results are **deterministic**: integer lanes are independent, so
//!   outputs are bit-stable across batch sizes, chunk widths, thread
//!   counts and sharding — unlike float, where reassociation would show.
//!
//! The price is quantization error. Lowering computes an analytic
//! per-output bound (`FixedPlan::error_bounds`): inputs contribute half
//! a grid step (round-to-nearest), every truncating right shift adds at
//! most one step, and each op scales its operands' bounds by `2^shift`.
//! The bound assumes the accumulator never saturates;
//! [`FixedPlan::max_mantissa_bound`] gives the matching worst-case
//! magnitude check.

use super::plan::{ExecPlan, OutOp};
use super::workers::{self, WorkerPool};
use super::Executor;
use crate::config::{AccWidth, ExecConfig, PoolMode, Saturation};
use crate::graph::AdderGraph;
use crate::quant::csd_digits;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// Recover the `(shift, negate)` pair of a `±2^k` coefficient from its
/// CSD digit form: scale to an integer mantissa and require exactly one
/// nonzero CSD digit. Exact for `|k| <= 31` (every f32 `±2^k` scales to
/// an exactly-representable integer); anything else — zero, non-finite,
/// multi-digit (not a power of two), or out-of-range shifts — returns
/// `None`, marking the plan as not purely shift-add.
pub fn po2_shift_negate(c: f32) -> Option<(i32, bool)> {
    const SCALE: i32 = 31;
    if !c.is_finite() {
        return None;
    }
    let scaled = (c as f64) * (SCALE as f64).exp2();
    if scaled != scaled.trunc() || scaled.abs() >= (63f64).exp2() {
        return None;
    }
    match csd_digits(scaled as i64).as_slice() {
        [d] => Some((d.shift - SCALE, d.negative)),
        _ => None,
    }
}

/// Output resolution over the integer value slots.
#[derive(Clone, Copy, Debug)]
enum FixedOut {
    Zero,
    Scaled { idx: u32, shift: i32, negate: bool },
}

/// Integer lowering of an [`ExecPlan`]: the same slot layout and
/// homogeneous runs, with every coefficient replaced by its
/// `(shift, negate)` pair and the format/datapath parameters baked in.
#[derive(Clone, Debug)]
pub struct FixedPlan {
    num_inputs: usize,
    ia: Vec<u32>,
    ib: Vec<u32>,
    sa: Vec<i32>,
    na: Vec<bool>,
    sb: Vec<i32>,
    nb: Vec<bool>,
    /// run boundaries, copied from the source plan (coefficient pairs
    /// and shift/negate pairs are in bijection, so the runs coincide)
    runs: Vec<u32>,
    outs: Vec<FixedOut>,
    frac_bits: u32,
    acc: AccWidth,
    sat: Saturation,
    /// analytic per-output `|fixed − exact|` bound (valid while the
    /// accumulator does not saturate)
    err: Vec<f64>,
}

impl FixedPlan {
    /// Lower a float plan onto the fixed datapath described by `cfg`
    /// (`fixed_frac_bits`, `fixed_acc`, `fixed_sat`). Fails if any
    /// coefficient is not `±2^k` with `|k| <= 31` — impossible for
    /// plans lowered from an [`AdderGraph`] with sane shifts, but the
    /// check is what makes the "pure shift-add" claim load-bearing.
    pub fn lower(plan: &ExecPlan, cfg: &ExecConfig) -> Result<Self> {
        let (ia, ib) = plan.op_indices();
        let (ca, cb) = plan.op_coeffs();
        let n = ca.len();
        let num_inputs = plan.num_inputs();
        let frac_bits = cfg.fixed_frac_bits.min(30);
        let step = (-(frac_bits as f64)).exp2();

        let lower_coeff = |c: f32, what: &str, j: usize| -> Result<(i32, bool)> {
            match po2_shift_negate(c) {
                Some(p) => Ok(p),
                None => bail!(
                    "{what} {j}: coefficient {c} is not ±2^k with |k| <= 31; \
                     the fixed datapath executes pure shift-add plans only"
                ),
            }
        };
        // per-slot error bound recursion, consumed below for the outputs
        let mut eps = vec![0.5 * step; num_inputs];
        eps.reserve(n);
        // scaling by 2^s multiplies the incoming bound; a truncating
        // right shift adds at most one grid step on top
        let scale_eps = |e: f64, s: i32| -> f64 {
            let scaled = e * (s as f64).exp2();
            if s < 0 { scaled + step } else { scaled }
        };

        let mut sa = Vec::with_capacity(n);
        let mut na = Vec::with_capacity(n);
        let mut sb = Vec::with_capacity(n);
        let mut nb = Vec::with_capacity(n);
        for j in 0..n {
            let (s_a, n_a) = lower_coeff(ca[j], "op", j)?;
            let (s_b, n_b) = lower_coeff(cb[j], "op", j)?;
            sa.push(s_a);
            na.push(n_a);
            sb.push(s_b);
            nb.push(n_b);
            let e = scale_eps(eps[ia[j] as usize], s_a) + scale_eps(eps[ib[j] as usize], s_b);
            eps.push(e);
        }

        let mut outs = Vec::with_capacity(plan.num_outputs());
        let mut err = Vec::with_capacity(plan.num_outputs());
        for (k, o) in plan.out_ops().iter().enumerate() {
            match *o {
                OutOp::Zero => {
                    outs.push(FixedOut::Zero);
                    err.push(0.0);
                }
                OutOp::Scaled { idx, c } => {
                    let (s, neg) = lower_coeff(c, "output", k)?;
                    outs.push(FixedOut::Scaled { idx, shift: s, negate: neg });
                    err.push(scale_eps(eps[idx as usize], s));
                }
            }
        }

        Ok(FixedPlan {
            num_inputs,
            ia: ia.to_vec(),
            ib: ib.to_vec(),
            sa,
            na,
            sb,
            nb,
            runs: plan.run_bounds().to_vec(),
            outs,
            frac_bits,
            acc: cfg.fixed_acc,
            sat: cfg.fixed_sat,
            err,
        })
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.outs.len()
    }

    /// Op count — unchanged by the lowering.
    pub fn additions(&self) -> usize {
        self.ia.len()
    }

    pub fn frac_bits(&self) -> u32 {
        self.frac_bits
    }

    /// The activation grid step `2^-frac_bits`.
    pub fn step(&self) -> f64 {
        (-(self.frac_bits as f64)).exp2()
    }

    /// Analytic `|fixed − exact|` bound per output, valid while no
    /// accumulator saturation occurs (see
    /// [`FixedPlan::max_mantissa_bound`]).
    pub fn error_bounds(&self) -> &[f64] {
        &self.err
    }

    /// The largest per-output error bound — the single-number tolerance
    /// for differential verification against a float oracle.
    pub fn max_error_bound(&self) -> f64 {
        self.err.iter().cloned().fold(0.0, f64::max)
    }

    /// Worst-case |mantissa| over every value slot and output, assuming
    /// every input magnitude is at most `max_abs_input`. When this stays
    /// below the accumulator range the datapath cannot saturate and
    /// [`FixedPlan::error_bounds`] is exact arithmetic, not heuristics.
    pub fn max_mantissa_bound(&self, max_abs_input: f64) -> f64 {
        let scale = (self.frac_bits as f64).exp2();
        let m0 = max_abs_input.abs() * scale + 0.5;
        let mut mag = vec![m0; self.num_inputs];
        mag.reserve(self.ia.len());
        let shift_mag = |m: f64, s: i32| m * (s as f64).exp2();
        let mut worst = m0;
        for j in 0..self.ia.len() {
            let m = shift_mag(mag[self.ia[j] as usize], self.sa[j])
                + shift_mag(mag[self.ib[j] as usize], self.sb[j]);
            worst = worst.max(m);
            mag.push(m);
        }
        for o in &self.outs {
            if let FixedOut::Scaled { idx, shift, .. } = *o {
                worst = worst.max(shift_mag(mag[idx as usize], shift));
            }
        }
        worst
    }

    /// Batch-major integer evaluation of one chunk: quantize inputs to
    /// mantissa lanes, run the shift-add program once per homogeneous
    /// run, dequantize the outputs. Lane results do not depend on
    /// `width`, which is what makes every chunking/sharding of the fixed
    /// engine bit-stable.
    pub(crate) fn eval_lanes(&self, xs: &[Vec<f32>], buf: &mut Vec<i64>, ys: &mut [Vec<f32>]) {
        match (self.acc, self.sat) {
            (AccWidth::W64, Saturation::Saturate) => self.eval_lanes_p::<Sat64>(xs, buf, ys),
            (AccWidth::W64, Saturation::Wrap) => self.eval_lanes_p::<Wrap64>(xs, buf, ys),
            (AccWidth::W32, Saturation::Saturate) => self.eval_lanes_p::<Sat32>(xs, buf, ys),
            (AccWidth::W32, Saturation::Wrap) => self.eval_lanes_p::<Wrap32>(xs, buf, ys),
        }
    }

    fn eval_lanes_p<P: AccPolicy>(&self, xs: &[Vec<f32>], buf: &mut Vec<i64>, ys: &mut [Vec<f32>]) {
        let width = xs.len();
        debug_assert_eq!(ys.len(), width);
        if width == 0 {
            return;
        }
        let needed = (self.num_inputs + self.ia.len()) * width;
        if buf.len() < needed {
            buf.resize(needed, 0);
        }
        // round-to-nearest onto the activation grid (the only rounding
        // in the datapath; everything after is shifts and adds)
        let scale = (self.frac_bits as f64).exp2();
        for (s, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.num_inputs, "input length mismatch");
            for (i, &v) in x.iter().enumerate() {
                buf[i * width + s] = P::clamp_in((v as f64 * scale).round() as i64);
            }
        }
        for r in 1..self.runs.len() {
            let (j0, j1) = (self.runs[r - 1] as usize, self.runs[r] as usize);
            let dst_start = (self.num_inputs + j0) * width;
            let (src, dst) = buf.split_at_mut(dst_start);
            self.run_kernel::<P>(src, &mut dst[..(j1 - j0) * width], j0, width);
        }
        let step = self.step();
        for (s, y) in ys.iter_mut().enumerate() {
            y.clear();
            y.reserve(self.outs.len());
            for o in &self.outs {
                y.push(match *o {
                    FixedOut::Zero => 0.0,
                    FixedOut::Scaled { idx, shift, negate } => {
                        let mut m = P::shift(buf[idx as usize * width + s], shift);
                        if negate {
                            m = P::neg(m);
                        }
                        (m as f64 * step) as f32
                    }
                });
            }
        }
    }

    /// One homogeneous run: the `(shift, negate)` quartet is loaded once
    /// and constant through the whole span, so the inner lane loop is
    /// two shifts, up to two negations, and one add per sample.
    fn run_kernel<P: AccPolicy>(&self, src: &[i64], dst: &mut [i64], j0: usize, width: usize) {
        let (sa, na, sb, nb) = (self.sa[j0], self.na[j0], self.sb[j0], self.nb[j0]);
        for (k, d) in dst.chunks_mut(width).enumerate() {
            let j = j0 + k;
            let a = &src[self.ia[j] as usize * width..][..width];
            let b = &src[self.ib[j] as usize * width..][..width];
            for s in 0..width {
                // shift first, then negate: the truncation of a right
                // shift lands before the sign flip, matching the error
                // model (|truncation| <= one step either way)
                let mut x = P::shift(a[s], sa);
                if na {
                    x = P::neg(x);
                }
                let mut y = P::shift(b[s], sb);
                if nb {
                    y = P::neg(y);
                }
                d[s] = P::add(x, y);
            }
        }
    }
}

/// The accumulator datapath: how mantissas scale, negate, and add at a
/// given width/overflow policy. Monomorphized per variant so the inner
/// loops carry no runtime policy branches.
trait AccPolicy: Copy + Send + Sync + 'static {
    /// Apply `±2^s` as a shift: left per the overflow policy, right
    /// always a truncating arithmetic shift.
    fn shift(m: i64, s: i32) -> i64;
    fn neg(m: i64) -> i64;
    fn add(a: i64, b: i64) -> i64;
    /// Bring a freshly quantized input into the accumulator range.
    fn clamp_in(m: i64) -> i64;
}

/// Saturating left shift against `[lo, hi]`; never overflows because the
/// limit comparison happens pre-shift.
#[inline]
fn sat_shl(m: i64, s: u32, lo: i64, hi: i64) -> i64 {
    if m == 0 {
        0
    } else if m > (hi >> s) {
        hi
    } else if m < (lo >> s) {
        lo
    } else {
        m << s
    }
}

const MIN32: i64 = i32::MIN as i64;
const MAX32: i64 = i32::MAX as i64;

#[derive(Clone, Copy)]
struct Sat64;
impl AccPolicy for Sat64 {
    #[inline]
    fn shift(m: i64, s: i32) -> i64 {
        if s >= 0 { sat_shl(m, s as u32, i64::MIN, i64::MAX) } else { m >> (-s) }
    }
    #[inline]
    fn neg(m: i64) -> i64 {
        m.saturating_neg()
    }
    #[inline]
    fn add(a: i64, b: i64) -> i64 {
        a.saturating_add(b)
    }
    #[inline]
    fn clamp_in(m: i64) -> i64 {
        m
    }
}

#[derive(Clone, Copy)]
struct Wrap64;
impl AccPolicy for Wrap64 {
    #[inline]
    fn shift(m: i64, s: i32) -> i64 {
        if s >= 0 { m.wrapping_shl(s as u32) } else { m >> (-s) }
    }
    #[inline]
    fn neg(m: i64) -> i64 {
        m.wrapping_neg()
    }
    #[inline]
    fn add(a: i64, b: i64) -> i64 {
        a.wrapping_add(b)
    }
    #[inline]
    fn clamp_in(m: i64) -> i64 {
        m
    }
}

/// 32-bit lanes carried in i64 storage: every result is brought back
/// into the i32 range, so intermediate sums (range at most 2^33) never
/// overflow the carrier.
#[derive(Clone, Copy)]
struct Sat32;
impl AccPolicy for Sat32 {
    #[inline]
    fn shift(m: i64, s: i32) -> i64 {
        if s >= 0 { sat_shl(m, s as u32, MIN32, MAX32) } else { m >> (-s) }
    }
    #[inline]
    fn neg(m: i64) -> i64 {
        (-m).clamp(MIN32, MAX32)
    }
    #[inline]
    fn add(a: i64, b: i64) -> i64 {
        (a + b).clamp(MIN32, MAX32)
    }
    #[inline]
    fn clamp_in(m: i64) -> i64 {
        m.clamp(MIN32, MAX32)
    }
}

#[derive(Clone, Copy)]
struct Wrap32;
impl AccPolicy for Wrap32 {
    #[inline]
    fn shift(m: i64, s: i32) -> i64 {
        if s >= 0 { ((m as i32).wrapping_shl(s as u32)) as i64 } else { m >> (-s) }
    }
    #[inline]
    fn neg(m: i64) -> i64 {
        ((m as i32).wrapping_neg()) as i64
    }
    #[inline]
    fn add(a: i64, b: i64) -> i64 {
        ((a as i32).wrapping_add(b as i32)) as i64
    }
    #[inline]
    fn clamp_in(m: i64) -> i64 {
        (m as i32) as i64
    }
}

/// Upper bound on cached lane buffers — mirrors `exec::BufferPool`.
const MAX_CACHED: usize = 1024;

/// Thread-safe free list of i64 lane buffers (the integer twin of
/// [`super::BufferPool`]; contents are unspecified between uses).
#[derive(Debug, Default)]
struct LanePool {
    free: Mutex<Vec<Vec<i64>>>,
}

impl LanePool {
    fn take(&self) -> Vec<i64> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, buf: Vec<i64>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < MAX_CACHED {
            free.push(buf);
        }
    }
}

/// The fixed-point twin of [`super::BatchEngine`]: chunked, pooled,
/// optionally chunk-parallel execution of a [`FixedPlan`], exposed as an
/// [`Executor`] so it drops into sharding, the registry, the pipeline
/// executor and the serve CLI unchanged.
///
/// Chunk parallelism follows the same job-list dispatch as the float
/// engine (persistent pool or scoped threads per `cfg.pool_mode`).
/// Level parallelism is intentionally absent: the integer lanes are
/// bit-stable under any chunking, so there is no observable scheduling
/// freedom to exploit, and the wide-graph small-batch case is served by
/// sharding.
#[derive(Debug)]
pub struct FixedEngine {
    plan: FixedPlan,
    cfg: ExecConfig,
    pool: LanePool,
    workers: Arc<WorkerPool>,
}

impl Clone for FixedEngine {
    fn clone(&self) -> Self {
        // buffer pool is a cache, not state; worker pool is shared
        FixedEngine {
            plan: self.plan.clone(),
            cfg: self.cfg,
            pool: LanePool::default(),
            workers: Arc::clone(&self.workers),
        }
    }
}

impl FixedEngine {
    /// Lower and wrap a graph (fails only on non-shift-add coefficients,
    /// which an [`AdderGraph`] cannot produce for sane shift ranges).
    pub fn with_config(g: &AdderGraph, cfg: ExecConfig) -> Result<Self> {
        Self::from_plan(&ExecPlan::new(g), cfg)
    }

    pub fn from_plan(plan: &ExecPlan, cfg: ExecConfig) -> Result<Self> {
        Self::from_plan_with_workers(plan, cfg, workers::global_pool())
    }

    pub fn from_plan_with_workers(
        plan: &ExecPlan,
        cfg: ExecConfig,
        workers: Arc<WorkerPool>,
    ) -> Result<Self> {
        Ok(FixedEngine {
            plan: FixedPlan::lower(plan, &cfg)?,
            cfg,
            pool: LanePool::default(),
            workers,
        })
    }

    pub fn fixed_plan(&self) -> &FixedPlan {
        &self.plan
    }

    pub fn config(&self) -> &ExecConfig {
        &self.cfg
    }

    /// Per-output error bound of the lowered datapath
    /// ([`FixedPlan::error_bounds`]).
    pub fn error_bounds(&self) -> &[f64] {
        self.plan.error_bounds()
    }

    pub fn max_error_bound(&self) -> f64 {
        self.plan.max_error_bound()
    }
}

impl Executor for FixedEngine {
    fn num_inputs(&self) -> usize {
        self.plan.num_inputs()
    }

    fn num_outputs(&self) -> usize {
        self.plan.num_outputs()
    }

    fn name(&self) -> &'static str {
        "fixed-engine"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        let b = xs.len();
        ys.resize_with(b, Vec::new);
        if b == 0 {
            return;
        }
        let chunk = self.cfg.chunk.max(1);
        let threads = workers::resolve_threads(self.cfg.threads);
        let n_chunks = b.div_ceil(chunk);
        if threads > 1 && n_chunks > 1 && b >= self.cfg.parallel_min_batch {
            let jobs: Mutex<Vec<(&[Vec<f32>], &mut [Vec<f32>])>> =
                Mutex::new(xs.chunks(chunk).zip(ys.chunks_mut(chunk)).collect());
            let n_workers = threads.min(n_chunks);
            let drain = || {
                let mut buf = self.pool.take();
                loop {
                    let job = jobs.lock().unwrap().pop();
                    match job {
                        Some((xc, yc)) => self.plan.eval_lanes(xc, &mut buf, yc),
                        None => break,
                    }
                }
                self.pool.put(buf);
            };
            match self.cfg.pool_mode {
                PoolMode::Persistent => {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(n_workers);
                    for _ in 0..n_workers {
                        tasks.push(Box::new(&drain));
                    }
                    if let Err(e) = self.workers.run_scoped(tasks) {
                        panic!("exec worker pool: {e}");
                    }
                }
                PoolMode::Scoped => {
                    std::thread::scope(|scope| {
                        for _ in 0..n_workers {
                            scope.spawn(&drain);
                        }
                    });
                }
            }
        } else {
            let mut buf = self.pool.take();
            for (xc, yc) in xs.chunks(chunk).zip(ys.chunks_mut(chunk)) {
                self.plan.eval_lanes(xc, &mut buf, yc);
            }
            self.pool.put(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NaiveExecutor;
    use crate::graph::{Operand, OutputSpec};
    use crate::util::Rng;

    #[test]
    fn po2_recovery_golden() {
        assert_eq!(po2_shift_negate(1.0), Some((0, false)));
        assert_eq!(po2_shift_negate(-1.0), Some((0, true)));
        assert_eq!(po2_shift_negate(8.0), Some((3, false)));
        assert_eq!(po2_shift_negate(-0.25), Some((-2, true)));
        assert_eq!(po2_shift_negate((31f32).exp2()), Some((31, false)));
        assert_eq!(po2_shift_negate((-31f32).exp2()), Some((-31, false)));
        assert_eq!(po2_shift_negate(0.0), None, "zero has no digit");
        assert_eq!(po2_shift_negate(3.0), None, "two CSD digits");
        assert_eq!(po2_shift_negate(0.75), None);
        assert_eq!(po2_shift_negate(f32::INFINITY), None);
        assert_eq!(po2_shift_negate(f32::NAN), None);
        assert_eq!(po2_shift_negate((40f32).exp2()), None, "out of datapath range");
    }

    #[test]
    fn po2_recovery_round_trips_operand_coeffs() {
        for shift in -31..=31 {
            for negative in [false, true] {
                let op = Operand::input(0).scaled(shift, negative);
                assert_eq!(po2_shift_negate(op.coeff()), Some((shift, negative)), "2^{shift}");
            }
        }
    }

    fn small_exact_graph() -> AdderGraph {
        // nonnegative shifts and tiny magnitudes: exactly representable
        // in both f32 arithmetic and the fixed grid
        let mut g = AdderGraph::new(3);
        let a = g.push_add(Operand::input(0).scaled(1, false), Operand::input(1));
        let b = g.push_add(a, Operand::input(2).scaled(2, true));
        let c = g.push_add(a.scaled(0, true), b.scaled(1, false));
        g.set_outputs(vec![
            OutputSpec::Ref(c),
            OutputSpec::Zero,
            OutputSpec::Ref(b.scaled(2, false)),
        ]);
        g
    }

    #[test]
    fn bit_exact_on_exactly_representable_plans() {
        let g = small_exact_graph();
        let oracle = NaiveExecutor::new(g.clone());
        let cfg = ExecConfig { threads: 1, ..ExecConfig::default() };
        let engine = FixedEngine::with_config(&g, cfg).unwrap();
        // inputs on the 2^-12 grid, small enough that the float oracle
        // computes exact arithmetic too
        let step = engine.fixed_plan().step() as f32;
        let xs: Vec<Vec<f32>> = (0..9)
            .map(|s| {
                (0..3)
                    .map(|i| ((s * 3 + i) as f32 - 13.0) * step * 128.0)
                    .collect()
            })
            .collect();
        let want = oracle.execute_batch(&xs);
        let got = engine.execute_batch(&xs);
        assert_eq!(got, want, "no rounding anywhere: results must be bit-exact");
    }

    fn random_graph(rng: &mut Rng) -> AdderGraph {
        let inputs = 1 + rng.below(6);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..rng.below(40) {
            let a = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..1 + rng.below(5))
            .map(|_| {
                if rng.f32() < 0.15 {
                    OutputSpec::Zero
                } else {
                    let r = refs[rng.below(refs.len())];
                    OutputSpec::Ref(r.scaled(rng.below(3) as i32 - 1, rng.f32() < 0.5))
                }
            })
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn error_bound_holds_against_float_oracle() {
        let mut rng = Rng::new(0xF1C5ED);
        let mut checked = 0usize;
        for _ in 0..30 {
            let g = random_graph(&mut rng);
            let oracle = NaiveExecutor::new(g.clone());
            let engine =
                FixedEngine::with_config(&g, ExecConfig { threads: 1, ..ExecConfig::default() })
                    .unwrap();
            // skip the rare pathological draw whose worst-case mantissa
            // could saturate (the bound's stated precondition)
            if engine.fixed_plan().max_mantissa_bound(4.0) >= 0.25 * i64::MAX as f64 {
                continue;
            }
            let xs: Vec<Vec<f32>> = (0..7)
                .map(|_| (0..g.num_inputs()).map(|_| rng.f32() * 8.0 - 4.0).collect())
                .collect();
            let want = oracle.execute_batch(&xs);
            let got = engine.execute_batch(&xs);
            let bounds = engine.error_bounds();
            for (ws, gs) in want.iter().zip(&got) {
                for ((w, g), &e) in ws.iter().zip(gs).zip(bounds) {
                    // slack covers the float oracle's own f32 rounding
                    let tol = e + 1e-4 * (1.0 + w.abs() as f64);
                    assert!(
                        ((w - g).abs() as f64) <= tol,
                        "fixed {g} vs float {w}: |diff| > bound {e}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 100, "sweep too small: {checked}");
    }

    #[test]
    fn results_bit_stable_across_chunks_threads_and_batches() {
        let mut rng = Rng::new(0xDE7);
        let g = random_graph(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..33).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
        let base = FixedEngine::with_config(
            &g,
            ExecConfig { threads: 1, chunk: 64, ..ExecConfig::default() },
        )
        .unwrap();
        let want = base.execute_batch(&xs);
        for cfg in [
            ExecConfig { threads: 1, chunk: 1, ..ExecConfig::default() },
            ExecConfig { threads: 1, chunk: 5, ..ExecConfig::default() },
            ExecConfig { threads: 4, chunk: 4, parallel_min_batch: 2, ..ExecConfig::default() },
            ExecConfig {
                threads: 4,
                chunk: 4,
                parallel_min_batch: 2,
                pool_mode: PoolMode::Scoped,
                ..ExecConfig::default()
            },
        ] {
            let engine = FixedEngine::with_config(&g, cfg).unwrap();
            assert_eq!(engine.execute_batch(&xs), want, "cfg {cfg:?}");
            // single-sample slices agree with the batch rows: integer
            // lanes are width-invariant
            assert_eq!(engine.execute_one(&xs[0]), want[0]);
        }
    }

    #[test]
    fn saturation_policies_differ_and_saturate_is_clamped() {
        // one op summing x << 20 twice: at frac 12 the mantissa is
        // x · 2^33, overflowing a 32-bit accumulator for x beyond ~0.25
        let mut g = AdderGraph::new(1);
        let big = Operand::input(0).scaled(20, false);
        let n = g.push_add(big, big);
        g.set_outputs(vec![OutputSpec::Ref(n)]);
        let base = ExecConfig { threads: 1, fixed_acc: AccWidth::W32, ..ExecConfig::default() };
        let sat = FixedEngine::with_config(&g, base).unwrap();
        let wrap = FixedEngine::with_config(
            &g,
            ExecConfig { fixed_sat: Saturation::Wrap, ..base },
        )
        .unwrap();
        let x = vec![vec![3.0f32]];
        let ys = sat.execute_batch(&x);
        let yw = wrap.execute_batch(&x);
        let ceiling = i32::MAX as f64 * sat.fixed_plan().step();
        assert!((ys[0][0] as f64 - ceiling).abs() < 1.0, "saturate clamps to the acc ceiling");
        assert!(ys[0][0] != yw[0][0], "wrap must differ once the accumulator overflows");
        // within range the two policies agree exactly
        let small = vec![vec![1e-4f32]];
        assert_eq!(sat.execute_batch(&small), wrap.execute_batch(&small));
    }

    #[test]
    fn error_bounds_scale_with_frac_bits() {
        let mut rng = Rng::new(0xBB);
        // redraw until some output carries a nonzero bound (an all-Zero
        // output draw would make the ratio below 0/0)
        let g = loop {
            let g = random_graph(&mut rng);
            let probe =
                FixedEngine::with_config(&g, ExecConfig::default()).unwrap();
            if probe.max_error_bound() > 0.0 {
                break g;
            }
        };
        let coarse = FixedEngine::with_config(
            &g,
            ExecConfig { fixed_frac_bits: 8, ..ExecConfig::default() },
        )
        .unwrap();
        let fine = FixedEngine::with_config(
            &g,
            ExecConfig { fixed_frac_bits: 16, ..ExecConfig::default() },
        )
        .unwrap();
        assert!(fine.max_error_bound() < coarse.max_error_bound());
        // halving the step halves every term of the recursion exactly
        let ratio = coarse.max_error_bound() / fine.max_error_bound();
        assert!((ratio - 256.0).abs() < 1e-6, "bound must scale linearly with the step: {ratio}");
    }

    #[test]
    fn empty_and_zero_shapes() {
        let mut g = AdderGraph::new(2);
        g.set_outputs(vec![OutputSpec::Zero, OutputSpec::Ref(Operand::input(1))]);
        let engine = FixedEngine::with_config(&g, ExecConfig::serial()).unwrap();
        assert_eq!(engine.execute_batch(&[]), Vec::<Vec<f32>>::new());
        let y = engine.execute_batch(&[vec![4.0, 5.0]]);
        assert_eq!(y, vec![vec![0.0, 5.0]]);
        assert_eq!(engine.error_bounds()[0], 0.0, "zero outputs are exact");
    }
}
