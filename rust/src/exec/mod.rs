//! The adder-graph execution engine — the single runtime for everything
//! the compressed network ultimately executes.
//!
//! The paper's cost model is *additions*; this module is where those
//! additions actually run. It replaces the three historical paths (the
//! scalar interpreter in `graph::vm`, the flattened `graph::CompiledGraph`
//! and the per-sample loops in `serve`) with one engine:
//!
//! * [`ExecPlan`] lowers an [`crate::graph::AdderGraph`] plus its ASAP
//!   [`crate::graph::Schedule`] into a level-sorted structure-of-arrays
//!   instruction stream: separate `u32` operand-index and `f32`
//!   coefficient arrays, outputs resolved to direct value indices, and
//!   per-level op ranges (ops of ASAP level *l* are contiguous).
//! * [`BatchEngine`] evaluates a plan **batch-major**: every graph value
//!   owns a contiguous `B`-wide lane of samples, so each op is a tight
//!   `d[s] = ca*a[s] + cb*b[s]` loop over the lane — cache-friendly and
//!   auto-vectorizable — instead of re-walking the graph per sample.
//!   Batches are split into chunks executed in parallel with scoped
//!   threads; within a single chunk, very wide ASAP levels can also be
//!   split across threads (every op in a level is independent — the same
//!   property that makes the level a single FPGA cycle). Lane buffers
//!   come from a [`BufferPool`], so steady-state serving performs no
//!   values-buffer allocation per batch.
//! * [`WorkerPool`] is the persistent, lazily-started worker pool the
//!   parallel kernels dispatch onto (default `pool_mode = persistent`):
//!   workers park between batches instead of being spawned per call, so
//!   steady-state `execute_batch` spawns zero threads. One process-wide
//!   pool ([`global_pool`]) is shared by every engine unless an engine
//!   is built with its own; `pool_mode = scoped` keeps the per-call
//!   `std::thread::scope` path as a selectable fallback. Task panics are
//!   isolated: the one batch fails, the pool survives.
//! * [`ShardPlan`] / [`ShardedExecutor`] partition a plan by
//!   output-column ranges into independent sub-plans served by per-shard
//!   engines (`Arc<dyn Executor>` — local [`BatchEngine`]s today, remote
//!   stubs tomorrow): a batch is scattered to every shard, executed
//!   serially or concurrently (`ExecConfig::{shards, shard_mode}`), and
//!   the column slices gathered back bit-identically to the unsharded
//!   engine. [`engine_for_graph`] is the entry point that picks
//!   sharded-vs-plain from the config.
//! * [`FixedEngine`] is the hardware-faithful integer mode
//!   (`exec_mode = fixed`): [`FixedPlan`] lowers an [`ExecPlan`] the
//!   rest of the way to the paper's datapath — activations quantized to
//!   integer mantissas, every `±2^k` coefficient recovered as a
//!   `(shift, negate)` pair from its CSD digit form
//!   ([`po2_shift_negate`]), and each node evaluated as two arithmetic
//!   shifts plus one integer add. Accumulator width and overflow policy
//!   are configurable; lowering computes an analytic per-output error
//!   bound, and integer lanes make results bit-stable across chunking,
//!   threading and sharding.
//! * Plan specialization: `ExecPlan` sorts the ops of each ASAP level by
//!   their `(shift, negate)` signature and records homogeneous *runs*,
//!   so both engines dispatch a specialized kernel once per run over a
//!   contiguous SoA slice instead of branching per op.
//! * [`RemoteExecutor`] / [`ShardWorker`] (`exec::remote`) carry a shard
//!   across a process boundary: a hand-rolled length-prefixed binary
//!   protocol over std TCP, bounded timeouts + retry with backoff on the
//!   client, and typed [`ExecError`]s so a dead shard *sheds* the batch
//!   (`shard.<i>.dead` metric) instead of hanging it.
//!   [`remote_sharded_executor`] gathers a list of `host:port` workers
//!   behind a [`ShardedExecutor`] interchangeably with local engines.
//! * [`Executor`] is the extension point future backends implement
//!   (sharded engines, GPU/accelerator lowerings, remote execution). The
//!   serving layer's `ExecutorBackend` serves any `Arc<dyn Executor>`.
//! * [`NaiveExecutor`] wraps the original interpreter and is kept only as
//!   the reference oracle for equivalence tests
//!   (`rust/tests/exec_equivalence.rs`).
//!
//! Numerics: the float engine evaluates exactly the same `mul, mul, add`
//! expression per node as the interpreter, in topological order, so
//! outputs are bit-identical to the oracle (no FMA contraction, no
//! reassociation; the run-specialized add/sub kernels are IEEE-identical
//! rewrites). The fixed engine instead matches the float oracle within
//! [`FixedPlan::error_bounds`]. Tuning lives in
//! [`crate::config::ExecConfig`].

mod engine;
mod fixed;
mod oracle;
mod plan;
mod pool;
pub mod remote;
mod sharded;
mod workers;

pub use engine::BatchEngine;
pub use fixed::{po2_shift_negate, FixedEngine, FixedPlan};
pub use oracle::NaiveExecutor;
pub use plan::ExecPlan;
pub use pool::BufferPool;
pub use remote::{
    remote_sharded_executor, RemoteExecutor, RemoteOptions, ReplicatedExecutor, ShardWorker,
};
pub use sharded::{engine_for_graph, even_ranges, ShardPlan, ShardedExecutor};
pub use workers::{global_pool, PoolPanic, PoolStats, WorkerPool};

pub(crate) use sharded::engine_for_plan;

/// Typed execution failure, introduced for backends that can fail at
/// runtime (today: remote shards). Local engines are infallible — their
/// contract violations are bugs and still panic.
///
/// The vendored `anyhow` is string-backed (no downcast), so failover
/// decisions must flow through this enum, not through `anyhow::Error`:
/// [`Executor::try_execute_batch_into`] and the serving layer's
/// `try_eval_batch` keep the type all the way to the router, where
/// `Unavailable` becomes a `ServeError::Shed` and `Failed` a
/// `ServeError::Backend`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// The backend cannot serve right now (dead/unreachable shard).
    /// Callers should shed the request, not fail the model.
    Unavailable { shard: String, message: String },
    /// The batch was rejected or the engine failed; retrying the same
    /// request cannot help.
    Failed { message: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Unavailable { shard, message } => {
                write!(f, "shard {shard} unavailable: {message}")
            }
            ExecError::Failed { message } => write!(f, "execution failed: {message}"),
        }
    }
}

impl std::error::Error for ExecError {}

/// Point-in-time availability of an executor, as reported by
/// [`Executor::health_report`]. Local engines are always [`Ready`];
/// remote shards probe their worker (a `Ping` round-trip over the
/// existing connection) and report drain/cooldown state, so the serving
/// layer can publish per-shard health gauges without sending a batch.
///
/// [`Ready`]: ExecHealth::Ready
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecHealth {
    /// Serving normally.
    Ready,
    /// Worker is draining: in-flight batches finish, new ones are
    /// refused with a typed error (clients fail over or shed).
    Draining,
    /// In the dead-cooldown window after exhausted retries; calls shed
    /// until the half-open probe un-deads the shard.
    Dead,
    /// Liveness cannot be determined cheaply (e.g. no open connection
    /// and the cooldown has lapsed, so the next batch will re-dial).
    Unknown,
}

impl ExecHealth {
    /// Stable gauge encoding for metrics: `1` ready, `0.5` draining,
    /// `0` dead, `-1` unknown.
    pub fn as_gauge(self) -> f64 {
        match self {
            ExecHealth::Ready => 1.0,
            ExecHealth::Draining => 0.5,
            ExecHealth::Dead => 0.0,
            ExecHealth::Unknown => -1.0,
        }
    }

    /// Short lowercase label for logs.
    pub fn as_str(self) -> &'static str {
        match self {
            ExecHealth::Ready => "ready",
            ExecHealth::Draining => "draining",
            ExecHealth::Dead => "dead",
            ExecHealth::Unknown => "unknown",
        }
    }
}

/// Per-layer execution statistics a chained executor reports through
/// [`Executor::layer_stats`]. The serving layer publishes these as
/// `model.<name>.layer.<k>.*` gauges in `Server::metrics_text`, so a
/// multi-layer model's per-layer cost is observable without tracing.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerStat {
    /// 1-based layer index (matches the checkpoint's `layer<k>` naming)
    pub index: usize,
    /// total microseconds spent executing this layer's batches
    pub batch_us_total: u64,
    /// batches executed through this layer
    pub batches: u64,
    /// additions of the layer's lowered program, when it has one
    pub additions: Option<usize>,
    /// analytic |served − exact| bound of the layer's datapath
    /// (0 on the float engines)
    pub err_bound: f64,
}

impl LayerStat {
    /// Mean microseconds per batch (0 before the first batch).
    pub fn mean_batch_us(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_us_total as f64 / self.batches as f64
        }
    }
}

/// A runtime for adder graphs: evaluates batches of input vectors to
/// batches of output vectors. Implementations must be shareable across
/// threads (the serving layer holds them behind `Arc<dyn Executor>`).
pub trait Executor: Send + Sync {
    /// Number of graph inputs each sample must provide.
    fn num_inputs(&self) -> usize;

    /// Number of outputs produced per sample.
    fn num_outputs(&self) -> usize;

    /// Short identifier for logs and bench tables.
    fn name(&self) -> &'static str;

    /// Evaluate a batch; `ys` is resized to `xs.len()` rows. Hot-path
    /// implementations ([`BatchEngine`]) reuse existing row allocations
    /// (zero per-row allocation in steady state); the testing oracle
    /// ([`NaiveExecutor`]) allocates per sample. Panics if a sample has
    /// the wrong input length.
    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>);

    /// Fallible variant of [`Executor::execute_batch_into`] for backends
    /// that can legitimately fail at runtime (remote shards). The
    /// default forwards to the infallible path — local engines never
    /// return `Err`; [`RemoteExecutor`] and [`ShardedExecutor`]
    /// override this to surface typed [`ExecError`]s instead of
    /// panicking, so the serving layer can shed and fail over.
    fn try_execute_batch_into(
        &self,
        xs: &[Vec<f32>],
        ys: &mut Vec<Vec<f32>>,
    ) -> Result<(), ExecError> {
        self.execute_batch_into(xs, ys);
        Ok(())
    }

    /// Health snapshot as `(label, health)` pairs. The default is a
    /// single always-[`ExecHealth::Ready`] entry with an empty label
    /// (local engines cannot be down). Composite executors
    /// ([`ShardedExecutor`], [`ReplicatedExecutor`]) flat-map their
    /// children, prefixing labels (`shard.0`, `shard.0.replica.1`);
    /// [`RemoteExecutor`] reports its probed worker state. Must be
    /// cheap and non-blocking beyond one bounded ping — it runs on the
    /// metrics render path.
    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        vec![(String::new(), ExecHealth::Ready)]
    }

    /// Per-layer statistics for chained executors
    /// (`compress::NetworkExecutor`): batch timing, additions and the
    /// per-layer error bound, one [`LayerStat`] per chained layer. The
    /// default — single-program engines have no layer structure —
    /// reports nothing.
    fn layer_stats(&self) -> Vec<LayerStat> {
        Vec::new()
    }

    /// Allocating convenience wrapper around [`Executor::execute_batch_into`].
    fn execute_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut ys = Vec::new();
        self.execute_batch_into(xs, &mut ys);
        ys
    }

    /// Evaluate a single sample.
    fn execute_one(&self, x: &[f32]) -> Vec<f32> {
        let xs = [x.to_vec()];
        let mut ys = Vec::new();
        self.execute_batch_into(&xs, &mut ys);
        ys.pop().expect("one output row per sample")
    }
}
