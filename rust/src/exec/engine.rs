//! The batch-major engine: chunked, pooled, optionally parallel
//! evaluation of an [`ExecPlan`].

use super::plan::ExecPlan;
use super::pool::BufferPool;
use super::Executor;
use crate::config::ExecConfig;
use crate::graph::AdderGraph;
use std::sync::Mutex;

/// Batch-major adder-graph executor.
///
/// A batch of `B` samples is split into chunks of `cfg.chunk` samples;
/// each chunk is evaluated lane-wise (every graph value holds a
/// contiguous chunk-wide lane). Chunks run in parallel on scoped threads
/// when the batch is large enough (`cfg.parallel_min_batch`); for small
/// batches of very wide graphs the engine instead splits the independent
/// ops *within* each ASAP level across threads
/// (`cfg.level_parallel_min_ops`). Lane buffers are recycled through a
/// [`BufferPool`], so steady-state execution does not allocate them.
///
/// Parallelism uses `std::thread::scope` (workers borrow the batch), so
/// each parallel `execute_batch` spawns and joins its workers. That
/// overhead is why `parallel_min_batch` defaults above the serving
/// layer's batch sizes: the latency path stays spawn-free, and the
/// throughput path (offline eval, benches) amortizes the spawns over
/// large batches. A persistent scoped worker pool is a known follow-up
/// (ROADMAP).
#[derive(Debug)]
pub struct BatchEngine {
    plan: ExecPlan,
    cfg: ExecConfig,
    pool: BufferPool,
}

impl Clone for BatchEngine {
    fn clone(&self) -> Self {
        // the pool is a cache, not state: a clone starts with an empty one
        BatchEngine { plan: self.plan.clone(), cfg: self.cfg, pool: BufferPool::new() }
    }
}

impl BatchEngine {
    /// Lower and wrap a graph with the default [`ExecConfig`].
    pub fn new(g: &AdderGraph) -> Self {
        Self::with_config(g, ExecConfig::default())
    }

    pub fn with_config(g: &AdderGraph, cfg: ExecConfig) -> Self {
        Self::from_plan(ExecPlan::new(g), cfg)
    }

    pub fn from_plan(plan: ExecPlan, cfg: ExecConfig) -> Self {
        BatchEngine { plan, cfg, pool: BufferPool::new() }
    }

    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    pub fn config(&self) -> &ExecConfig {
        &self.cfg
    }

    fn resolved_threads(&self) -> usize {
        // hard cap: a misconfigured thread count must never translate
        // into unbounded OS-thread spawns in the kernels below
        const MAX_THREADS: usize = 1024;
        let t = if self.cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.cfg.threads
        };
        t.clamp(1, MAX_THREADS)
    }
}

impl Executor for BatchEngine {
    fn num_inputs(&self) -> usize {
        self.plan.num_inputs()
    }

    fn num_outputs(&self) -> usize {
        self.plan.num_outputs()
    }

    fn name(&self) -> &'static str {
        "batch-engine"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        let b = xs.len();
        ys.resize_with(b, Vec::new);
        if b == 0 {
            return;
        }
        let chunk = self.cfg.chunk.max(1);
        let threads = self.resolved_threads();
        let n_chunks = b.div_ceil(chunk);
        if threads > 1 && n_chunks > 1 && b >= self.cfg.parallel_min_batch {
            // data parallelism: independent chunks, one worker + one lane
            // buffer each, pulled from a shared job list
            let jobs: Mutex<Vec<(&[Vec<f32>], &mut [Vec<f32>])>> =
                Mutex::new(xs.chunks(chunk).zip(ys.chunks_mut(chunk)).collect());
            let workers = threads.min(n_chunks);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| {
                        let mut buf = self.pool.take();
                        loop {
                            let job = jobs.lock().unwrap().pop();
                            match job {
                                Some((xc, yc)) => self.plan.eval_lanes(xc, &mut buf, yc),
                                None => break,
                            }
                        }
                        self.pool.put(buf);
                    });
                }
            });
        } else {
            let mut buf = self.pool.take();
            let level_parallel =
                threads > 1 && self.plan.max_level_ops() >= self.cfg.level_parallel_min_ops;
            for (xc, yc) in xs.chunks(chunk).zip(ys.chunks_mut(chunk)) {
                if level_parallel {
                    self.plan.eval_lanes_level_parallel(
                        xc,
                        &mut buf,
                        yc,
                        threads,
                        self.cfg.level_parallel_min_ops,
                    );
                } else {
                    self.plan.eval_lanes(xc, &mut buf, yc);
                }
            }
            self.pool.put(buf);
        }
    }

    fn execute_one(&self, x: &[f32]) -> Vec<f32> {
        // scalar fast path: no lane layout, just the flattened program
        let mut scratch = self.pool.take();
        let mut out = Vec::with_capacity(self.plan.num_outputs());
        self.plan.execute_one_into(x, &mut scratch, &mut out);
        self.pool.put(scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Operand, OutputSpec};
    use crate::util::Rng;

    fn ladder_graph(inputs: usize, nodes: usize, seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..nodes {
            let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..4)
            .map(|_| OutputSpec::Ref(refs[rng.below(refs.len())]))
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn all_configs_match_scalar_plan() {
        let mut rng = Rng::new(0);
        let g = ladder_graph(6, 50, 1);
        let plan = ExecPlan::new(&g);
        let configs = [
            ExecConfig { threads: 1, chunk: 4, ..ExecConfig::default() },
            ExecConfig { threads: 4, chunk: 4, parallel_min_batch: 2, ..ExecConfig::default() },
            ExecConfig {
                threads: 3,
                chunk: 1024,
                parallel_min_batch: usize::MAX,
                level_parallel_min_ops: 1,
                ..ExecConfig::default()
            },
        ];
        for cfg in configs {
            let engine = BatchEngine::with_config(&g, cfg);
            for b in [0usize, 1, 3, 17, 33] {
                let xs: Vec<Vec<f32>> =
                    (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
                let ys = engine.execute_batch(&xs);
                assert_eq!(ys.len(), b);
                for (x, y) in xs.iter().zip(&ys) {
                    assert_eq!(*y, plan.execute_one(x), "cfg {cfg:?} b {b}");
                }
            }
        }
    }

    #[test]
    fn execute_one_matches_batch() {
        let mut rng = Rng::new(2);
        let g = ladder_graph(4, 20, 3);
        let engine = BatchEngine::new(&g);
        let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
        let one = engine.execute_one(&x);
        let batch = engine.execute_batch(&[x.clone()]);
        assert_eq!(one, batch[0]);
        assert_eq!(one.len(), engine.num_outputs());
    }

    #[test]
    fn steady_state_reuses_pooled_buffers() {
        let g = ladder_graph(4, 20, 4);
        let engine = BatchEngine::with_config(
            &g,
            ExecConfig { threads: 1, ..ExecConfig::default() },
        );
        let xs: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
        let mut ys = Vec::new();
        engine.execute_batch_into(&xs, &mut ys);
        assert_eq!(engine.pool.cached(), 1, "lane buffer must return to the pool");
        let first = ys.clone();
        engine.execute_batch_into(&xs, &mut ys);
        assert_eq!(first, ys);
        assert_eq!(engine.pool.cached(), 1);
    }

    #[test]
    fn engine_is_shareable_as_dyn_executor() {
        let g = ladder_graph(3, 10, 5);
        let engine: std::sync::Arc<dyn Executor> = std::sync::Arc::new(BatchEngine::new(&g));
        let xs = vec![vec![1.0, 2.0, 3.0]];
        let ys = engine.execute_batch(&xs);
        assert_eq!(ys.len(), 1);
        assert_eq!(ys[0].len(), engine.num_outputs());
    }
}
