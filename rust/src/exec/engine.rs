//! The batch-major engine: chunked, pooled, optionally parallel
//! evaluation of an [`ExecPlan`].

use super::plan::ExecPlan;
use super::pool::BufferPool;
use super::workers::{self, WorkerPool};
use super::Executor;
use crate::config::{ExecConfig, PoolMode};
use crate::graph::AdderGraph;
use std::sync::{Arc, Mutex};

/// Batch-major adder-graph executor.
///
/// A batch of `B` samples is split into chunks of `cfg.chunk` samples;
/// each chunk is evaluated lane-wise (every graph value holds a
/// contiguous chunk-wide lane). Chunks run in parallel when the batch is
/// large enough (`cfg.parallel_min_batch`); for small batches of very
/// wide graphs the engine instead splits the independent ops *within*
/// each ASAP level across workers (`cfg.level_parallel_min_ops`). Lane
/// buffers are recycled through a [`BufferPool`], so steady-state
/// execution does not allocate them.
///
/// Parallel work is dispatched per `cfg.pool_mode`: `Persistent`
/// (default) runs it on a lazily-started [`WorkerPool`] — shared
/// process-wide unless the engine was built with its own via
/// [`BatchEngine::with_workers`] — so steady-state `execute_batch`
/// spawns no threads; `Scoped` keeps the PR-1 per-call
/// `std::thread::scope` spawn/join path as a fallback and for
/// differential testing (`rust/tests/exec_equivalence.rs` diffs the
/// two).
#[derive(Debug)]
pub struct BatchEngine {
    plan: ExecPlan,
    cfg: ExecConfig,
    pool: BufferPool,
    workers: Arc<WorkerPool>,
}

impl Clone for BatchEngine {
    fn clone(&self) -> Self {
        // the buffer pool is a cache, not state: a clone starts with an
        // empty one; the worker pool is shared infrastructure
        BatchEngine {
            plan: self.plan.clone(),
            cfg: self.cfg,
            pool: BufferPool::new(),
            workers: Arc::clone(&self.workers),
        }
    }
}

impl BatchEngine {
    /// Lower and wrap a graph with the default [`ExecConfig`].
    pub fn new(g: &AdderGraph) -> Self {
        Self::with_config(g, ExecConfig::default())
    }

    pub fn with_config(g: &AdderGraph, cfg: ExecConfig) -> Self {
        Self::from_plan(ExecPlan::new(g), cfg)
    }

    /// Like [`BatchEngine::with_config`] with an engine-private worker
    /// pool instead of the process-wide one (isolation, tests).
    pub fn with_workers(g: &AdderGraph, cfg: ExecConfig, workers: Arc<WorkerPool>) -> Self {
        Self::from_plan_with_workers(ExecPlan::new(g), cfg, workers)
    }

    pub fn from_plan(plan: ExecPlan, cfg: ExecConfig) -> Self {
        Self::from_plan_with_workers(plan, cfg, workers::global_pool())
    }

    pub fn from_plan_with_workers(
        plan: ExecPlan,
        cfg: ExecConfig,
        workers: Arc<WorkerPool>,
    ) -> Self {
        BatchEngine { plan, cfg, pool: BufferPool::new(), workers }
    }

    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    pub fn config(&self) -> &ExecConfig {
        &self.cfg
    }

    /// The worker pool parallel dispatch runs on (shared process-wide
    /// unless the engine was built with its own).
    pub fn worker_pool(&self) -> &Arc<WorkerPool> {
        &self.workers
    }

    fn resolved_threads(&self) -> usize {
        workers::resolve_threads(self.cfg.threads)
    }
}

impl Executor for BatchEngine {
    fn num_inputs(&self) -> usize {
        self.plan.num_inputs()
    }

    fn num_outputs(&self) -> usize {
        self.plan.num_outputs()
    }

    fn name(&self) -> &'static str {
        "batch-engine"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        let b = xs.len();
        ys.resize_with(b, Vec::new);
        if b == 0 {
            return;
        }
        let chunk = self.cfg.chunk.max(1);
        let threads = self.resolved_threads();
        let n_chunks = b.div_ceil(chunk);
        if threads > 1 && n_chunks > 1 && b >= self.cfg.parallel_min_batch {
            // data parallelism: independent chunks, one worker + one lane
            // buffer each, pulled from a shared job list
            let jobs: Mutex<Vec<(&[Vec<f32>], &mut [Vec<f32>])>> =
                Mutex::new(xs.chunks(chunk).zip(ys.chunks_mut(chunk)).collect());
            let workers = threads.min(n_chunks);
            let drain = || {
                let mut buf = self.pool.take();
                loop {
                    let job = jobs.lock().unwrap().pop();
                    match job {
                        Some((xc, yc)) => self.plan.eval_lanes(xc, &mut buf, yc),
                        None => break,
                    }
                }
                self.pool.put(buf);
            };
            match self.cfg.pool_mode {
                PoolMode::Persistent => {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
                    for _ in 0..workers {
                        tasks.push(Box::new(&drain));
                    }
                    if let Err(e) = self.workers.run_scoped(tasks) {
                        panic!("exec worker pool: {e}");
                    }
                }
                PoolMode::Scoped => {
                    std::thread::scope(|scope| {
                        for _ in 0..workers {
                            scope.spawn(&drain);
                        }
                    });
                }
            }
        } else {
            let mut buf = self.pool.take();
            let level_parallel =
                threads > 1 && self.plan.max_level_ops() >= self.cfg.level_parallel_min_ops;
            let level_pool = match self.cfg.pool_mode {
                PoolMode::Persistent => Some(&*self.workers),
                PoolMode::Scoped => None,
            };
            for (xc, yc) in xs.chunks(chunk).zip(ys.chunks_mut(chunk)) {
                if level_parallel {
                    self.plan.eval_lanes_level_parallel(
                        xc,
                        &mut buf,
                        yc,
                        threads,
                        self.cfg.level_parallel_min_ops,
                        level_pool,
                    );
                } else {
                    self.plan.eval_lanes(xc, &mut buf, yc);
                }
            }
            self.pool.put(buf);
        }
    }

    fn execute_one(&self, x: &[f32]) -> Vec<f32> {
        // scalar fast path: no lane layout, just the flattened program
        let mut scratch = self.pool.take();
        let mut out = Vec::with_capacity(self.plan.num_outputs());
        self.plan.execute_one_into(x, &mut scratch, &mut out);
        self.pool.put(scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Operand, OutputSpec};
    use crate::util::Rng;

    fn ladder_graph(inputs: usize, nodes: usize, seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..nodes {
            let a = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(5) as i32 - 2, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..4)
            .map(|_| OutputSpec::Ref(refs[rng.below(refs.len())]))
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn all_configs_match_scalar_plan() {
        let mut rng = Rng::new(0);
        let g = ladder_graph(6, 50, 1);
        let plan = ExecPlan::new(&g);
        let base = [
            ExecConfig { threads: 1, chunk: 4, ..ExecConfig::default() },
            ExecConfig { threads: 4, chunk: 4, parallel_min_batch: 2, ..ExecConfig::default() },
            ExecConfig {
                threads: 3,
                chunk: 1024,
                parallel_min_batch: usize::MAX,
                level_parallel_min_ops: 1,
                ..ExecConfig::default()
            },
        ];
        for mode in [PoolMode::Scoped, PoolMode::Persistent] {
            for cfg in base {
                let cfg = ExecConfig { pool_mode: mode, ..cfg };
                let engine = BatchEngine::with_config(&g, cfg);
                for b in [0usize, 1, 3, 17, 33] {
                    let xs: Vec<Vec<f32>> =
                        (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
                    let ys = engine.execute_batch(&xs);
                    assert_eq!(ys.len(), b);
                    for (x, y) in xs.iter().zip(&ys) {
                        assert_eq!(*y, plan.execute_one(x), "cfg {cfg:?} b {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn engines_share_the_process_wide_pool_by_default() {
        let a = BatchEngine::new(&ladder_graph(3, 10, 6));
        let b = BatchEngine::new(&ladder_graph(3, 10, 7));
        assert!(
            std::sync::Arc::ptr_eq(a.worker_pool(), b.worker_pool()),
            "default engines must share the global worker pool"
        );
        // a clone shares its source's pool; an explicit pool is private
        assert!(std::sync::Arc::ptr_eq(a.clone().worker_pool(), a.worker_pool()));
        let private = std::sync::Arc::new(WorkerPool::new(2, 0, 20));
        let c = BatchEngine::with_workers(
            &ladder_graph(3, 10, 8),
            ExecConfig::default(),
            std::sync::Arc::clone(&private),
        );
        assert!(!std::sync::Arc::ptr_eq(c.worker_pool(), a.worker_pool()));
    }

    #[test]
    fn execute_one_matches_batch() {
        let mut rng = Rng::new(2);
        let g = ladder_graph(4, 20, 3);
        let engine = BatchEngine::new(&g);
        let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
        let one = engine.execute_one(&x);
        let batch = engine.execute_batch(&[x.clone()]);
        assert_eq!(one, batch[0]);
        assert_eq!(one.len(), engine.num_outputs());
    }

    #[test]
    fn steady_state_reuses_pooled_buffers() {
        let g = ladder_graph(4, 20, 4);
        let engine = BatchEngine::with_config(
            &g,
            ExecConfig { threads: 1, ..ExecConfig::default() },
        );
        let xs: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
        let mut ys = Vec::new();
        engine.execute_batch_into(&xs, &mut ys);
        assert_eq!(engine.pool.cached(), 1, "lane buffer must return to the pool");
        let first = ys.clone();
        engine.execute_batch_into(&xs, &mut ys);
        assert_eq!(first, ys);
        assert_eq!(engine.pool.cached(), 1);
    }

    #[test]
    fn engine_is_shareable_as_dyn_executor() {
        let g = ladder_graph(3, 10, 5);
        let engine: std::sync::Arc<dyn Executor> = std::sync::Arc::new(BatchEngine::new(&g));
        let xs = vec![vec![1.0, 2.0, 3.0]];
        let ys = engine.execute_batch(&xs);
        assert_eq!(ys.len(), 1);
        assert_eq!(ys[0].len(), engine.num_outputs());
    }
}
