//! Lowering: adder graph + ASAP schedule → level-sorted SoA instruction
//! stream with direct indices and precomputed coefficients.

use super::workers::WorkerPool;
use crate::graph::{schedule, AdderGraph, NodeRef, OutputSpec, Schedule};

/// Output resolution: zero row or a scaled read of a value slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum OutOp {
    Zero,
    Scaled { idx: u32, c: f32 },
}

/// Executable lowering of an [`AdderGraph`].
///
/// Value slots are numbered `0..num_inputs` for the graph inputs followed
/// by one slot per op in **ASAP-level order**, so the ops of level *l*
/// write the contiguous slot range `num_inputs + level_range(l)`. That
/// contiguity is what lets the batch engine split a level's lanes across
/// threads with safe disjoint borrows. Within a level, ops are further
/// sorted by coefficient signature `(shift_a, neg_a, shift_b, neg_b)`
/// (stable), grouping same-shape ops into contiguous **runs**: the lane
/// kernels load the coefficient pair and pick a specialized inner loop
/// once per run instead of once per op. Reordering within a level is
/// sound — operands always live in strictly earlier levels — and leaves
/// every per-node expression (hence every output) bit-identical.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    num_inputs: usize,
    ia: Vec<u32>,
    ca: Vec<f32>,
    ib: Vec<u32>,
    cb: Vec<f32>,
    /// ops of ASAP level `l` (1-based) occupy `level_starts[l-1]..level_starts[l]`
    level_starts: Vec<u32>,
    /// maximal same-coefficient spans within levels: run `r` is
    /// `runs[r]..runs[r+1]`, uniform `(ca, cb)`, never crossing a level
    /// boundary — the dispatch unit of the run-grouped kernels
    runs: Vec<u32>,
    outs: Vec<OutOp>,
    max_level_ops: usize,
}

/// Run boundaries: a new run at every level start and wherever the
/// coefficient pair changes within a level.
fn compute_runs(ca: &[f32], cb: &[f32], level_starts: &[u32]) -> Vec<u32> {
    let n = ca.len();
    let mut runs = vec![0u32];
    for l in 1..level_starts.len() {
        let (lo, hi) = (level_starts[l - 1] as usize, level_starts[l] as usize);
        for j in lo..hi {
            if j > 0 && (j == lo || ca[j] != ca[j - 1] || cb[j] != cb[j - 1]) {
                runs.push(j as u32);
            }
        }
    }
    if n > 0 {
        runs.push(n as u32);
    }
    runs
}

impl ExecPlan {
    /// Lower a graph, computing its ASAP schedule internally.
    pub fn new(g: &AdderGraph) -> Self {
        Self::with_schedule(g, &schedule(g))
    }

    /// Lower a graph with a precomputed schedule (must belong to `g`).
    pub fn with_schedule(g: &AdderGraph, s: &Schedule) -> Self {
        let n = g.nodes().len();
        assert_eq!(s.levels.len(), n, "schedule does not match graph");
        let num_levels = s.levels.iter().copied().max().unwrap_or(0);

        // stable sort by ASAP level, then by operand signature within the
        // level: contiguous levels, and same-shape ops adjacent so the
        // kernels dispatch once per run (original order kept within ties)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| {
            let nd = g.nodes()[i];
            (s.levels[i], nd.a.shift, nd.a.negative, nd.b.shift, nd.b.negative)
        });
        let mut perm = vec![0u32; n];
        for (new, &orig) in order.iter().enumerate() {
            perm[orig] = new as u32;
        }

        let base = g.num_inputs() as u32;
        let idx = |r: NodeRef| -> u32 {
            match r {
                NodeRef::Input(i) => i,
                NodeRef::Node(i) => base + perm[i as usize],
            }
        };

        let mut ia = Vec::with_capacity(n);
        let mut ca = Vec::with_capacity(n);
        let mut ib = Vec::with_capacity(n);
        let mut cb = Vec::with_capacity(n);
        for &orig in &order {
            let node = g.nodes()[orig];
            ia.push(idx(node.a.src));
            ca.push(node.a.coeff());
            ib.push(idx(node.b.src));
            cb.push(node.b.coeff());
        }

        let mut level_starts = vec![0u32; num_levels + 1];
        for &l in &s.levels {
            level_starts[l] += 1; // node levels are 1-based; slot 0 stays 0
        }
        for l in 1..=num_levels {
            level_starts[l] += level_starts[l - 1];
        }
        let max_level_ops = (1..=num_levels)
            .map(|l| (level_starts[l] - level_starts[l - 1]) as usize)
            .max()
            .unwrap_or(0);

        let outs = g
            .outputs()
            .iter()
            .map(|o| match o {
                OutputSpec::Zero => OutOp::Zero,
                OutputSpec::Ref(op) => OutOp::Scaled { idx: idx(op.src), c: op.coeff() },
            })
            .collect();

        let runs = compute_runs(&ca, &cb, &level_starts);
        ExecPlan {
            num_inputs: g.num_inputs(),
            ia,
            ca,
            ib,
            cb,
            level_starts,
            runs,
            outs,
            max_level_ops,
        }
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.outs.len()
    }

    /// Op count — the paper's addition metric.
    pub fn additions(&self) -> usize {
        self.ia.len()
    }

    /// Total value slots (inputs + one per op).
    pub fn num_values(&self) -> usize {
        self.num_inputs + self.ia.len()
    }

    /// Pipeline depth (number of ASAP levels with at least one op).
    pub fn num_levels(&self) -> usize {
        self.level_starts.len() - 1
    }

    /// Widest level — the available intra-batch op parallelism.
    pub fn max_level_ops(&self) -> usize {
        self.max_level_ops
    }

    /// Homogeneous dispatch runs (uniform coefficient pair within one
    /// ASAP level). Always `<= additions()`; the gap is what the
    /// run-grouped kernels amortize away.
    pub fn num_runs(&self) -> usize {
        self.runs.len().saturating_sub(1)
    }

    /// Raw operand slot indices `(ia, ib)` — for alternate lowerings
    /// (the fixed-point plan) that mirror this plan's slot layout.
    pub(crate) fn op_indices(&self) -> (&[u32], &[u32]) {
        (&self.ia, &self.ib)
    }

    /// Raw operand coefficients `(ca, cb)`, level-and-signature sorted.
    pub(crate) fn op_coeffs(&self) -> (&[f32], &[f32]) {
        (&self.ca, &self.cb)
    }

    /// Run boundaries (see [`ExecPlan::num_runs`]).
    pub(crate) fn run_bounds(&self) -> &[u32] {
        &self.runs
    }

    /// Output resolutions over this plan's value slots.
    pub(crate) fn out_ops(&self) -> &[OutOp] {
        &self.outs
    }

    /// Extract the sub-plan computing the output slice `lo..hi` — the
    /// per-shard lowering behind [`crate::exec::ShardPlan`].
    ///
    /// The sub-plan keeps the full input arity (a shard receives the
    /// same scattered batch as every other shard) and exactly the ops
    /// backward-reachable from the selected outputs, in the original
    /// level-sorted order with their original ASAP levels — every kept
    /// op evaluates the identical `ca*a + cb*b` expression on identical
    /// operand values, so a shard's outputs are bit-identical to the
    /// same outputs of the full plan.
    pub fn extract_output_range(&self, lo: usize, hi: usize) -> ExecPlan {
        assert!(lo <= hi && hi <= self.outs.len(), "output range {lo}..{hi} out of bounds");
        let n = self.ia.len();
        let base = self.num_inputs as u32;
        // backward reachability: outputs first, then ops in reverse
        // (operands always point at strictly earlier slots)
        let mut needed = vec![false; n];
        for o in &self.outs[lo..hi] {
            if let OutOp::Scaled { idx, .. } = *o {
                if idx >= base {
                    needed[(idx - base) as usize] = true;
                }
            }
        }
        for j in (0..n).rev() {
            if needed[j] {
                for op in [self.ia[j], self.ib[j]] {
                    if op >= base {
                        needed[(op - base) as usize] = true;
                    }
                }
            }
        }
        // compact the kept ops, preserving order (still level-sorted)
        let mut remap = vec![u32::MAX; n];
        let mut kept = 0u32;
        for (j, r) in remap.iter_mut().enumerate() {
            if needed[j] {
                *r = kept;
                kept += 1;
            }
        }
        let map_idx = |idx: u32| -> u32 {
            if idx < base { idx } else { base + remap[(idx - base) as usize] }
        };
        let mut ia = Vec::with_capacity(kept as usize);
        let mut ca = Vec::with_capacity(kept as usize);
        let mut ib = Vec::with_capacity(kept as usize);
        let mut cb = Vec::with_capacity(kept as usize);
        for j in 0..n {
            if needed[j] {
                ia.push(map_idx(self.ia[j]));
                ca.push(self.ca[j]);
                ib.push(map_idx(self.ib[j]));
                cb.push(self.cb[j]);
            }
        }
        // ops keep their original ASAP levels; count the kept ops per
        // level and drop trailing empty levels (interior empties are
        // fine: the eval loops skip zero-op levels)
        let num_levels = self.level_starts.len() - 1;
        let mut level_starts = vec![0u32; num_levels + 1];
        for l in 1..=num_levels {
            let (a, b) = (self.level_starts[l - 1] as usize, self.level_starts[l] as usize);
            let in_level = (a..b).filter(|&j| needed[j]).count() as u32;
            level_starts[l] = level_starts[l - 1] + in_level;
        }
        while level_starts.len() > 1
            && level_starts[level_starts.len() - 1] == level_starts[level_starts.len() - 2]
        {
            level_starts.pop();
        }
        let max_level_ops = (1..level_starts.len())
            .map(|l| (level_starts[l] - level_starts[l - 1]) as usize)
            .max()
            .unwrap_or(0);
        let outs = self.outs[lo..hi]
            .iter()
            .map(|o| match *o {
                OutOp::Zero => OutOp::Zero,
                OutOp::Scaled { idx, c } => OutOp::Scaled { idx: map_idx(idx), c },
            })
            .collect();
        // kept ops stay (level, signature)-sorted, so run boundaries
        // recompute to maximal homogeneous spans again
        let runs = compute_runs(&ca, &cb, &level_starts);
        ExecPlan {
            num_inputs: self.num_inputs,
            ia,
            ca,
            ib,
            cb,
            level_starts,
            runs,
            outs,
            max_level_ops,
        }
    }

    /// Execute one sample with caller-provided buffers (the scalar path;
    /// `CompiledGraph` delegates here). `scratch` holds the value slots.
    pub fn execute_one_into(&self, x: &[f32], scratch: &mut Vec<f32>, out: &mut Vec<f32>) {
        assert_eq!(x.len(), self.num_inputs, "input length mismatch");
        scratch.clear();
        scratch.reserve(self.num_values());
        scratch.extend_from_slice(x);
        for j in 0..self.ia.len() {
            let v = self.ca[j] * scratch[self.ia[j] as usize]
                + self.cb[j] * scratch[self.ib[j] as usize];
            scratch.push(v);
        }
        out.clear();
        out.extend(self.outs.iter().map(|o| match *o {
            OutOp::Zero => 0.0,
            OutOp::Scaled { idx, c } => c * scratch[idx as usize],
        }));
    }

    /// Allocating scalar execute.
    pub fn execute_one(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Vec::with_capacity(self.num_values());
        let mut out = Vec::with_capacity(self.outs.len());
        self.execute_one_into(x, &mut scratch, &mut out);
        out
    }

    /// Fill the input lanes of a batch-major values buffer:
    /// `buf[v * width + s]` is value `v` of sample `s`. Grow-only: stale
    /// contents are never read, because the input loop writes every input
    /// lane and the level ranges cover every op lane before any read.
    fn fill_input_lanes(&self, xs: &[Vec<f32>], buf: &mut Vec<f32>) {
        let width = xs.len();
        let needed = self.num_values() * width;
        if buf.len() < needed {
            buf.resize(needed, 0.0);
        }
        for (s, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.num_inputs, "input length mismatch");
            for (i, &v) in x.iter().enumerate() {
                buf[i * width + s] = v;
            }
        }
    }

    fn read_output_lanes(&self, buf: &[f32], width: usize, ys: &mut [Vec<f32>]) {
        for (s, y) in ys.iter_mut().enumerate() {
            y.clear();
            y.reserve(self.outs.len());
            for o in &self.outs {
                y.push(match *o {
                    OutOp::Zero => 0.0,
                    OutOp::Scaled { idx, c } => c * buf[idx as usize * width + s],
                });
            }
        }
    }

    /// Batch-major evaluation of one chunk of samples, dispatched once
    /// per homogeneous run. `ys.len()` must equal `xs.len()`; `buf` is
    /// the reusable lane buffer.
    pub(crate) fn eval_lanes(&self, xs: &[Vec<f32>], buf: &mut Vec<f32>, ys: &mut [Vec<f32>]) {
        let width = xs.len();
        debug_assert_eq!(ys.len(), width);
        if width == 0 {
            return;
        }
        self.fill_input_lanes(xs, buf);
        for r in 1..self.runs.len() {
            let (j0, j1) = (self.runs[r - 1] as usize, self.runs[r] as usize);
            let dst_start = (self.num_inputs + j0) * width;
            let (src, dst) = buf.split_at_mut(dst_start);
            self.eval_run(src, &mut dst[..(j1 - j0) * width], j0, width);
        }
        self.read_output_lanes(buf, width, ys);
    }

    /// Per-op reference dispatch (one coefficient load and loop per op,
    /// no run grouping) — the pre-specialization kernel, kept public so
    /// benches can measure the run-grouping win and tests can diff the
    /// two paths. Bit-identical to [`ExecPlan::eval_lanes`] wrapped by
    /// the engines.
    pub fn eval_lanes_per_op(&self, xs: &[Vec<f32>], buf: &mut Vec<f32>, ys: &mut [Vec<f32>]) {
        let width = xs.len();
        assert_eq!(ys.len(), width, "output batch length mismatch");
        if width == 0 {
            return;
        }
        self.fill_input_lanes(xs, buf);
        for j in 0..self.ia.len() {
            let dst_start = (self.num_inputs + j) * width;
            let (src, dst) = buf.split_at_mut(dst_start);
            let a = &src[self.ia[j] as usize * width..][..width];
            let b = &src[self.ib[j] as usize * width..][..width];
            let (ca, cb) = (self.ca[j], self.cb[j]);
            let d = &mut dst[..width];
            for s in 0..width {
                d[s] = ca * a[s] + cb * b[s];
            }
        }
        self.read_output_lanes(buf, width, ys);
    }

    /// Evaluate one homogeneous run (ops `j0..j0 + dst.len()/width`,
    /// uniform `(ca, cb)`) into `dst`. The coefficient pair is inspected
    /// once per run: the ±1 shapes drop their multiplies entirely
    /// (`-1.0 * x` and `x + (-y)` are exact in IEEE float, so every
    /// specialization stays bit-identical to the `mul, mul, add` form).
    fn eval_run(&self, src: &[f32], dst: &mut [f32], j0: usize, width: usize) {
        let (ca, cb) = (self.ca[j0], self.cb[j0]);
        if ca == 1.0 && cb == 1.0 {
            self.run_loop(src, dst, j0, width, |a, b| a + b);
        } else if ca == 1.0 && cb == -1.0 {
            self.run_loop(src, dst, j0, width, |a, b| a - b);
        } else if ca == -1.0 && cb == 1.0 {
            self.run_loop(src, dst, j0, width, |a, b| b - a);
        } else if ca == -1.0 && cb == -1.0 {
            self.run_loop(src, dst, j0, width, |a, b| -a - b);
        } else {
            self.run_loop(src, dst, j0, width, move |a, b| ca * a + cb * b);
        }
    }

    /// The shared run inner loop, monomorphized per kernel shape.
    #[inline]
    fn run_loop<F: Fn(f32, f32) -> f32>(
        &self,
        src: &[f32],
        dst: &mut [f32],
        j0: usize,
        width: usize,
        f: F,
    ) {
        for (k, d) in dst.chunks_mut(width).enumerate() {
            let j = j0 + k;
            let a = &src[self.ia[j] as usize * width..][..width];
            let b = &src[self.ib[j] as usize * width..][..width];
            for s in 0..width {
                d[s] = f(a[s], b[s]);
            }
        }
    }

    /// Like [`ExecPlan::eval_lanes`], but splits the ops of each wide
    /// ASAP level across `threads` workers — dispatched onto `pool` when
    /// given (the persistent path: no thread spawns), or onto per-level
    /// `std::thread::scope` workers otherwise. Sound because ops in one
    /// level only read strictly earlier slots (lower levels/inputs) and
    /// write disjoint contiguous lanes.
    pub(crate) fn eval_lanes_level_parallel(
        &self,
        xs: &[Vec<f32>],
        buf: &mut Vec<f32>,
        ys: &mut [Vec<f32>],
        threads: usize,
        min_ops: usize,
        pool: Option<&WorkerPool>,
    ) {
        let width = xs.len();
        debug_assert_eq!(ys.len(), width);
        if width == 0 {
            return;
        }
        self.fill_input_lanes(xs, buf);
        for l in 1..self.level_starts.len() {
            let lo = self.level_starts[l - 1] as usize;
            let hi = self.level_starts[l] as usize;
            let nops = hi - lo;
            if nops == 0 {
                continue;
            }
            let base = (self.num_inputs + lo) * width;
            let (src, rest) = buf.split_at_mut(base);
            let dst_level = &mut rest[..nops * width];
            let threads = threads.min(nops); // never more workers than ops
            if threads <= 1 || nops < min_ops {
                self.eval_op_span(src, dst_level, lo, width);
            } else {
                let span = nops.div_ceil(threads);
                let src: &[f32] = src;
                match pool {
                    Some(pool) => {
                        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                            Vec::with_capacity(threads);
                        for (t, dspan) in dst_level.chunks_mut(span * width).enumerate() {
                            let j0 = lo + t * span;
                            tasks.push(Box::new(move || {
                                self.eval_op_span(src, dspan, j0, width);
                            }));
                        }
                        if let Err(e) = pool.run_scoped(tasks) {
                            panic!("exec worker pool: {e}");
                        }
                    }
                    None => {
                        std::thread::scope(|scope| {
                            for (t, dspan) in dst_level.chunks_mut(span * width).enumerate() {
                                let j0 = lo + t * span;
                                scope.spawn(move || {
                                    self.eval_op_span(src, dspan, j0, width);
                                });
                            }
                        });
                    }
                }
            }
        }
        self.read_output_lanes(buf, width, ys);
    }

    /// Evaluate ops `j0..j0 + dst.len()/width` into `dst` (their lanes),
    /// reading operands from `src` (all strictly earlier lanes).
    fn eval_op_span(&self, src: &[f32], dst: &mut [f32], j0: usize, width: usize) {
        for (k, d) in dst.chunks_mut(width).enumerate() {
            let j = j0 + k;
            let a = &src[self.ia[j] as usize * width..][..width];
            let b = &src[self.ib[j] as usize * width..][..width];
            let (ca, cb) = (self.ca[j], self.cb[j]);
            for s in 0..width {
                d[s] = ca * a[s] + cb * b[s];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AdderGraph, Operand, OutputSpec};
    use crate::util::Rng;

    fn random_graph(seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let inputs = 2 + rng.below(8);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..40 {
            let a = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())].scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..5)
            .map(|_| {
                if rng.f32() < 0.1 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn scalar_path_bit_identical_to_interpreter() {
        let mut rng = Rng::new(1);
        for seed in 0..8 {
            let g = random_graph(seed);
            let plan = ExecPlan::new(&g);
            assert_eq!(plan.additions(), g.additions());
            assert_eq!(plan.num_outputs(), g.num_outputs());
            let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
            // same per-node expression in topological order: exact equality
            assert_eq!(plan.execute_one(&x), g.execute(&x));
        }
    }

    #[test]
    fn level_ranges_cover_all_ops_in_schedule_order() {
        let g = random_graph(3);
        let s = schedule(&g);
        let plan = ExecPlan::with_schedule(&g, &s);
        assert_eq!(plan.num_levels(), s.width_histogram.len());
        assert_eq!(
            *plan.level_starts.last().unwrap() as usize,
            plan.additions(),
            "levels must cover every op"
        );
        for l in 1..plan.level_starts.len() {
            let n = (plan.level_starts[l] - plan.level_starts[l - 1]) as usize;
            assert_eq!(n, s.width_histogram[l - 1], "level {l} width");
            assert!(plan.max_level_ops() >= n);
        }
        // every operand strictly precedes its destination slot
        for j in 0..plan.additions() {
            let dst = (plan.num_inputs() + j) as u32;
            assert!(plan.ia[j] < dst && plan.ib[j] < dst, "op {j} reads forward");
        }
    }

    #[test]
    fn batch_lanes_match_scalar_path() {
        let mut rng = Rng::new(7);
        let g = random_graph(11);
        let plan = ExecPlan::new(&g);
        let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
        let mut buf = Vec::new();
        let mut ys: Vec<Vec<f32>> = vec![Vec::new(); xs.len()];
        plan.eval_lanes(&xs, &mut buf, &mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, plan.execute_one(x));
        }
        // level-parallel kernel agrees too (forced on with min_ops = 1),
        // on both dispatch paths
        let mut ys2: Vec<Vec<f32>> = vec![Vec::new(); xs.len()];
        plan.eval_lanes_level_parallel(&xs, &mut buf, &mut ys2, 3, 1, None);
        assert_eq!(ys, ys2);
        let wp = WorkerPool::new(2, 0, 20);
        let mut ys3: Vec<Vec<f32>> = vec![Vec::new(); xs.len()];
        plan.eval_lanes_level_parallel(&xs, &mut buf, &mut ys3, 3, 1, Some(&wp));
        assert_eq!(ys, ys3);
    }

    #[test]
    fn runs_are_homogeneous_level_aligned_and_cover_all_ops() {
        for seed in 0..8 {
            let g = random_graph(seed);
            let plan = ExecPlan::new(&g);
            let runs = &plan.runs;
            assert!(plan.num_runs() <= plan.additions());
            assert_eq!(runs.first().copied().unwrap_or(0), 0);
            assert_eq!(*runs.last().unwrap() as usize, plan.additions());
            for r in 1..runs.len() {
                let (j0, j1) = (runs[r - 1] as usize, runs[r] as usize);
                assert!(j0 < j1, "empty run {r}");
                for j in j0..j1 {
                    assert_eq!(plan.ca[j], plan.ca[j0], "run {r} mixes ca");
                    assert_eq!(plan.cb[j], plan.cb[j0], "run {r} mixes cb");
                }
                // a run never crosses a level boundary
                let level = plan.level_starts.partition_point(|&s| (s as usize) <= j0);
                assert!(
                    j1 <= plan.level_starts[level] as usize,
                    "run {r} ({j0}..{j1}) crosses level boundary {}",
                    plan.level_starts[level]
                );
            }
        }
    }

    #[test]
    fn same_signature_ops_coalesce_into_few_runs() {
        // one wide level of identical (a+b)-shaped ops must collapse
        // into a single dispatch run
        let mut g = AdderGraph::new(4);
        for i in 0..32 {
            let a = Operand::input(i % 4);
            let b = Operand::input((i + 1) % 4);
            g.push_add(a, b);
        }
        g.set_outputs(vec![OutputSpec::Ref(Operand::node(31))]);
        let plan = ExecPlan::new(&g);
        assert_eq!(plan.additions(), 32);
        assert_eq!(plan.num_runs(), 1, "uniform signature must be one run");
    }

    #[test]
    fn per_op_dispatch_bit_identical_to_run_grouped() {
        let mut rng = Rng::new(31);
        for seed in 0..6 {
            let g = random_graph(seed);
            let plan = ExecPlan::new(&g);
            let xs: Vec<Vec<f32>> =
                (0..7).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
            let mut buf = Vec::new();
            let mut ys: Vec<Vec<f32>> = vec![Vec::new(); xs.len()];
            plan.eval_lanes(&xs, &mut buf, &mut ys);
            let mut ys_ref: Vec<Vec<f32>> = vec![Vec::new(); xs.len()];
            plan.eval_lanes_per_op(&xs, &mut buf, &mut ys_ref);
            assert_eq!(ys, ys_ref, "seed {seed}");
        }
    }

    #[test]
    fn extracted_output_range_bit_identical_to_full_plan() {
        let mut rng = Rng::new(21);
        for seed in 0..6 {
            let g = random_graph(seed);
            let plan = ExecPlan::new(&g);
            let n = plan.num_outputs();
            let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
            let full = plan.execute_one(&x);
            for (lo, hi) in [(0usize, n), (0, n / 2), (n / 2, n), (1.min(n), n)] {
                let sub = plan.extract_output_range(lo, hi);
                assert_eq!(sub.num_inputs(), plan.num_inputs(), "shards keep full arity");
                assert_eq!(sub.num_outputs(), hi - lo);
                assert!(sub.additions() <= plan.additions(), "never more ops than the whole");
                assert_eq!(sub.execute_one(&x), full[lo..hi].to_vec(), "range {lo}..{hi}");
                // operand indices still strictly precede their slots
                for j in 0..sub.additions() {
                    let dst = (sub.num_inputs() + j) as u32;
                    assert!(sub.ia[j] < dst && sub.ib[j] < dst, "sub op {j} reads forward");
                }
                assert_eq!(
                    *sub.level_starts.last().unwrap() as usize,
                    sub.additions(),
                    "levels cover every kept op"
                );
            }
        }
    }

    #[test]
    fn extracted_empty_range_is_a_no_output_plan() {
        let g = random_graph(5);
        let plan = ExecPlan::new(&g);
        let sub = plan.extract_output_range(0, 0);
        assert_eq!(sub.num_outputs(), 0);
        assert_eq!(sub.additions(), 0, "nothing reachable from no outputs");
        assert!(sub.execute_one(&vec![0.5; plan.num_inputs()]).is_empty());
    }

    #[test]
    fn empty_graph_and_zero_outputs() {
        let mut g = AdderGraph::new(2);
        g.set_outputs(vec![OutputSpec::Zero, OutputSpec::Ref(Operand::input(1))]);
        let plan = ExecPlan::new(&g);
        assert_eq!(plan.num_levels(), 0);
        assert_eq!(plan.execute_one(&[4.0, 5.0]), vec![0.0, 5.0]);
    }
}
