//! The reference oracle: the original scalar interpreter behind the
//! [`Executor`] interface. Kept for differential testing only — the
//! batch engine must match it bit-for-bit (see
//! `rust/tests/exec_equivalence.rs`). It deliberately stays as simple
//! as possible (allocates per sample, no buffer reuse): its job is to
//! be obviously correct, not fast.

use super::Executor;
use crate::graph::AdderGraph;

/// Per-sample interpreter over the un-lowered graph.
pub struct NaiveExecutor {
    graph: AdderGraph,
}

impl NaiveExecutor {
    pub fn new(graph: AdderGraph) -> Self {
        NaiveExecutor { graph }
    }

    pub fn graph(&self) -> &AdderGraph {
        &self.graph
    }
}

impl Executor for NaiveExecutor {
    fn num_inputs(&self) -> usize {
        self.graph.num_inputs()
    }

    fn num_outputs(&self) -> usize {
        self.graph.num_outputs()
    }

    fn name(&self) -> &'static str {
        "naive-interpreter"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        ys.resize_with(xs.len(), Vec::new);
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            *y = self.graph.execute(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AdderGraph, Operand, OutputSpec};

    #[test]
    fn oracle_matches_direct_interpreter() {
        let mut g = AdderGraph::new(2);
        let n = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        g.set_outputs(vec![OutputSpec::Ref(n)]);
        let oracle = NaiveExecutor::new(g.clone());
        assert_eq!(oracle.num_inputs(), 2);
        assert_eq!(oracle.num_outputs(), 1);
        let xs = vec![vec![1.0, 2.0], vec![-0.5, 4.0]];
        let ys = oracle.execute_batch(&xs);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, g.execute(x));
        }
        assert_eq!(oracle.execute_one(&[1.0, 2.0]), g.execute(&[1.0, 2.0]));
    }
}
