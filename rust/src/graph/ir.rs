//! The adder-graph intermediate representation.

/// Reference to a value: either one of the graph inputs or the result of
/// an earlier add node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeRef {
    Input(u32),
    Node(u32),
}

/// A referenced value, bit-shifted by `shift` (multiplication by
/// 2^shift — free in hardware) and optionally negated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Operand {
    pub src: NodeRef,
    pub shift: i32,
    pub negative: bool,
}

impl Operand {
    pub fn input(i: usize) -> Self {
        Operand { src: NodeRef::Input(i as u32), shift: 0, negative: false }
    }

    pub fn node(i: usize) -> Self {
        Operand { src: NodeRef::Node(i as u32), shift: 0, negative: false }
    }

    /// Compose an additional scale on top of this operand:
    /// (±2^s) * (self) — shifts add, negations xor.
    pub fn scaled(self, shift: i32, negative: bool) -> Self {
        Operand {
            src: self.src,
            shift: self.shift + shift,
            negative: self.negative ^ negative,
        }
    }

    pub fn coeff(&self) -> f32 {
        let m = (self.shift as f32).exp2();
        if self.negative { -m } else { m }
    }
}

/// One hardware adder: value = coeff(a) * val(a.src) + coeff(b) * val(b.src).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AddNode {
    pub a: Operand,
    pub b: Operand,
}

/// A graph output: zero (a pruned row) or a scaled reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OutputSpec {
    Zero,
    Ref(Operand),
}

/// DAG of shift-add nodes over `num_inputs` external inputs.
///
/// Nodes are in topological order by construction: a node may only
/// reference inputs or strictly earlier nodes (checked on push).
#[derive(Clone, Debug, Default)]
pub struct AdderGraph {
    num_inputs: usize,
    nodes: Vec<AddNode>,
    outputs: Vec<OutputSpec>,
}

impl AdderGraph {
    pub fn new(num_inputs: usize) -> Self {
        AdderGraph { num_inputs, nodes: Vec::new(), outputs: Vec::new() }
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    pub fn nodes(&self) -> &[AddNode] {
        &self.nodes
    }

    pub fn outputs(&self) -> &[OutputSpec] {
        &self.outputs
    }

    /// The paper's cost metric: one addition per node.
    pub fn additions(&self) -> usize {
        self.nodes.len()
    }

    fn check(&self, op: Operand) {
        match op.src {
            NodeRef::Input(i) => assert!((i as usize) < self.num_inputs, "input oob"),
            NodeRef::Node(i) => assert!((i as usize) < self.nodes.len(), "forward node ref"),
        }
    }

    /// Append an adder; returns a reference to its value.
    pub fn push_add(&mut self, a: Operand, b: Operand) -> Operand {
        self.check(a);
        self.check(b);
        self.nodes.push(AddNode { a, b });
        Operand::node(self.nodes.len() - 1)
    }

    /// Sum a list of operands with a balanced tree (minimal depth),
    /// returning the root operand. Returns `None` for an empty list.
    pub fn push_sum(&mut self, mut ops: Vec<Operand>) -> Option<Operand> {
        if ops.is_empty() {
            return None;
        }
        while ops.len() > 1 {
            let mut next = Vec::with_capacity(ops.len().div_ceil(2));
            let mut it = ops.chunks(2);
            for pair in &mut it {
                if pair.len() == 2 {
                    next.push(self.push_add(pair[0], pair[1]));
                } else {
                    next.push(pair[0]);
                }
            }
            ops = next;
        }
        Some(ops[0])
    }

    pub fn push_output(&mut self, out: OutputSpec) {
        if let OutputSpec::Ref(op) = out {
            self.check(op);
        }
        self.outputs.push(out);
    }

    pub fn set_outputs(&mut self, outs: Vec<OutputSpec>) {
        self.outputs.clear();
        for o in outs {
            self.push_output(o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_add_returns_sequential_refs() {
        let mut g = AdderGraph::new(2);
        let n0 = g.push_add(Operand::input(0), Operand::input(1));
        assert_eq!(n0.src, NodeRef::Node(0));
        let n1 = g.push_add(n0, Operand::input(0));
        assert_eq!(n1.src, NodeRef::Node(1));
        assert_eq!(g.additions(), 2);
    }

    #[test]
    #[should_panic(expected = "forward node ref")]
    fn forward_reference_rejected() {
        let mut g = AdderGraph::new(1);
        g.push_add(Operand::node(0), Operand::input(0));
    }

    #[test]
    #[should_panic(expected = "input oob")]
    fn input_oob_rejected() {
        let mut g = AdderGraph::new(1);
        g.push_add(Operand::input(1), Operand::input(0));
    }

    #[test]
    fn scaled_composes_shift_and_sign() {
        let op = Operand::input(0).scaled(2, true).scaled(-1, true);
        assert_eq!(op.shift, 1);
        assert!(!op.negative);
        assert_eq!(op.coeff(), 2.0);
    }

    #[test]
    fn push_sum_balanced() {
        let mut g = AdderGraph::new(4);
        let ops: Vec<Operand> = (0..4).map(Operand::input).collect();
        let root = g.push_sum(ops).unwrap();
        assert_eq!(g.additions(), 3);
        g.set_outputs(vec![OutputSpec::Ref(root)]);
        assert_eq!(g.num_outputs(), 1);
    }

    #[test]
    fn push_sum_empty_is_none() {
        let mut g = AdderGraph::new(1);
        assert!(g.push_sum(vec![]).is_none());
    }
}
