//! Builders: lower LCC decompositions (factor chains, FS subgraphs) into
//! one flat [`AdderGraph`] covering the whole matrix, including the
//! cross-slice output summation of eq. (3).

use super::ir::{AdderGraph, NodeRef, Operand, OutputSpec};
use crate::lcc::decompose::{LccDecomposition, SliceKind};
use crate::lcc::factor::P2Factor;

/// Append a factor chain (F_0 first) whose F_0 consumes `inputs`.
/// Returns one optional operand per final-factor row (None = zero row).
pub fn append_factor_chain(
    g: &mut AdderGraph,
    factors: &[P2Factor],
    inputs: &[Operand],
) -> Vec<Option<Operand>> {
    let mut layer: Vec<Option<Operand>> = inputs.iter().copied().map(Some).collect();
    for f in factors {
        assert_eq!(f.in_dim, layer.len(), "factor chain dim mismatch");
        let mut next = Vec::with_capacity(f.out_dim());
        for row in &f.rows {
            let ops: Vec<Operand> = row
                .iter()
                .filter_map(|t| layer[t.src].map(|op| op.scaled(t.shift, t.negative)))
                .collect();
            next.push(g.push_sum(ops));
        }
        layer = next;
    }
    layer
}

/// Inline `sub` into `g`, wiring `sub`'s inputs to the given operands.
/// Returns `sub`'s outputs as operands of `g` (None for Zero outputs).
pub fn append_subgraph(
    g: &mut AdderGraph,
    sub: &AdderGraph,
    input_map: &[Operand],
) -> Vec<Option<Operand>> {
    assert_eq!(input_map.len(), sub.num_inputs(), "subgraph input mismatch");
    let mut node_map: Vec<Operand> = Vec::with_capacity(sub.nodes().len());
    let remap = |op: Operand, node_map: &[Operand]| -> Operand {
        let base = match op.src {
            NodeRef::Input(i) => input_map[i as usize],
            NodeRef::Node(i) => node_map[i as usize],
        };
        base.scaled(op.shift, op.negative)
    };
    for node in sub.nodes() {
        let a = remap(node.a, &node_map);
        let b = remap(node.b, &node_map);
        node_map.push(g.push_add(a, b));
    }
    sub.outputs()
        .iter()
        .map(|o| match o {
            OutputSpec::Zero => None,
            OutputSpec::Ref(op) => Some(remap(*op, &node_map)),
        })
        .collect()
}

/// Lower a full decomposition to a single graph over all `n_cols` inputs:
/// each slice's program runs on its column range and the per-row slice
/// outputs are summed with balanced trees.
pub fn decomposition_to_graph(d: &LccDecomposition) -> AdderGraph {
    let mut g = AdderGraph::new(d.n_cols);
    // per output row, the operands contributed by each slice
    let mut row_parts: Vec<Vec<Operand>> = vec![Vec::new(); d.n_rows];
    for slice in &d.slices {
        let inputs: Vec<Operand> =
            (slice.col_start..slice.col_start + slice.width).map(Operand::input).collect();
        let outs = match &slice.kind {
            SliceKind::Factors(factors) => append_factor_chain(&mut g, factors, &inputs),
            SliceKind::Graph(sub) => append_subgraph(&mut g, sub, &inputs),
        };
        assert_eq!(outs.len(), d.n_rows, "slice output arity");
        for (r, op) in outs.into_iter().enumerate() {
            if let Some(op) = op {
                row_parts[r].push(op);
            }
        }
    }
    let outputs = row_parts
        .into_iter()
        .map(|parts| match_sum(&mut g, parts))
        .collect();
    g.set_outputs(outputs);
    g
}

fn match_sum(g: &mut AdderGraph, parts: Vec<Operand>) -> OutputSpec {
    match g.push_sum(parts) {
        None => OutputSpec::Zero,
        Some(op) => OutputSpec::Ref(op),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lcc::factor::Term;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn factor_chain_equals_dense_product() {
        // F0: 3x2, F1: 2x3 random po2 factors
        let f0 = P2Factor {
            in_dim: 2,
            rows: vec![
                vec![Term { src: 0, shift: 1, negative: false }],
                vec![
                    Term { src: 0, shift: 0, negative: true },
                    Term { src: 1, shift: -1, negative: false },
                ],
                vec![Term { src: 1, shift: 2, negative: false }],
            ],
        };
        let f1 = P2Factor {
            in_dim: 3,
            rows: vec![
                vec![
                    Term { src: 0, shift: 0, negative: false },
                    Term { src: 2, shift: -2, negative: true },
                ],
                vec![Term { src: 1, shift: 3, negative: false }],
            ],
        };
        let mut g = AdderGraph::new(2);
        let inputs: Vec<Operand> = (0..2).map(Operand::input).collect();
        let outs = append_factor_chain(&mut g, &[f0.clone(), f1.clone()], &inputs);
        g.set_outputs(outs.into_iter().map(|o| match o {
            Some(op) => OutputSpec::Ref(op),
            None => OutputSpec::Zero,
        }).collect());

        let dense = crate::lcc::factor::chain_to_dense(&[f0, f1]);
        let mut rng = Rng::new(0);
        let rep = crate::graph::verify_against(&g, &dense, 8, &mut rng);
        assert!(rep.passes(1e-6), "{rep:?}");
    }

    #[test]
    fn subgraph_inlining_preserves_semantics() {
        // sub computes [x0 + 2 x1]; inline with inputs swapped and scaled
        let mut sub = AdderGraph::new(2);
        let n = sub.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        sub.set_outputs(vec![OutputSpec::Ref(n)]);

        let mut g = AdderGraph::new(2);
        let outs = append_subgraph(
            &mut g,
            &sub,
            &[Operand::input(1), Operand::input(0).scaled(0, true)],
        );
        g.set_outputs(vec![OutputSpec::Ref(outs[0].unwrap())]);
        // expected: x1 + 2*(-x0)
        let y = g.execute(&[3.0, 5.0]);
        assert_eq!(y, vec![5.0 - 6.0]);
    }

    #[test]
    fn zero_rows_propagate_through_chain() {
        let f0 = P2Factor {
            in_dim: 1,
            rows: vec![vec![], vec![Term { src: 0, shift: 0, negative: false }]],
        };
        let f1 = P2Factor {
            in_dim: 2,
            rows: vec![vec![
                Term { src: 0, shift: 0, negative: false }, // hits zero row -> dropped
                Term { src: 1, shift: 1, negative: false },
            ]],
        };
        let mut g = AdderGraph::new(1);
        let outs = append_factor_chain(&mut g, &[f0, f1], &[Operand::input(0)]);
        // single term survives: no adder needed
        assert_eq!(g.additions(), 0);
        let op = outs[0].unwrap();
        g.set_outputs(vec![OutputSpec::Ref(op)]);
        assert_eq!(g.execute(&[3.0]), vec![6.0]);
    }

    #[test]
    fn decomposition_graph_cross_slice_sum() {
        // two 1-col slices, each identity-ish: y = x0 + x1 per row
        use crate::lcc::decompose::{LccDecomposition, SliceDecomposition};
        let mk = || {
            P2Factor { in_dim: 1, rows: vec![vec![Term { src: 0, shift: 0, negative: false }]] }
        };
        let d = LccDecomposition::from_parts(
            1,
            2,
            vec![
                SliceDecomposition { col_start: 0, width: 1, kind: SliceKind::Factors(vec![mk()]) },
                SliceDecomposition { col_start: 1, width: 1, kind: SliceKind::Factors(vec![mk()]) },
            ],
        );
        let g = decomposition_to_graph(&d);
        assert_eq!(g.additions(), 1); // one cross-slice add
        assert_eq!(g.execute(&[2.0, 3.0]), vec![5.0]);
        let w = Matrix::from_rows(&[&[1.0, 1.0]]);
        let mut rng = Rng::new(1);
        assert!(crate::graph::verify_against(&g, &w, 4, &mut rng).passes(1e-6));
    }
}
