//! Numeric verification: an adder graph claiming to implement `W x` is
//! executed on random inputs and compared against the dense product.
//! Every decomposition the pipeline emits passes through here before its
//! adder count is reported (DESIGN.md: counts must be execution-backed).

use super::ir::AdderGraph;
use crate::tensor::Matrix;
use crate::util::{stats, Rng};

#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub trials: usize,
    pub max_abs_err: f64,
    /// max |err| / ||y_ref||_inf per trial, worst case
    pub max_rel_err: f64,
    /// SQNR (dB) pooled over all trials
    pub sqnr_db: f64,
}

impl VerifyReport {
    /// The graph reproduces the matrix within `tol` relative error.
    pub fn passes(&self, tol: f64) -> bool {
        self.max_rel_err <= tol
    }
}

/// Execute `g` on `trials` random vectors and compare with `w.matvec`.
pub fn verify_against(g: &AdderGraph, w: &Matrix, trials: usize, rng: &mut Rng) -> VerifyReport {
    assert_eq!(g.num_inputs(), w.cols(), "graph/matrix input mismatch");
    assert_eq!(g.num_outputs(), w.rows(), "graph/matrix output mismatch");
    let mut max_abs: f64 = 0.0;
    let mut max_rel: f64 = 0.0;
    let mut all_ref = Vec::new();
    let mut all_got = Vec::new();
    for _ in 0..trials {
        let x: Vec<f32> = rng.normal_vec(w.cols(), 1.0);
        let want = w.matvec(&x);
        let got = g.execute(&x);
        let scale = want.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64)).max(1e-12);
        for (a, b) in want.iter().zip(&got) {
            let err = (*a as f64 - *b as f64).abs();
            max_abs = max_abs.max(err);
            max_rel = max_rel.max(err / scale);
        }
        all_ref.extend_from_slice(&want);
        all_got.extend_from_slice(&got);
    }
    VerifyReport {
        trials,
        max_abs_err: max_abs,
        max_rel_err: max_rel,
        sqnr_db: stats::sqnr_db(&all_ref, &all_got),
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{AdderGraph, Operand, OutputSpec};
    use super::*;

    #[test]
    fn exact_graph_verifies() {
        // W = [[1, 2], [4, -0.5]] built by hand
        let w = Matrix::from_rows(&[&[1.0, 2.0], &[4.0, -0.5]]);
        let mut g = AdderGraph::new(2);
        let n0 = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        let n1 = g.push_add(Operand::input(0).scaled(2, false),
                            Operand::input(1).scaled(-1, true));
        g.set_outputs(vec![OutputSpec::Ref(n0), OutputSpec::Ref(n1)]);
        let mut rng = Rng::new(0);
        let rep = verify_against(&g, &w, 16, &mut rng);
        assert!(rep.passes(1e-6), "{rep:?}");
        assert!(rep.sqnr_db > 100.0);
    }

    #[test]
    fn wrong_graph_fails() {
        let w = Matrix::from_rows(&[&[1.0, 1.0]]);
        let mut g = AdderGraph::new(2);
        let n0 = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false)); // 1,2 not 1,1
        g.set_outputs(vec![OutputSpec::Ref(n0)]);
        let mut rng = Rng::new(1);
        let rep = verify_against(&g, &w, 8, &mut rng);
        assert!(!rep.passes(1e-3));
    }
}
