//! Compiled form of an adder graph for fast VM execution.
//!
//! `AdderGraph::execute` resolves every operand through a `NodeRef` match
//! and recomputes `exp2(shift)` per visit. For serving and accuracy
//! evaluation the graph is executed millions of times, so this module
//! flattens it once: one contiguous value array (inputs followed by node
//! values), direct indices, and precomputed f32 coefficients.
//! §Perf (EXPERIMENTS.md) records the measured speedup.

use super::ir::{AdderGraph, NodeRef, OutputSpec};

#[derive(Clone, Copy, Debug)]
struct Op {
    ia: u32,
    ca: f32,
    ib: u32,
    cb: f32,
}

#[derive(Clone, Copy, Debug)]
enum OutOp {
    Zero,
    Scaled { idx: u32, c: f32 },
}

/// Flattened executable graph.
#[derive(Clone, Debug)]
pub struct CompiledGraph {
    num_inputs: usize,
    ops: Vec<Op>,
    outs: Vec<OutOp>,
}

impl CompiledGraph {
    pub fn new(g: &AdderGraph) -> Self {
        let base = g.num_inputs() as u32;
        let idx = |r: NodeRef| match r {
            NodeRef::Input(i) => i,
            NodeRef::Node(i) => base + i,
        };
        let ops = g
            .nodes()
            .iter()
            .map(|n| Op {
                ia: idx(n.a.src),
                ca: n.a.coeff(),
                ib: idx(n.b.src),
                cb: n.b.coeff(),
            })
            .collect();
        let outs = g
            .outputs()
            .iter()
            .map(|o| match o {
                OutputSpec::Zero => OutOp::Zero,
                OutputSpec::Ref(op) => OutOp::Scaled { idx: idx(op.src), c: op.coeff() },
            })
            .collect();
        CompiledGraph { num_inputs: g.num_inputs(), ops, outs }
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.outs.len()
    }

    pub fn additions(&self) -> usize {
        self.ops.len()
    }

    /// Execute with a caller-provided scratch buffer (len >= num_inputs +
    /// ops). Returns the outputs in `out`.
    pub fn execute_into(&self, x: &[f32], scratch: &mut Vec<f32>, out: &mut Vec<f32>) {
        assert_eq!(x.len(), self.num_inputs, "input length mismatch");
        scratch.clear();
        scratch.extend_from_slice(x);
        for op in &self.ops {
            let v = op.ca * scratch[op.ia as usize] + op.cb * scratch[op.ib as usize];
            scratch.push(v);
        }
        out.clear();
        out.extend(self.outs.iter().map(|o| match o {
            OutOp::Zero => 0.0,
            OutOp::Scaled { idx, c } => c * scratch[*idx as usize],
        }));
    }

    /// Convenience allocating execute.
    pub fn execute(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Vec::with_capacity(self.num_inputs + self.ops.len());
        let mut out = Vec::with_capacity(self.outs.len());
        self.execute_into(x, &mut scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AdderGraph, Operand, OutputSpec};
    use crate::util::Rng;

    fn random_graph(seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let inputs = 4 + rng.below(8);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..30 {
            let a = refs[rng.below(refs.len())]
                .scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())]
                .scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..6)
            .map(|_| {
                if rng.f32() < 0.1 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn compiled_matches_interpreter() {
        let mut rng = Rng::new(1);
        for seed in 0..10 {
            let g = random_graph(seed);
            let c = CompiledGraph::new(&g);
            assert_eq!(c.additions(), g.additions());
            let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
            let want = g.execute(&x);
            let got = c.execute(&x);
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn execute_into_reuses_buffers() {
        let g = random_graph(42);
        let c = CompiledGraph::new(&g);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        let x = vec![1.0; g.num_inputs()];
        c.execute_into(&x, &mut scratch, &mut out);
        let first = out.clone();
        c.execute_into(&x, &mut scratch, &mut out);
        assert_eq!(first, out);
    }
}
