//! Compatibility wrapper around the unified execution engine.
//!
//! `CompiledGraph` used to own its own flattening of the adder graph
//! (direct indices + precomputed coefficients). That lowering now lives
//! in [`crate::exec::ExecPlan`] — level-sorted, batch-capable, shared by
//! every runtime path — and this type is a thin deprecated shim kept so
//! old call sites and benches keep working. §Perf (EXPERIMENTS.md)
//! records the measured speedups of the engine family.

use super::ir::AdderGraph;
use crate::exec::ExecPlan;

/// Flattened executable graph (deprecated shim over [`ExecPlan`]).
#[deprecated(
    note = "superseded by crate::exec::{ExecPlan, BatchEngine}; this wrapper only \
            forwards to ExecPlan's scalar path"
)]
#[derive(Clone, Debug)]
pub struct CompiledGraph {
    plan: ExecPlan,
}

#[allow(deprecated)]
impl CompiledGraph {
    pub fn new(g: &AdderGraph) -> Self {
        CompiledGraph { plan: ExecPlan::new(g) }
    }

    pub fn num_inputs(&self) -> usize {
        self.plan.num_inputs()
    }

    pub fn num_outputs(&self) -> usize {
        self.plan.num_outputs()
    }

    pub fn additions(&self) -> usize {
        self.plan.additions()
    }

    /// Execute with a caller-provided scratch buffer (len >= num_inputs +
    /// ops). Returns the outputs in `out`.
    pub fn execute_into(&self, x: &[f32], scratch: &mut Vec<f32>, out: &mut Vec<f32>) {
        self.plan.execute_one_into(x, scratch, out);
    }

    /// Convenience allocating execute.
    pub fn execute(&self, x: &[f32]) -> Vec<f32> {
        self.plan.execute_one(x)
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::{AdderGraph, Operand, OutputSpec};
    use crate::util::Rng;

    fn random_graph(seed: u64) -> AdderGraph {
        let mut rng = Rng::new(seed);
        let inputs = 4 + rng.below(8);
        let mut g = AdderGraph::new(inputs);
        let mut refs: Vec<Operand> = (0..inputs).map(Operand::input).collect();
        for _ in 0..30 {
            let a = refs[rng.below(refs.len())]
                .scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            let b = refs[rng.below(refs.len())]
                .scaled(rng.below(7) as i32 - 3, rng.f32() < 0.5);
            refs.push(g.push_add(a, b));
        }
        let outs = (0..6)
            .map(|_| {
                if rng.f32() < 0.1 {
                    OutputSpec::Zero
                } else {
                    OutputSpec::Ref(refs[rng.below(refs.len())].scaled(1, false))
                }
            })
            .collect();
        g.set_outputs(outs);
        g
    }

    #[test]
    fn compiled_matches_interpreter() {
        let mut rng = Rng::new(1);
        for seed in 0..10 {
            let g = random_graph(seed);
            let c = CompiledGraph::new(&g);
            assert_eq!(c.additions(), g.additions());
            let x: Vec<f32> = rng.normal_vec(g.num_inputs(), 1.0);
            let want = g.execute(&x);
            let got = c.execute(&x);
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn execute_into_reuses_buffers() {
        let g = random_graph(42);
        let c = CompiledGraph::new(&g);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        let x = vec![1.0; g.num_inputs()];
        c.execute_into(&x, &mut scratch, &mut out);
        let first = out.clone();
        c.execute_into(&x, &mut scratch, &mut out);
        assert_eq!(first, out);
    }
}
