//! ASAP scheduling of an adder graph: pipeline depth and per-level width.
//!
//! On an FPGA every adder at the same ASAP level can evaluate in the same
//! cycle, so `depth` is the latency (critical path in adder stages) and
//! `max_width` is the peak number of simultaneously busy adders — the
//! resource/parallelism proxy used in the benches. The FP algorithm's
//! selling point (paper Sec. III-A) shows up here: its graphs are shallow
//! and wide, while FS graphs are deeper chains.

use super::ir::{AdderGraph, NodeRef, OutputSpec};

/// ASAP schedule summary.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// ASAP level of each node (inputs are level 0; a node is
    /// 1 + max(level of operands)).
    pub levels: Vec<usize>,
    /// critical path over the outputs, in adder stages
    pub depth: usize,
    /// number of adders at each level (level 1..=depth)
    pub width_histogram: Vec<usize>,
    /// peak simultaneous adders
    pub max_width: usize,
}

fn ref_level(levels: &[usize], src: NodeRef) -> usize {
    match src {
        NodeRef::Input(_) => 0,
        NodeRef::Node(i) => levels[i as usize],
    }
}

/// Compute the ASAP schedule.
pub fn schedule(g: &AdderGraph) -> Schedule {
    let mut levels = Vec::with_capacity(g.nodes().len());
    for node in g.nodes() {
        let l = 1 + ref_level(&levels, node.a.src).max(ref_level(&levels, node.b.src));
        levels.push(l);
    }
    let depth = g
        .outputs()
        .iter()
        .map(|o| match o {
            OutputSpec::Zero => 0,
            OutputSpec::Ref(op) => ref_level(&levels, op.src),
        })
        .max()
        .unwrap_or_else(|| levels.iter().copied().max().unwrap_or(0));
    let max_level = levels.iter().copied().max().unwrap_or(0);
    let mut width_histogram = vec![0usize; max_level];
    for &l in &levels {
        width_histogram[l - 1] += 1;
    }
    let max_width = width_histogram.iter().copied().max().unwrap_or(0);
    Schedule { levels, depth, width_histogram, max_width }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{AdderGraph, Operand, OutputSpec};
    use super::*;

    #[test]
    fn chain_has_linear_depth() {
        let mut g = AdderGraph::new(2);
        let mut acc = g.push_add(Operand::input(0), Operand::input(1));
        for _ in 0..5 {
            acc = g.push_add(acc, Operand::input(0));
        }
        g.set_outputs(vec![OutputSpec::Ref(acc)]);
        let s = schedule(&g);
        assert_eq!(s.depth, 6);
        assert_eq!(s.max_width, 1);
    }

    #[test]
    fn balanced_tree_has_log_depth() {
        let mut g = AdderGraph::new(8);
        let ops: Vec<Operand> = (0..8).map(Operand::input).collect();
        let root = g.push_sum(ops).unwrap();
        g.set_outputs(vec![OutputSpec::Ref(root)]);
        let s = schedule(&g);
        assert_eq!(s.depth, 3);
        assert_eq!(s.width_histogram, vec![4, 2, 1]);
        assert_eq!(s.max_width, 4);
    }

    #[test]
    fn empty_graph_zero_depth() {
        let mut g = AdderGraph::new(3);
        g.set_outputs(vec![OutputSpec::Ref(Operand::input(2))]);
        let s = schedule(&g);
        assert_eq!(s.depth, 0);
        assert!(s.width_histogram.is_empty());
    }
}
