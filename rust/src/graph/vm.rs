//! Shift-add virtual machine: executes an [`AdderGraph`] on concrete
//! inputs. This simulates the FPGA datapath; numerics are f32 with exact
//! power-of-two scaling, so results are bit-comparable with the dense
//! product up to float addition order.
//!
//! This interpreter is the *numeric oracle*: every faster path
//! ([`crate::exec::ExecPlan`], [`crate::exec::BatchEngine`]) is tested
//! for bit-identical outputs against it. Hot paths should not call it —
//! use the `exec` engine.

use super::ir::{AdderGraph, NodeRef, OutputSpec};

impl AdderGraph {
    /// Execute the graph on one input vector.
    pub fn execute(&self, x: &[f32]) -> Vec<f32> {
        let mut vals = Vec::with_capacity(self.nodes().len());
        self.execute_reusing(x, &mut vals)
    }

    /// Execute with a caller-owned node-value buffer (reused across calls).
    fn execute_reusing(&self, x: &[f32], vals: &mut Vec<f32>) -> Vec<f32> {
        assert_eq!(x.len(), self.num_inputs(), "input length mismatch");
        vals.clear();
        for node in self.nodes() {
            let a = operand_value(x, vals.as_slice(), node.a.src) * node.a.coeff();
            let b = operand_value(x, vals.as_slice(), node.b.src) * node.b.coeff();
            vals.push(a + b);
        }
        let vals: &[f32] = vals;
        self.outputs()
            .iter()
            .map(|o| match o {
                OutputSpec::Zero => 0.0,
                OutputSpec::Ref(op) => operand_value(x, vals, op.src) * op.coeff(),
            })
            .collect()
    }

    /// Execute on a batch of input vectors, reusing one node buffer
    /// across samples.
    #[deprecated(
        note = "use crate::exec::BatchEngine: batch-major lanes, buffer pooling and \
                parallel chunks instead of a per-sample interpreter loop"
    )]
    pub fn execute_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut vals = Vec::with_capacity(self.nodes().len());
        xs.iter().map(|x| self.execute_reusing(x, &mut vals)).collect()
    }
}

#[inline]
fn operand_value(x: &[f32], vals: &[f32], src: NodeRef) -> f32 {
    match src {
        NodeRef::Input(i) => x[i as usize],
        NodeRef::Node(i) => vals[i as usize],
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{AdderGraph, Operand, OutputSpec};

    #[test]
    fn executes_paper_eq2_by_hand() {
        // eq. (2): y0 = 2 x0 + (2^-1 - 2^-3) x1 ; y1 = -2^-2 x0 + x1
        // with the shared subexpression m = 2 x0 + 2^-1 x1 ... here the
        // straightforward 3-adder program:
        let mut g = AdderGraph::new(2);
        // n0 = 2^1 x0 + 2^-1 x1
        let n0 = g.push_add(Operand::input(0).scaled(1, false),
                            Operand::input(1).scaled(-1, false));
        // n1 = n0 - 2^-3 x1     (y0)
        let n1 = g.push_add(n0, Operand::input(1).scaled(-3, true));
        // n2 = -2^-2 x0 + x1    (y1)
        let n2 = g.push_add(Operand::input(0).scaled(-2, true),
                            Operand::input(1));
        g.set_outputs(vec![OutputSpec::Ref(n1), OutputSpec::Ref(n2)]);

        let y = g.execute(&[1.0, 2.0]);
        assert_eq!(y[0], 2.0 * 1.0 + 0.375 * 2.0);
        assert_eq!(y[1], -0.25 * 1.0 + 1.0 * 2.0);
        assert_eq!(g.additions(), 3);
    }

    #[test]
    fn zero_output_is_zero() {
        let mut g = AdderGraph::new(1);
        g.set_outputs(vec![OutputSpec::Zero, OutputSpec::Ref(Operand::input(0))]);
        assert_eq!(g.execute(&[5.0]), vec![0.0, 5.0]);
        assert_eq!(g.additions(), 0);
    }

    #[test]
    #[allow(deprecated)]
    fn batch_matches_single() {
        let mut g = AdderGraph::new(2);
        let n = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        g.set_outputs(vec![OutputSpec::Ref(n)]);
        let xs = vec![vec![1.0, 2.0], vec![-3.0, 0.5]];
        let batch = g.execute_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(*y, g.execute(x));
        }
    }
}
