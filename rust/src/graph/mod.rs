//! Adder-graph IR + shift-add virtual machine — the "reconfigurable
//! hardware" substrate.
//!
//! Everything the compressed network ultimately executes is a DAG of
//! two-operand additions whose operands are bit-shifted (and possibly
//! negated) earlier values. The number of nodes in the graph **is** the
//! paper's cost metric (additions); bitshifts are free. The VM executes
//! the graph so every claimed adder count is backed by a runnable,
//! numerically-verified program, and the scheduler reports pipeline
//! depth/width — the FPGA parallelism proxy (see DESIGN.md
//! §Hardware-Adaptation).
//!
//! Execution hot paths live in [`crate::exec`]: this module keeps the IR,
//! the scheduler, the verifier and the scalar interpreter (the numeric
//! oracle the engine is tested against).

mod build;
mod compiled;
mod ir;
mod schedule;
mod verify;
mod vm;

pub use build::{append_factor_chain, append_subgraph, decomposition_to_graph};
#[allow(deprecated)]
pub use compiled::CompiledGraph;
pub use ir::{AddNode, AdderGraph, NodeRef, Operand, OutputSpec};
pub use schedule::{schedule, Schedule};
pub use verify::{verify_against, VerifyReport};
