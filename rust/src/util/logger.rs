//! Tiny `log`-facade backend: level from `LCCNN_LOG` (error..trace),
//! timestamps relative to process start.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

struct Logger;

impl Log for Logger {
    fn enabled(&self, _meta: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level from `LCCNN_LOG`, default info.
pub fn init() {
    let level = match std::env::var("LCCNN_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
