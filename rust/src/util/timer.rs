//! Wall-clock timing helpers for the first-party bench harness.

use std::time::Instant;

/// Measure `f`, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Run `f` `iters` times after `warmup` runs; returns per-iteration seconds.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let start = Instant::now();
            f();
            start.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn time_returns_result() {
        let (v, secs) = super::time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_counts_iters() {
        let samples = super::bench(1, 5, || {
            std::hint::black_box(0u64);
        });
        assert_eq!(samples.len(), 5);
    }
}
