//! Bench-harness plumbing shared by the `harness = false` benches:
//! CI quick mode and machine-readable result emission.
//!
//! * `LCCNN_BENCH_QUICK=1` shrinks iteration counts so the CI
//!   `bench-smoke` job finishes in seconds while still producing real
//!   numbers for every row.
//! * `LCCNN_BENCH_JSON=path` appends one JSON object per recorded row
//!   (JSON Lines) — the `BENCH_exec.json` workflow artifact the
//!   EXPERIMENTS.md §Perf tables are filled from.

use std::fmt::Write as _;
use std::fs::OpenOptions;
use std::io::Write as _;

/// True when `LCCNN_BENCH_QUICK` is set to anything but `0`/empty:
/// benches should cut warmups/iterations to smoke-test scale.
pub fn quick() -> bool {
    std::env::var("LCCNN_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// `a` in quick mode, `b` otherwise — `bench::pick(3, 30)` reads as
/// "3 iters on CI, 30 for real measurements".
pub fn pick<T>(a: T, b: T) -> T {
    if quick() { a } else { b }
}

/// One JSON-lines result row (newline-terminated): `fields` values that
/// parse as finite JSON numbers are emitted bare, everything else as a
/// JSON string. The format shared by [`emit`]'s `BENCH_exec.json` rows
/// and `tune`'s `sweep.json`.
pub fn json_line(bench: &str, fields: &[(&str, String)]) -> String {
    let mut line = String::new();
    let _ = write!(line, "{{\"bench\":\"{}\"", escape(bench));
    for (k, v) in fields {
        let is_number = v.parse::<f64>().map(|f| f.is_finite()).unwrap_or(false);
        if is_number {
            let _ = write!(line, ",\"{}\":{v}", escape(k));
        } else {
            let _ = write!(line, ",\"{}\":\"{}\"", escape(k), escape(v));
        }
    }
    line.push_str("}\n");
    line
}

/// Append one result row to the `LCCNN_BENCH_JSON` file (no-op when the
/// variable is unset). Row format per [`json_line`].
pub fn emit(bench: &str, fields: &[(&str, String)]) {
    let Ok(path) = std::env::var("LCCNN_BENCH_JSON") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    let line = json_line(bench, fields);
    let opened = OpenOptions::new().create(true).append(true).open(&path);
    match opened {
        Ok(mut f) => {
            if let Err(e) = f.write_all(line.as_bytes()) {
                log::warn!("bench json append to {path:?} failed: {e}");
            }
        }
        Err(e) => log::warn!("bench json open {path:?} failed: {e}"),
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_follows_quick_flag() {
        // the env flag is process-global; only assert the unset default
        if std::env::var("LCCNN_BENCH_QUICK").is_err() {
            assert!(!quick());
            assert_eq!(pick(3, 30), 30);
        }
    }

    #[test]
    fn emit_appends_json_lines() {
        let path = std::env::temp_dir()
            .join(format!("lccnn-bench-json-{}.jsonl", std::process::id()));
        std::fs::remove_file(&path).ok();
        // emit() reads the env var itself; point it at the temp file
        std::env::set_var("LCCNN_BENCH_JSON", &path);
        emit("t", &[("us", "1.25".to_string()), ("name", "x\"y".to_string())]);
        emit("t", &[("n", "7".to_string())]);
        std::env::remove_var("LCCNN_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "{\"bench\":\"t\",\"us\":1.25,\"name\":\"x\\\"y\"}");
        assert_eq!(lines[1], "{\"bench\":\"t\",\"n\":7}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_line_matches_emit_format() {
        let line = json_line("sweep", &[("id", "3".into()), ("algo", "fs".into())]);
        assert_eq!(line, "{\"bench\":\"sweep\",\"id\":3,\"algo\":\"fs\"}\n");
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }
}
