//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! Every stochastic component in the library (dataset synthesis, weight
//! init, LCC test matrices, clustering restarts) takes an explicit seed so
//! experiments are bit-reproducible across runs.

/// xoshiro256** with SplitMix64 seed expansion.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal sample from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (for per-worker seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(11);
        let mut c1 = base.fork(1);
        let mut c2 = base.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
