//! First-party utilities: PRNG, logger, statistics, timers, bench
//! harness plumbing (quick mode + JSON result rows).
//!
//! The offline vendor tree only carries the `xla` crate's dependency
//! closure, so randomness, logging and stats are implemented here
//! instead of pulling `rand`/`env_logger`. (Parallelism lives in
//! [`crate::exec::WorkerPool`] — the one pool implementation in the
//! tree; the legacy `util::ThreadPool` was retired in its favor.)

pub mod bench;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
