//! First-party utilities: PRNG, thread pool, logger, statistics, timers.
//!
//! The offline vendor tree only carries the `xla` crate's dependency
//! closure, so randomness, parallelism, logging and stats are implemented
//! here instead of pulling `rand`/`rayon`/`env_logger`.

pub mod logger;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use threadpool::ThreadPool;
