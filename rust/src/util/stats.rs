//! Small statistics helpers used by benches and the serving metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
///
/// NaN policy: NaN samples are dropped before ranking — one poisoned
/// latency sample (e.g. a zero-duration division upstream) must skew a
/// metrics render at worst, never panic it. An empty or all-NaN slice
/// yields 0, matching the empty-input convention of [`mean`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Signal-to-quantization-noise ratio in dB between a reference signal and
/// its approximation: 10 log10(||ref||^2 / ||ref - approx||^2).
pub fn sqnr_db(reference: &[f32], approx: &[f32]) -> f64 {
    assert_eq!(reference.len(), approx.len());
    let sig: f64 = reference.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let err: f64 = reference
        .iter()
        .zip(approx)
        .map(|(&r, &a)| {
            let d = r as f64 - a as f64;
            d * d
        })
        .sum();
    if err == 0.0 {
        return f64::INFINITY;
    }
    if sig == 0.0 {
        return f64::NEG_INFINITY;
    }
    10.0 * (sig / err).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // A NaN in the sample set must neither panic the sort (the old
        // `partial_cmp().unwrap()`) nor perturb the ranked values.
        let clean = [5.0, 1.0, 3.0];
        let dirty = [5.0, f64::NAN, 1.0, 3.0, f64::NAN];
        for p in [0.0, 25.0, 50.0, 75.0, 100.0] {
            assert_eq!(percentile(&dirty, p), percentile(&clean, p), "p{p}");
        }
        // All-NaN behaves like empty input.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // Infinities are legitimate samples and still rank.
        assert_eq!(percentile(&[f64::NEG_INFINITY, 0.0, f64::INFINITY], 0.0), f64::NEG_INFINITY);
        assert_eq!(percentile(&[f64::NEG_INFINITY, 0.0, f64::INFINITY], 100.0), f64::INFINITY);
    }

    #[test]
    fn sqnr_perfect_is_infinite() {
        let a = [1.0f32, 2.0, 3.0];
        assert!(sqnr_db(&a, &a).is_infinite());
    }

    #[test]
    fn sqnr_known_value() {
        let r = [1.0f32, 0.0];
        let a = [0.9f32, 0.0];
        let db = sqnr_db(&r, &a);
        assert!((db - 20.0).abs() < 0.1, "{db}"); // err 0.01, sig 1 -> 20 dB
    }
}
