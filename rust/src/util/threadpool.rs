//! Minimal fixed-size thread pool with a scoped parallel-for.
//!
//! Used by the LCC decomposer (per-slice parallelism), the pipeline
//! coordinator (stage jobs) and the serving worker pool. Plain
//! `std::thread` + channel fan-out; no external crates.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `threads == 0` selects the available parallelism (min 1).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("lccnn-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("workers alive");
    }

    /// Apply `f` to every index in `0..n`, writing results in order.
    /// Blocks until all items are done.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let done_tx = done_tx.clone();
            self.execute(move || {
                let v = f(i);
                let _ = done_tx.send((i, v));
            });
        }
        drop(done_tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in done_rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("all jobs completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn execute_runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn zero_threads_defaults_to_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.threads() >= 1);
    }
}
