//! Row-major dense f32 matrix with the operations the compression
//! pipeline needs: products, slicing, column norms/selection, transposes.

use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a row-of-rows literal (tests, examples).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Gaussian random matrix (for LCC ablations and init).
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// y = self * x  (x.len() == cols).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (w, xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            y[r] = acc;
        }
        y
    }

    /// C = self * other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let crow = out.row_mut(i);
                for (cv, &ov) in crow.iter_mut().zip(orow) {
                    *cv += a * ov;
                }
            }
        }
        out
    }

    /// Vertical slice: columns [start, start+width).
    pub fn slice_cols(&self, start: usize, width: usize) -> Matrix {
        assert!(start + width <= self.cols, "slice out of range");
        let mut out = Matrix::zeros(self.rows, width);
        for r in 0..self.rows {
            out.row_mut(r)
                .copy_from_slice(&self.row(r)[start..start + width]);
        }
        out
    }

    /// New matrix keeping only the given columns (in the given order).
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            for (j, &c) in idx.iter().enumerate() {
                *out.at_mut(r, j) = self.at(r, c);
            }
        }
        out
    }

    /// New matrix keeping only the given rows.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// L2 norm of every column.
    pub fn col_norms(&self) -> Vec<f32> {
        let mut sq = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                sq[c] += v * v;
            }
        }
        sq.into_iter().map(|s| s.sqrt()).collect()
    }

    /// L2 norm of every row.
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|v| v * v).sum::<f32>().sqrt())
            .collect()
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// self -= other
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        let i = Matrix::identity(7);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_matches_matvec() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(4, 6, 1.0, &mut rng);
        let x: Vec<f32> = rng.normal_vec(6, 1.0);
        let xm = Matrix::from_vec(6, 1, x.clone());
        let y1 = a.matvec(&x);
        let y2 = a.matmul(&xm);
        for r in 0..4 {
            assert!((y1[r] - y2.at(r, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(3, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_and_hcat_roundtrip() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(4, 10, 1.0, &mut rng);
        let left = a.slice_cols(0, 4);
        let right = a.slice_cols(4, 6);
        assert_eq!(left.hcat(&right), a);
    }

    #[test]
    fn select_cols_reorders() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let s = a.select_cols(&[2, 0]);
        assert_eq!(s, Matrix::from_rows(&[&[3.0, 1.0], &[6.0, 4.0]]));
    }

    #[test]
    fn col_norms_known() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[4.0, 2.0]]);
        let n = a.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn row_norms_known() {
        let a = Matrix::from_rows(&[&[3.0, 4.0], &[0.0, 0.0]]);
        let n = a.row_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert_eq!(n[1], 0.0);
    }

    #[test]
    #[should_panic(expected = "matvec dim mismatch")]
    fn matvec_checks_dims() {
        Matrix::zeros(2, 3).matvec(&[1.0, 2.0]);
    }
}
