//! NHWC 4-d tensor for images and HWIO conv kernels.

/// Dense f32 tensor with shape (n, h, w, c), row-major in that order.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor4 {
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Self {
        Tensor4 { n, h, w, c, data: vec![0.0; n * h * w * c] }
    }

    pub fn from_vec(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * h * w * c, "shape/data mismatch");
        Tensor4 { n, h, w, c, data }
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.n, self.h, self.w, self.c)
    }

    #[inline]
    fn idx(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert!(n < self.n && h < self.h && w < self.w && c < self.c);
        ((n * self.h + h) * self.w + w) * self.c + c
    }

    #[inline]
    pub fn at(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.idx(n, h, w, c)]
    }

    #[inline]
    pub fn at_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let i = self.idx(n, h, w, c);
        &mut self.data[i]
    }

    /// Value with zero padding outside the spatial extent.
    #[inline]
    pub fn at_padded(&self, n: usize, h: isize, w: isize, c: usize) -> f32 {
        if h < 0 || w < 0 || h as usize >= self.h || w as usize >= self.w {
            0.0
        } else {
            self.at(n, h as usize, w as usize, c)
        }
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut t = Tensor4::zeros(2, 3, 4, 5);
        *t.at_mut(1, 2, 3, 4) = 7.0;
        assert_eq!(t.at(1, 2, 3, 4), 7.0);
        assert_eq!(t.data().iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn padded_reads_zero_outside() {
        let t = Tensor4::from_vec(1, 1, 1, 1, vec![3.0]);
        assert_eq!(t.at_padded(0, -1, 0, 0), 0.0);
        assert_eq!(t.at_padded(0, 0, 1, 0), 0.0);
        assert_eq!(t.at_padded(0, 0, 0, 0), 3.0);
    }
}
