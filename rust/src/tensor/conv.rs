//! Direct 2-d convolution (NHWC activations, HWIO kernels).
//!
//! This is the reference semantics that the FK/PK matrix reformulations in
//! [`crate::convert`] must reproduce exactly, and the fallback used by the
//! compressed-model evaluator for unreformulated layers.

use super::Tensor4;

/// SAME (zero) padding or VALID.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

#[derive(Clone, Copy, Debug)]
pub struct Conv2dParams {
    pub stride: usize,
    pub padding: Padding,
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: 1, padding: Padding::Same }
    }
}

/// out[n, y, x, co] = sum_{ky,kx,ci} in[n, y*s - ph + ky, x*s - pw + kx, ci]
///                    * k[ky, kx, ci, co]
///
/// SAME uses the TF/JAX convention: pad_total = (k - 1) for stride 1,
/// generally `max((out-1)*s + k - in, 0)` split low/high (low = total/2).
pub fn conv2d(input: &Tensor4, kernel: &Tensor4, params: Conv2dParams) -> Tensor4 {
    let (n, h, w, ci) = input.shape();
    let (kh, kw, kci, co) = kernel.shape();
    assert_eq!(ci, kci, "channel mismatch");
    let s = params.stride;
    let (oh, ow, ph, pw) = match params.padding {
        Padding::Same => {
            let oh = h.div_ceil(s);
            let ow = w.div_ceil(s);
            let pad_h = ((oh - 1) * s + kh).saturating_sub(h);
            let pad_w = ((ow - 1) * s + kw).saturating_sub(w);
            (oh, ow, pad_h / 2, pad_w / 2)
        }
        Padding::Valid => ((h - kh) / s + 1, (w - kw) / s + 1, 0, 0),
    };
    let mut out = Tensor4::zeros(n, oh, ow, co);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = (oy * s + ky) as isize - ph as isize;
                    for kx in 0..kw {
                        let ix = (ox * s + kx) as isize - pw as isize;
                        for c_in in 0..ci {
                            let v = input.at_padded(b, iy, ix, c_in);
                            if v == 0.0 {
                                continue;
                            }
                            for c_out in 0..co {
                                *out.at_mut(b, oy, ox, c_out) +=
                                    v * kernel.at(ky, kx, c_in, c_out);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 kernel = identity over channels
        let mut input = Tensor4::zeros(1, 3, 3, 2);
        for i in 0..18 {
            input.data_mut()[i] = i as f32;
        }
        let mut k = Tensor4::zeros(1, 1, 2, 2);
        *k.at_mut(0, 0, 0, 0) = 1.0;
        *k.at_mut(0, 0, 1, 1) = 1.0;
        let out = conv2d(&input, &k, Conv2dParams::default());
        assert_eq!(out, input);
    }

    #[test]
    fn known_3x3_valid() {
        // single channel, all-ones 3x3 kernel over a 3x3 image = sum
        let input = Tensor4::from_vec(1, 3, 3, 1, (1..=9).map(|v| v as f32).collect());
        let k = Tensor4::from_vec(3, 3, 1, 1, vec![1.0; 9]);
        let out = conv2d(&input, &k, Conv2dParams { stride: 1, padding: Padding::Valid });
        assert_eq!(out.shape(), (1, 1, 1, 1));
        assert_eq!(out.at(0, 0, 0, 0), 45.0);
    }

    #[test]
    fn same_padding_shape_stride2() {
        let input = Tensor4::zeros(2, 8, 8, 3);
        let k = Tensor4::zeros(3, 3, 3, 16);
        let out = conv2d(&input, &k, Conv2dParams { stride: 2, padding: Padding::Same });
        assert_eq!(out.shape(), (2, 4, 4, 16));
    }

    #[test]
    fn same_padding_centers_kernel() {
        // delta image, 3x3 averaging kernel: center output sees the delta
        let mut input = Tensor4::zeros(1, 5, 5, 1);
        *input.at_mut(0, 2, 2, 0) = 1.0;
        let k = Tensor4::from_vec(3, 3, 1, 1, vec![1.0; 9]);
        let out = conv2d(&input, &k, Conv2dParams::default());
        assert_eq!(out.shape(), (1, 5, 5, 1));
        assert_eq!(out.at(0, 2, 2, 0), 1.0);
        assert_eq!(out.at(0, 1, 2, 0), 1.0);
        assert_eq!(out.at(0, 0, 2, 0), 0.0);
    }
}
