//! Dense tensor substrate: row-major f32 matrices and NHWC image tensors.
//!
//! This is the numeric foundation every other module builds on: the LCC
//! decomposer consumes [`Matrix`] weights, the adder-graph verifier
//! compares against [`Matrix::matvec`], the conv reformulations
//! ([`crate::convert`]) turn [`Tensor4`] kernels into matrices.

mod conv;
mod matrix;
mod tensor4;

pub use conv::{conv2d, Conv2dParams, Padding};
pub use matrix::Matrix;
pub use tensor4::Tensor4;
