//! k-means (k-means++ init) over matrix columns — baseline against
//! affinity propagation in the weight-sharing ablation. The paper notes
//! AP avoids fixing k a priori; this module quantifies what a fixed-k
//! method does to the sharing gain.

use super::Clustering;
use crate::tensor::Matrix;
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct KMeansParams {
    pub k: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams { k: 8, max_iters: 100, seed: 0 }
    }
}

fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

/// Cluster the columns of `w` into k groups.
pub fn kmeans_columns(w: &Matrix, p: &KMeansParams) -> Clustering {
    let n = w.cols();
    let k = p.k.min(n).max(1);
    let cols: Vec<Vec<f32>> = (0..n).map(|c| w.col(c)).collect();
    let mut rng = Rng::new(p.seed);

    // k-means++ seeding
    let mut centers: Vec<Vec<f32>> = vec![cols[rng.below(n)].clone()];
    while centers.len() < k {
        let d2: Vec<f32> = cols
            .iter()
            .map(|c| centers.iter().map(|ct| dist_sq(c, ct)).fold(f32::INFINITY, f32::min))
            .collect();
        let total: f32 = d2.iter().sum();
        if total <= 0.0 {
            centers.push(cols[rng.below(n)].clone());
            continue;
        }
        let mut target = rng.f32() * total;
        let mut pick = n - 1;
        for (i, &d) in d2.iter().enumerate() {
            if target <= d {
                pick = i;
                break;
            }
            target -= d;
        }
        centers.push(cols[pick].clone());
    }

    let mut labels = vec![0usize; n];
    for _ in 0..p.max_iters {
        let mut changed = false;
        for (i, c) in cols.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist_sq(c, &centers[a]).partial_cmp(&dist_sq(c, &centers[b])).unwrap()
                })
                .unwrap();
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        // recompute centers
        let dim = w.rows();
        let mut sums = vec![vec![0.0f32; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, c) in cols.iter().enumerate() {
            counts[labels[i]] += 1;
            for (s, &v) in sums[labels[i]].iter_mut().zip(c) {
                *s += v;
            }
        }
        for ci in 0..k {
            if counts[ci] > 0 {
                let inv = 1.0 / counts[ci] as f32;
                centers[ci] = sums[ci].iter().map(|&s| s * inv).collect();
            } else {
                centers[ci] = cols[rng.below(n)].clone(); // respawn empty
            }
        }
        if !changed {
            break;
        }
    }

    // drop empty clusters and relabel densely
    let mut used: Vec<usize> = labels.clone();
    used.sort();
    used.dedup();
    let remap: std::collections::HashMap<usize, usize> =
        used.iter().enumerate().map(|(new, &old)| (old, new)).collect();
    let labels: Vec<usize> = labels.iter().map(|l| remap[l]).collect();
    // exemplar = member closest to its center
    let mut exemplars = vec![0usize; used.len()];
    let mut best_d = vec![f32::INFINITY; used.len()];
    for (i, c) in cols.iter().enumerate() {
        let l = labels[i];
        let d = dist_sq(c, &centers[used[l]]);
        if d < best_d[l] {
            best_d[l] = d;
            exemplars[l] = i;
        }
    }
    Clustering { labels, exemplars }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grouped(k: usize, per: usize, dim: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(dim, 5.0)).collect();
        let n = k * per;
        let mut w = Matrix::zeros(dim, n);
        let mut truth = vec![0usize; n];
        for g in 0..k {
            for j in 0..per {
                let col = g * per + j;
                truth[col] = g;
                for r in 0..dim {
                    *w.at_mut(r, col) = centers[g][r] + 0.02 * rng.normal_f32();
                }
            }
        }
        (w, truth)
    }

    #[test]
    fn recovers_separated_groups() {
        let (w, truth) = grouped(3, 10, 6, 0);
        let c = kmeans_columns(&w, &KMeansParams { k: 3, ..Default::default() });
        // perfect partition up to relabeling
        let mut map = std::collections::HashMap::new();
        for (l, t) in c.labels.iter().zip(&truth) {
            assert_eq!(*map.entry(*l).or_insert(*t), *t);
        }
        assert_eq!(c.num_clusters(), 3);
    }

    #[test]
    fn k_clamped_to_columns() {
        let (w, _) = grouped(2, 2, 4, 1);
        let c = kmeans_columns(&w, &KMeansParams { k: 100, ..Default::default() });
        assert!(c.num_clusters() <= 4);
    }

    #[test]
    fn deterministic_for_seed() {
        let (w, _) = grouped(3, 5, 4, 2);
        let a = kmeans_columns(&w, &KMeansParams { k: 3, seed: 7, ..Default::default() });
        let b = kmeans_columns(&w, &KMeansParams { k: 3, seed: 7, ..Default::default() });
        assert_eq!(a.labels, b.labels);
    }
}
