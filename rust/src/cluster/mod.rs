//! Column clustering for weight sharing (paper Sec. III-C).
//!
//! The paper clusters highly correlated weight-matrix columns with
//! affinity propagation [Frey & Dueck 2007] — chosen because it does not
//! need the number of clusters up front. [`affinity`] is a from-scratch
//! implementation (the paper used scikit-learn; see DESIGN.md
//! Substitutions); [`kmeans`] is the comparison baseline used in the
//! ablation bench.

pub mod affinity;
pub mod kmeans;

use crate::tensor::Matrix;

/// A clustering of matrix columns.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// cluster id for every column (0..num_clusters)
    pub labels: Vec<usize>,
    /// column index of each cluster's exemplar/centroid seed
    pub exemplars: Vec<usize>,
}

impl Clustering {
    pub fn num_clusters(&self) -> usize {
        self.exemplars.len()
    }

    /// Column indices belonging to each cluster (the paper's I_i sets).
    pub fn index_sets(&self) -> Vec<Vec<usize>> {
        let mut sets = vec![Vec::new(); self.num_clusters()];
        for (col, &l) in self.labels.iter().enumerate() {
            sets[l].push(col);
        }
        sets
    }

    /// Centroid matrix G: column i = mean of the member columns of
    /// cluster i (paper: centroids replace their cluster's columns).
    pub fn centroids(&self, w: &Matrix) -> Matrix {
        let sets = self.index_sets();
        let mut g = Matrix::zeros(w.rows(), sets.len());
        for (ci, set) in sets.iter().enumerate() {
            assert!(!set.is_empty(), "empty cluster {ci}");
            for &col in set {
                for r in 0..w.rows() {
                    *g.at_mut(r, ci) += w.at(r, col);
                }
            }
            let inv = 1.0 / set.len() as f32;
            for r in 0..w.rows() {
                *g.at_mut(r, ci) *= inv;
            }
        }
        g
    }

    /// Expanded matrix with every column replaced by its centroid.
    pub fn expand(&self, w: &Matrix) -> Matrix {
        let g = self.centroids(w);
        let mut out = Matrix::zeros(w.rows(), w.cols());
        for (col, &l) in self.labels.iter().enumerate() {
            for r in 0..w.rows() {
                *out.at_mut(r, col) = g.at(r, l);
            }
        }
        out
    }
}

/// Negative squared euclidean distance between all column pairs — the
/// similarity both clustering algorithms consume.
pub fn column_similarities(w: &Matrix) -> Matrix {
    let n = w.cols();
    let cols: Vec<Vec<f32>> = (0..n).map(|c| w.col(c)).collect();
    let mut s = Matrix::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let d: f32 = cols[i]
                .iter()
                .zip(&cols[j])
                .map(|(&a, &b)| (a - b) * (a - b))
                .sum();
            *s.at_mut(i, j) = -d;
            *s.at_mut(j, i) = -d;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_clustering() -> (Matrix, Clustering) {
        // 3 columns; columns 0 and 2 identical
        let w = Matrix::from_rows(&[&[1.0, 5.0, 1.0], &[2.0, 6.0, 2.0]]);
        let c = Clustering { labels: vec![0, 1, 0], exemplars: vec![0, 1] };
        (w, c)
    }

    #[test]
    fn centroids_average_members() {
        let (w, c) = toy_clustering();
        let g = c.centroids(&w);
        assert_eq!(g.col(0), vec![1.0, 2.0]);
        assert_eq!(g.col(1), vec![5.0, 6.0]);
    }

    #[test]
    fn expand_replaces_columns() {
        let (w, c) = toy_clustering();
        assert_eq!(c.expand(&w), w); // identical members: expansion exact
    }

    #[test]
    fn index_sets_partition_columns() {
        let (_, c) = toy_clustering();
        let sets = c.index_sets();
        assert_eq!(sets, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn similarities_symmetric_nonpositive() {
        let (w, _) = toy_clustering();
        let s = column_similarities(&w);
        for i in 0..3 {
            assert_eq!(s.at(i, i), 0.0);
            for j in 0..3 {
                assert!(s.at(i, j) <= 0.0);
                assert_eq!(s.at(i, j), s.at(j, i));
            }
        }
        assert_eq!(s.at(0, 2), 0.0); // identical columns
        assert!(s.at(0, 1) < 0.0);
    }
}
