//! Affinity propagation [Frey & Dueck, Science 2007].
//!
//! Message-passing clustering on a similarity matrix: responsibilities
//! `r(i,k)` (how well-suited k is as exemplar for i) and availabilities
//! `a(i,k)` (how appropriate it is for i to choose k) are iterated with
//! damping until the exemplar set is stable. The preference (self
//! similarity) controls cluster granularity; the scikit-learn default —
//! median of the similarities — is the default here too, matching the
//! paper's setup.

use super::{column_similarities, Clustering};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct AffinityParams {
    /// damping factor in [0.5, 1)
    pub damping: f32,
    /// maximum message-passing iterations
    pub max_iters: usize,
    /// stop after the exemplar set is unchanged for this many iterations
    pub convergence_iters: usize,
    /// self-similarity; None = `preference_scale` × median of
    /// off-diagonal similarities
    pub preference: Option<f32>,
    /// scale on the median when `preference` is None. Similarities are
    /// negative distances, so a scale < 1 moves the preference toward 0
    /// and yields *finer* clusterings — merging only genuinely
    /// correlated columns, which is what weight sharing needs when the
    /// matrix is not heavily pruned.
    pub preference_scale: f32,
}

impl Default for AffinityParams {
    fn default() -> Self {
        AffinityParams {
            damping: 0.7,
            max_iters: 300,
            convergence_iters: 20,
            preference: None,
            preference_scale: 0.3,
        }
    }
}

fn median(mut v: Vec<f32>) -> f32 {
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) }
}

/// Run affinity propagation on a (symmetric) similarity matrix.
pub fn affinity_propagation(s_in: &Matrix, p: &AffinityParams) -> Clustering {
    let n = s_in.rows();
    assert_eq!(n, s_in.cols(), "similarity must be square");
    if n == 0 {
        return Clustering { labels: vec![], exemplars: vec![] };
    }
    if n == 1 {
        return Clustering { labels: vec![0], exemplars: vec![0] };
    }

    let mut s = s_in.clone();
    let pref = p.preference.unwrap_or_else(|| {
        let mut off = Vec::with_capacity(n * (n - 1));
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    off.push(s.at(i, j));
                }
            }
        }
        p.preference_scale * median(off)
    });
    for i in 0..n {
        *s.at_mut(i, i) = pref;
    }
    // deterministic asymmetric jitter breaks exemplar ties (sklearn uses
    // random noise; deterministic here for reproducibility). Duplicated
    // columns make the similarity matrix exactly symmetric under swapping
    // them, which famously makes AP oscillate or crown both — the jitter
    // must be relative to the *global* similarity scale to matter.
    let s_scale = (0..n)
        .flat_map(|i| (0..n).map(move |j| (i, j)))
        .map(|(i, j)| s.at(i, j).abs())
        .fold(0.0f32, f32::max)
        .max(1e-6);
    for i in 0..n {
        for j in 0..n {
            let h = ((i.wrapping_mul(2654435761) ^ j.wrapping_mul(40503)) % 1009) as f32
                / 1009.0
                - 0.5;
            *s.at_mut(i, j) += 1e-5 * s_scale * h;
        }
    }

    let mut r = Matrix::zeros(n, n);
    let mut a = Matrix::zeros(n, n);
    let mut stable = 0usize;
    let mut last_exemplars: Vec<usize> = Vec::new();

    for _ in 0..p.max_iters {
        // responsibilities: r(i,k) <- s(i,k) - max_{k' != k} (a(i,k') + s(i,k'))
        for i in 0..n {
            // top-2 of a(i,:) + s(i,:)
            let (mut m1, mut m1_idx, mut m2) = (f32::NEG_INFINITY, 0usize, f32::NEG_INFINITY);
            for k in 0..n {
                let v = a.at(i, k) + s.at(i, k);
                if v > m1 {
                    m2 = m1;
                    m1 = v;
                    m1_idx = k;
                } else if v > m2 {
                    m2 = v;
                }
            }
            for k in 0..n {
                let other = if k == m1_idx { m2 } else { m1 };
                let new = s.at(i, k) - other;
                *r.at_mut(i, k) = p.damping * r.at(i, k) + (1.0 - p.damping) * new;
            }
        }
        // availabilities:
        // a(i,k) <- min(0, r(k,k) + sum_{i' not in {i,k}} max(0, r(i',k)))
        // a(k,k) <- sum_{i' != k} max(0, r(i',k))
        for k in 0..n {
            let mut pos_sum = 0.0f32;
            for i in 0..n {
                if i != k {
                    pos_sum += r.at(i, k).max(0.0);
                }
            }
            for i in 0..n {
                let new = if i == k {
                    pos_sum
                } else {
                    (r.at(k, k) + pos_sum - r.at(i, k).max(0.0)).min(0.0)
                };
                *a.at_mut(i, k) = p.damping * a.at(i, k) + (1.0 - p.damping) * new;
            }
        }
        // exemplars: k with r(k,k) + a(k,k) > 0
        let exemplars: Vec<usize> = (0..n).filter(|&k| r.at(k, k) + a.at(k, k) > 0.0).collect();
        if exemplars == last_exemplars && !exemplars.is_empty() {
            stable += 1;
            if stable >= p.convergence_iters {
                break;
            }
        } else {
            stable = 0;
            last_exemplars = exemplars;
        }
    }

    let mut exemplars = last_exemplars;
    if exemplars.is_empty() {
        // degenerate fallback: every point its own exemplar is useless;
        // pick the point with max aggregate similarity as one cluster
        let best = (0..n)
            .max_by(|&i, &j| {
                let si: f32 = (0..n).map(|k| s.at(k, i)).sum();
                let sj: f32 = (0..n).map(|k| s.at(k, j)).sum();
                si.partial_cmp(&sj).unwrap()
            })
            .unwrap();
        exemplars = vec![best];
    }
    // merge exemplars that are (near-)duplicates of each other — exact
    // column duplicates can crown several identical exemplars, which
    // costs sharing gain without any fidelity benefit. Two exemplars are
    // merged when their similarity is within jitter of the maximum (0).
    let merge_tol = -1e-4 * {
        let mut m = 0.0f32;
        for i in 0..n {
            for j in 0..n {
                m = m.max(s_in.at(i, j).abs());
            }
        }
        m.max(1e-6)
    };
    let mut kept: Vec<usize> = Vec::new();
    for &e in &exemplars {
        if !kept.iter().any(|&k| s_in.at(e, k) >= merge_tol) {
            kept.push(e);
        }
    }
    let exemplars = kept;
    // assign every point to the most similar exemplar (exemplars to
    // themselves)
    let mut labels = vec![0usize; n];
    for i in 0..n {
        if let Some(pos) = exemplars.iter().position(|&e| e == i) {
            labels[i] = pos;
            continue;
        }
        let mut best = 0usize;
        let mut best_s = f32::NEG_INFINITY;
        for (ci, &e) in exemplars.iter().enumerate() {
            if s.at(i, e) > best_s {
                best_s = s.at(i, e);
                best = ci;
            }
        }
        labels[i] = best;
    }
    Clustering { labels, exemplars }
}

/// Cluster the columns of a weight matrix (the paper's usage).
pub fn cluster_columns(w: &Matrix, p: &AffinityParams) -> Clustering {
    affinity_propagation(&column_similarities(w), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Build a matrix whose columns form `k` well-separated groups.
    fn grouped_columns(k: usize, per_group: usize, dim: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(dim, 4.0)).collect();
        let n = k * per_group;
        let mut w = Matrix::zeros(dim, n);
        let mut truth = vec![0usize; n];
        for g in 0..k {
            for j in 0..per_group {
                let col = g * per_group + j;
                truth[col] = g;
                for r in 0..dim {
                    *w.at_mut(r, col) = centers[g][r] + 0.05 * rng.normal_f32();
                }
            }
        }
        (w, truth)
    }

    fn clusters_match_truth(c: &Clustering, truth: &[usize]) -> bool {
        // same partition: labels must be a bijective relabeling of truth
        let mut map = std::collections::HashMap::new();
        for (l, t) in c.labels.iter().zip(truth) {
            let e = map.entry(*l).or_insert(*t);
            if e != t {
                return false;
            }
        }
        let distinct: std::collections::HashSet<_> = truth.iter().collect();
        c.num_clusters() == distinct.len()
    }

    #[test]
    fn recovers_separated_groups() {
        let (w, truth) = grouped_columns(4, 8, 10, 0);
        let c = cluster_columns(&w, &AffinityParams::default());
        assert!(clusters_match_truth(&c, &truth),
                "got {} clusters, labels {:?}", c.num_clusters(), c.labels);
    }

    #[test]
    fn exemplars_label_themselves() {
        let (w, _) = grouped_columns(3, 5, 8, 1);
        let c = cluster_columns(&w, &AffinityParams::default());
        for (ci, &e) in c.exemplars.iter().enumerate() {
            assert_eq!(c.labels[e], ci);
        }
    }

    #[test]
    fn single_point() {
        let s = Matrix::zeros(1, 1);
        let c = affinity_propagation(&s, &AffinityParams::default());
        assert_eq!(c.labels, vec![0]);
        assert_eq!(c.exemplars, vec![0]);
    }

    #[test]
    fn low_preference_fewer_clusters() {
        let (w, _) = grouped_columns(4, 6, 8, 2);
        let s = column_similarities(&w);
        let many = affinity_propagation(
            &s,
            &AffinityParams { preference: Some(-0.01), ..Default::default() },
        );
        let few = affinity_propagation(
            &s,
            &AffinityParams { preference: Some(-1000.0), ..Default::default() },
        );
        assert!(few.num_clusters() <= many.num_clusters(),
                "few {} many {}", few.num_clusters(), many.num_clusters());
    }

    #[test]
    fn all_labels_valid() {
        let (w, _) = grouped_columns(2, 10, 6, 3);
        let c = cluster_columns(&w, &AffinityParams::default());
        assert!(c.labels.iter().all(|&l| l < c.num_clusters()));
        assert_eq!(c.labels.len(), w.cols());
    }
}
