//! Mini-TOML: `[section]` headers, `key = value` with string / integer /
//! float / bool / homogeneous scalar arrays, `#` comments. Enough for the
//! experiment configs; not a general TOML implementation.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        if let TomlValue::Str(s) = self {
            Some(s)
        } else {
            None
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        if let TomlValue::Bool(b) = self {
            Some(*b)
        } else {
            None
        }
    }

    pub fn as_float_array(&self) -> Option<Vec<f64>> {
        if let TomlValue::Array(items) = self {
            items.iter().map(|v| v.as_float()).collect()
        } else {
            None
        }
    }

    /// The array's items as non-negative integers (`None` if this is not
    /// an array or any item is not an `Int >= 0`).
    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        if let TomlValue::Array(items) = self {
            items.iter().map(|v| v.as_int().and_then(|i| usize::try_from(i).ok())).collect()
        } else {
            None
        }
    }

    /// The array's items as strings (`None` if this is not an array or
    /// any item is not a `Str`).
    pub fn as_str_array(&self) -> Option<Vec<&str>> {
        if let TomlValue::Array(items) = self {
            items.iter().map(|v| v.as_str()).collect()
        } else {
            None
        }
    }
}

fn parse_scalar(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

fn parse_value(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array: {s:?}");
        }
        let inner = &s[1..s.len() - 1];
        let items: Vec<TomlValue> = inner
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(parse_scalar)
            .collect::<Result<_>>()?;
        return Ok(TomlValue::Array(items));
    }
    parse_scalar(s)
}

/// Parse into section -> key -> value (top-level keys land in "").
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>> {
    let mut out: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // only strip comments outside strings (strings here never
            // contain '#': good enough for experiment configs)
            Some(idx) if !raw[..idx].contains('"') || raw[..idx].matches('"').count() % 2 == 0 => {
                &raw[..idx]
            }
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: bad section header {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let v = parse_value(value)
            .with_context(|| format!("line {}: {value:?}", lineno + 1))?;
        out.entry(section.clone()).or_default().insert(key.trim().to_string(), v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
# experiment config
name = "fig2"
[train]
steps = 500
lr = 0.05
lambdas = [0.001, 0.002, 0.005]
verbose = true
"#;
        let t = parse_toml(text).unwrap();
        assert_eq!(t[""]["name"].as_str(), Some("fig2"));
        assert_eq!(t["train"]["steps"].as_int(), Some(500));
        assert_eq!(t["train"]["lr"].as_float(), Some(0.05));
        assert_eq!(t["train"]["lambdas"].as_float_array().unwrap().len(), 3);
        assert_eq!(t["train"]["verbose"].as_bool(), Some(true));
    }

    #[test]
    fn int_coerces_to_float() {
        let t = parse_toml("x = 3").unwrap();
        assert_eq!(t[""]["x"].as_float(), Some(3.0));
    }

    #[test]
    fn comments_stripped() {
        let t = parse_toml("x = 1 # trailing\n# full line\ny = 2").unwrap();
        assert_eq!(t[""]["x"].as_int(), Some(1));
        assert_eq!(t[""]["y"].as_int(), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_toml("x =").is_err());
        assert!(parse_toml("just words").is_err());
        assert!(parse_toml("[unterminated").is_err());
    }

    #[test]
    fn empty_array() {
        let t = parse_toml("xs = []").unwrap();
        assert_eq!(t[""]["xs"], TomlValue::Array(vec![]));
    }

    #[test]
    fn typed_array_accessors() {
        let t = parse_toml("ns = [1, 2, 3]\nss = [\"a\", \"b\"]\nmixed = [1, \"x\"]").unwrap();
        assert_eq!(t[""]["ns"].as_usize_array(), Some(vec![1, 2, 3]));
        assert_eq!(t[""]["ss"].as_str_array(), Some(vec!["a", "b"]));
        assert_eq!(t[""]["mixed"].as_usize_array(), None, "non-int item rejects the array");
        assert_eq!(t[""]["mixed"].as_str_array(), None, "non-str item rejects the array");
        let neg = parse_toml("ns = [-1, 2]").unwrap();
        assert_eq!(neg[""]["ns"].as_usize_array(), None, "negative item rejects the array");
    }
}
