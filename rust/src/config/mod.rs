//! Configuration system: a first-party mini-TOML parser (sections,
//! scalars, arrays of scalars, comments) plus the typed experiment
//! configs the CLI and pipeline consume.

mod toml;
mod types;

pub use toml::{parse_toml, TomlValue};
pub use types::{
    ExecConfig, LccAlgoConfig, MlpPipelineConfig, PoolMode, ResnetPipelineConfig, ServeConfig,
};
