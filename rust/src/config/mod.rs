//! Configuration system: a first-party mini-TOML parser (sections,
//! scalars, arrays of scalars, comments) plus the typed experiment
//! configs the CLI and pipeline consume.

mod toml;
mod types;

pub use toml::{parse_toml, TomlValue};
pub use types::{
    serve_models_from_env, serve_models_from_toml, AccWidth, ExecConfig, ExecMode, LccAlgoConfig,
    MlpPipelineConfig, ModelSpec, PoolMode, RemoteConfig, ResnetPipelineConfig, Saturation,
    ServeConfig, ShardMode, ShardSpec,
};
