//! Typed experiment configurations (defaults chosen to reproduce the
//! paper's setups at this host's scale; every field overridable from a
//! TOML file via `from_toml`).

use super::toml::{parse_toml, TomlValue};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

type Sections = BTreeMap<String, BTreeMap<String, TomlValue>>;

fn get<'a>(t: &'a Sections, section: &str, key: &str) -> Option<&'a TomlValue> {
    t.get(section).and_then(|s| s.get(key))
}

/// LCC algorithm selection for configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LccAlgoConfig {
    Fp,
    Fs,
}

impl LccAlgoConfig {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fp" | "FP" => Some(LccAlgoConfig::Fp),
            "fs" | "FS" => Some(LccAlgoConfig::Fs),
            _ => None,
        }
    }
}

/// The Fig. 2 experiment (MLP on synthetic digits).
#[derive(Clone, Debug)]
pub struct MlpPipelineConfig {
    pub train_examples: usize,
    pub test_examples: usize,
    pub train_steps: usize,
    pub share_retrain_steps: usize,
    pub lr: f32,
    pub lr_decay_every: usize,
    pub lr_decay: f32,
    pub lambda: f32,
    pub prune_eps: f32,
    pub lcc_algo: LccAlgoConfig,
    pub target_rel_err: f64,
    pub seed: u64,
}

impl Default for MlpPipelineConfig {
    fn default() -> Self {
        MlpPipelineConfig {
            train_examples: 4096,
            test_examples: 1024,
            train_steps: 600,
            share_retrain_steps: 120,
            lr: 0.05,
            lr_decay_every: 100,
            lr_decay: 0.95,
            lambda: 0.15,
            prune_eps: 1e-4,
            lcc_algo: LccAlgoConfig::Fs,
            target_rel_err: 0.02,
            seed: 0,
        }
    }
}

impl MlpPipelineConfig {
    pub fn from_toml(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let t = parse_toml(&text)?;
        let mut c = MlpPipelineConfig::default();
        if let Some(v) = get(&t, "mlp", "train_examples").and_then(TomlValue::as_int) {
            c.train_examples = v as usize;
        }
        if let Some(v) = get(&t, "mlp", "test_examples").and_then(TomlValue::as_int) {
            c.test_examples = v as usize;
        }
        if let Some(v) = get(&t, "mlp", "train_steps").and_then(TomlValue::as_int) {
            c.train_steps = v as usize;
        }
        if let Some(v) = get(&t, "mlp", "share_retrain_steps").and_then(TomlValue::as_int) {
            c.share_retrain_steps = v as usize;
        }
        if let Some(v) = get(&t, "mlp", "lr").and_then(TomlValue::as_float) {
            c.lr = v as f32;
        }
        if let Some(v) = get(&t, "mlp", "lambda").and_then(TomlValue::as_float) {
            c.lambda = v as f32;
        }
        if let Some(v) = get(&t, "mlp", "lcc_algo").and_then(TomlValue::as_str) {
            if let Some(a) = LccAlgoConfig::parse(v) {
                c.lcc_algo = a;
            }
        }
        if let Some(v) = get(&t, "mlp", "seed").and_then(TomlValue::as_int) {
            c.seed = v as u64;
        }
        Ok(c)
    }
}

/// The Table-I experiment (residual CNN on synthetic tiny-images).
#[derive(Clone, Debug)]
pub struct ResnetPipelineConfig {
    pub train_examples: usize,
    pub test_examples: usize,
    pub train_steps: usize,
    pub lr: f32,
    pub lambda: f32,
    /// PK groups (kernel columns) have kh x fewer elements than FK groups
    /// (whole kernels), so their norms are ~sqrt(kh) smaller; the paper
    /// tunes lambda per layer/grouping (Sec. III-B) — this scale keeps
    /// the two groupings' pruning pressure comparable.
    pub lambda_pk_scale: f32,
    pub prune_eps: f32,
    pub target_rel_err: f64,
    pub eval_limit: usize,
    pub seed: u64,
}

impl Default for ResnetPipelineConfig {
    fn default() -> Self {
        ResnetPipelineConfig {
            train_examples: 2048,
            test_examples: 512,
            train_steps: 300,
            lr: 0.04,
            lambda: 0.05,
            lambda_pk_scale: 0.577, // 1/sqrt(3) for 3x3 kernels
            prune_eps: 1e-4,
            target_rel_err: 0.02,
            eval_limit: 256,
            seed: 0,
        }
    }
}

/// Serving layer configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_timeout_us: u64,
    pub workers: usize,
    /// per-model cap on in-flight requests (submit → response): submits
    /// beyond it are load-shed with a typed error and a
    /// `model.<name>.shed` counter. 0 disables shedding.
    pub queue_capacity: usize,
    /// optional path to a compression recipe (`[compress]` TOML) applied
    /// to every checkpoint the `serve` CLI loads; absent → per-checkpoint
    /// discovery (artifact dirs carrying `recipe.toml`) with the legacy
    /// LCC-only fallback
    pub recipe: Option<String>,
    /// remote shard-worker addresses (`host:port`) gathered behind one
    /// served model: `[serve] remote_shards = ["h:p", ...]` in TOML,
    /// `LCCNN_SERVE_REMOTE_SHARDS` as a comma list, or repeatable
    /// `--remote-shard` CLI flags (merged after config/env). An entry
    /// may list replicas of one range as `"h:p|h:p"` — and any
    /// addresses whose handshakes report the same output range are
    /// grouped as replicas with client-side failover regardless
    pub remote_shards: Vec<String>,
    /// transport tuning for those shards
    pub remote: RemoteConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            batch_timeout_us: 200,
            workers: 1,
            queue_capacity: 1024,
            recipe: None,
            remote_shards: Vec::new(),
            remote: RemoteConfig::default(),
        }
    }
}

impl ServeConfig {
    fn overrides(t: &Sections, mut c: ServeConfig) -> ServeConfig {
        let read = |key: &str| -> Option<usize> {
            get(t, "serve", key)
                .and_then(TomlValue::as_int)
                .and_then(|v| usize::try_from(v).ok())
        };
        if let Some(v) = read("max_batch") {
            c.max_batch = v.max(1);
        }
        if let Some(v) = read("batch_timeout_us") {
            c.batch_timeout_us = v as u64;
        }
        if let Some(v) = read("workers") {
            c.workers = v;
        }
        if let Some(v) = read("queue_capacity") {
            c.queue_capacity = v;
        }
        if let Some(v) = get(t, "serve", "recipe").and_then(TomlValue::as_str) {
            c.recipe = Some(v.to_string());
        }
        if let Some(TomlValue::Array(items)) = get(t, "serve", "remote_shards") {
            c.remote_shards =
                items.iter().filter_map(|v| v.as_str().map(str::to_string)).collect();
        }
        c.remote = RemoteConfig::overrides(t, c.remote);
        c
    }

    /// Overrides from a `[serve]` TOML section, over the defaults.
    pub fn from_toml(path: &Path) -> Result<Self> {
        Self::from_toml_over(path, ServeConfig::default())
    }

    /// Overrides from a `[serve]` TOML section layered over `base` —
    /// keys the file does not set keep `base`'s values, so env- or
    /// flag-derived settings survive a config file that only lists
    /// models.
    pub fn from_toml_over(path: &Path, base: ServeConfig) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let t = parse_toml(&text)?;
        Ok(Self::overrides(&t, base))
    }

    /// Environment overrides: `LCCNN_SERVE_MAX_BATCH`,
    /// `LCCNN_SERVE_BATCH_TIMEOUT_US`, `LCCNN_SERVE_QUEUE_CAPACITY`,
    /// `LCCNN_SERVE_RECIPE`, `LCCNN_SERVE_REMOTE_SHARDS` (comma list),
    /// plus the `LCCNN_REMOTE_*` transport knobs ([`RemoteConfig`]).
    pub fn from_env() -> Self {
        fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
            std::env::var(name).ok().and_then(|v| v.parse().ok())
        }
        let mut c = ServeConfig::default();
        if let Some(v) = env_parse::<usize>("LCCNN_SERVE_MAX_BATCH") {
            c.max_batch = v.max(1);
        }
        if let Some(v) = env_parse::<u64>("LCCNN_SERVE_BATCH_TIMEOUT_US") {
            c.batch_timeout_us = v;
        }
        if let Some(v) = env_parse::<usize>("LCCNN_SERVE_QUEUE_CAPACITY") {
            c.queue_capacity = v;
        }
        if let Ok(v) = std::env::var("LCCNN_SERVE_RECIPE") {
            if !v.is_empty() {
                c.recipe = Some(v);
            }
        }
        if let Ok(v) = std::env::var("LCCNN_SERVE_REMOTE_SHARDS") {
            let addrs: Vec<String> =
                v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
            if !addrs.is_empty() {
                c.remote_shards = addrs;
            }
        }
        c.remote = RemoteConfig::from_env_over(c.remote);
        c
    }
}

/// Remote shard transport tuning (`[serve.remote]` in TOML,
/// `LCCNN_REMOTE_*` in the environment). Consumed by
/// `exec::remote::RemoteOptions::from_config`; the knobs bound how long
/// a dead shard can hold a batch before it sheds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RemoteConfig {
    /// TCP dial budget per attempt, in milliseconds.
    pub connect_timeout_ms: u64,
    /// Per-response read budget (also the write budget), in milliseconds.
    pub read_timeout_ms: u64,
    /// Additional attempts after a transport failure (reconnect+resend).
    pub retries: u32,
    /// Base backoff before retry `k` is `backoff_ms << (k-1)` ms.
    pub backoff_ms: u64,
    /// Dead-cooldown window, in milliseconds: after all retries fail,
    /// batches shed instantly for this long, then a single half-open
    /// probe attempt re-dials (success un-deads the shard, failure
    /// re-arms the window).
    pub cooldown_ms: u64,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            connect_timeout_ms: 1000,
            read_timeout_ms: 5000,
            retries: 2,
            backoff_ms: 50,
            cooldown_ms: 250,
        }
    }
}

impl RemoteConfig {
    fn overrides(t: &Sections, mut c: RemoteConfig) -> RemoteConfig {
        let read = |key: &str| -> Option<u64> {
            get(t, "serve.remote", key)
                .and_then(TomlValue::as_int)
                .and_then(|v| u64::try_from(v).ok())
        };
        if let Some(v) = read("connect_timeout_ms") {
            c.connect_timeout_ms = v.max(1);
        }
        if let Some(v) = read("read_timeout_ms") {
            c.read_timeout_ms = v.max(1);
        }
        if let Some(v) = read("retries") {
            c.retries = v.min(16) as u32;
        }
        if let Some(v) = read("backoff_ms") {
            c.backoff_ms = v;
        }
        if let Some(v) = read("cooldown_ms") {
            c.cooldown_ms = v.max(1);
        }
        c
    }

    /// Environment overrides: `LCCNN_REMOTE_CONNECT_TIMEOUT_MS`,
    /// `LCCNN_REMOTE_READ_TIMEOUT_MS`, `LCCNN_REMOTE_RETRIES`,
    /// `LCCNN_REMOTE_BACKOFF_MS`, `LCCNN_REMOTE_COOLDOWN_MS`.
    pub fn from_env_over(mut c: RemoteConfig) -> RemoteConfig {
        fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
            std::env::var(name).ok().and_then(|v| v.parse().ok())
        }
        if let Some(v) = env_parse::<u64>("LCCNN_REMOTE_CONNECT_TIMEOUT_MS") {
            c.connect_timeout_ms = v.max(1);
        }
        if let Some(v) = env_parse::<u64>("LCCNN_REMOTE_READ_TIMEOUT_MS") {
            c.read_timeout_ms = v.max(1);
        }
        if let Some(v) = env_parse::<u32>("LCCNN_REMOTE_RETRIES") {
            c.retries = v.min(16);
        }
        if let Some(v) = env_parse::<u64>("LCCNN_REMOTE_BACKOFF_MS") {
            c.backoff_ms = v;
        }
        if let Some(v) = env_parse::<u64>("LCCNN_REMOTE_COOLDOWN_MS") {
            c.cooldown_ms = v.max(1);
        }
        c
    }
}

/// One model for the multi-model server: a name, the checkpoint path to
/// load it from (a 2-D `.npy` or a checkpoint dir), and an optional
/// per-model engine tuning override.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub path: String,
    /// per-model `ExecConfig` override (`[serve.exec.<name>]` in TOML);
    /// `None` = use the deployment-wide default
    pub exec: Option<ExecConfig>,
}

impl ModelSpec {
    /// Parse a `name=path` CLI/env spec.
    pub fn parse(s: &str) -> Option<Self> {
        let (name, path) = s.split_once('=')?;
        let (name, path) = (name.trim(), path.trim());
        if name.is_empty() || path.is_empty() {
            return None;
        }
        Some(ModelSpec { name: name.to_string(), path: path.to_string(), exec: None })
    }
}

/// Models from a `[serve.models]` TOML section (`name = "path"` per
/// line). A model may carry engine tuning in its own
/// `[serve.exec.<name>]` section, layered over the file's `[exec]`
/// section (which itself layers over the defaults).
pub fn serve_models_from_toml(path: &Path) -> Result<Vec<ModelSpec>> {
    let text = std::fs::read_to_string(path)?;
    let t = parse_toml(&text)?;
    let has_file_exec = t.contains_key("exec");
    let base = ExecConfig::overrides(&t, "exec", ExecConfig::default());
    let mut out = Vec::new();
    if let Some(models) = t.get("serve.models") {
        for (name, v) in models {
            let Some(p) = v.as_str() else {
                anyhow::bail!("[serve.models] {name}: path must be a string, got {v:?}");
            };
            // a file-level [exec] section applies to *every* model of
            // the file; a [serve.exec.<name>] section layers on top
            let section = format!("serve.exec.{name}");
            let exec = if t.contains_key(&section) {
                Some(ExecConfig::overrides(&t, &section, base))
            } else if has_file_exec {
                Some(base)
            } else {
                None
            };
            out.push(ModelSpec { name: name.clone(), path: p.to_string(), exec });
        }
    }
    Ok(out)
}

/// Models from the `LCCNN_SERVE_MODELS` environment variable — a
/// comma-separated list of `name=path` specs. Malformed entries are
/// skipped with a warning.
pub fn serve_models_from_env() -> Vec<ModelSpec> {
    let Ok(raw) = std::env::var("LCCNN_SERVE_MODELS") else {
        return Vec::new();
    };
    raw.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .filter_map(|s| {
            let spec = ModelSpec::parse(s);
            if spec.is_none() {
                log::warn!("LCCNN_SERVE_MODELS: skipping malformed spec {s:?}");
            }
            spec
        })
        .collect()
}

/// How a sharded executor drives its per-shard engines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardMode {
    /// run shard engines one after another on the submitting thread
    /// (deterministic scheduling; debugging and differential testing)
    Serial,
    /// run shard engines concurrently, dispatched per `pool_mode`
    /// (persistent worker pool or per-call scoped threads)
    #[default]
    Parallel,
}

impl ShardMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Some(ShardMode::Serial),
            "parallel" => Some(ShardMode::Parallel),
            _ => None,
        }
    }

    /// The TOML/env spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMode::Serial => "serial",
            ShardMode::Parallel => "parallel",
        }
    }
}

/// Sharding of one plan across independent engines: how many shards and
/// how to drive them. Used by `[compress.shard]` recipe sections and by
/// `ExecConfig::{shards, shard_mode}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// number of output-range shards (values <= 1 mean unsharded; the
    /// executor clamps to the output count so no shard is ever empty)
    pub shards: usize,
    pub mode: ShardMode,
}

impl Default for ShardSpec {
    /// The minimal real split: 2 shards, driven in parallel — what a
    /// bare `[compress.shard]` section with no keys means.
    fn default() -> Self {
        ShardSpec { shards: 2, mode: ShardMode::default() }
    }
}

impl ShardSpec {
    /// The one effective-sharding rule: an explicit spec when present,
    /// else the engine tuning's `shards` knob promoted to a spec (so
    /// `LCCNN_EXEC_SHARDS` / `[exec] shards` shard recipe-served
    /// artifacts too). `None` = one unsharded engine.
    pub fn effective(explicit: Option<ShardSpec>, exec: &ExecConfig) -> Option<ShardSpec> {
        explicit.or_else(|| {
            (exec.shards > 1).then(|| ShardSpec { shards: exec.shards, mode: exec.shard_mode })
        })
    }
}

/// How the exec engine dispatches its parallel kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PoolMode {
    /// spawn + join `std::thread::scope` workers per `execute_batch`
    /// call — the PR-1 behaviour, kept as a fallback and so the
    /// equivalence suite can diff the two dispatch paths
    Scoped,
    /// dispatch onto the persistent worker pool
    /// (`crate::exec::WorkerPool`): zero thread spawns after warmup
    #[default]
    Persistent,
}

impl PoolMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scoped" => Some(PoolMode::Scoped),
            "persistent" | "pool" => Some(PoolMode::Persistent),
            _ => None,
        }
    }
}

/// Arithmetic mode of the execution engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// f32 lane kernels (`exec::BatchEngine`): coefficients applied as
    /// float multiplies; bit-identical to the `NaiveExecutor` oracle
    #[default]
    Float,
    /// integer lane kernels (`exec::FixedEngine`): inputs quantized to
    /// fixed-point mantissas, every ±2^k coefficient applied as an
    /// arithmetic shift — the hardware-faithful adder datapath
    Fixed,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "float" | "f32" => Some(ExecMode::Float),
            "fixed" | "int" | "integer" => Some(ExecMode::Fixed),
            _ => None,
        }
    }

    /// The TOML/env spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Float => "float",
            ExecMode::Fixed => "fixed",
        }
    }
}

/// Accumulator width of the fixed-point datapath.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AccWidth {
    /// 32-bit accumulators: the narrow-datapath model (FPGA DSP-ish);
    /// overflow is governed by the saturation policy
    W32,
    /// 64-bit accumulators: overflow is practically unreachable for
    /// sane formats and graph depths
    #[default]
    W64,
}

impl AccWidth {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "32" | "i32" => Some(AccWidth::W32),
            "64" | "i64" => Some(AccWidth::W64),
            _ => None,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            AccWidth::W32 => 32,
            AccWidth::W64 => 64,
        }
    }
}

/// What the fixed-point datapath does on accumulator overflow.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Saturation {
    /// clamp to the accumulator range (the usual DSP behaviour; keeps
    /// the analytic error bound meaningful up to the clamp point)
    #[default]
    Saturate,
    /// two's-complement wraparound (the cheapest hardware; a faithful
    /// model of an unguarded adder chain)
    Wrap,
}

impl Saturation {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "saturate" | "sat" => Some(Saturation::Saturate),
            "wrap" => Some(Saturation::Wrap),
            _ => None,
        }
    }

    /// The TOML/env spelling of this policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            Saturation::Saturate => "saturate",
            Saturation::Wrap => "wrap",
        }
    }
}

/// Tuning for the adder-graph execution engine (`crate::exec`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    /// worker threads; 0 = one per available core
    pub threads: usize,
    /// samples per lane chunk (the batch-major lane width)
    pub chunk: usize,
    /// minimum batch size before chunks are spread across threads —
    /// below this, dispatch overhead beats the parallelism (serving
    /// latency path stays single-threaded)
    pub parallel_min_batch: usize,
    /// minimum ops in an ASAP level before the ops of that level are
    /// split across threads for a *single* chunk (wide-graph, small-batch
    /// workloads)
    pub level_parallel_min_ops: usize,
    /// parallel dispatch strategy: persistent pool (default) or per-call
    /// scoped threads
    pub pool_mode: PoolMode,
    /// idle pool workers spin this long (µs) polling for work before
    /// parking on the condvar (0 = park immediately)
    pub pool_spin_us: u64,
    /// parked pool workers re-check for work/shutdown at this interval
    /// (ms); bounds worst-case shutdown latency
    pub pool_park_ms: u64,
    /// partition graph-built engines into this many output-range shards
    /// (`exec::ShardedExecutor`); 0 or 1 = one unsharded engine
    pub shards: usize,
    /// how the shard engines are driven (serial for deterministic
    /// debugging, parallel for throughput)
    pub shard_mode: ShardMode,
    /// arithmetic mode: float lane kernels (default) or the
    /// fixed-point shift-add datapath (`exec::FixedEngine`)
    pub exec_mode: ExecMode,
    /// fractional bits of the fixed-point activation grid (value =
    /// mantissa · 2^-frac); only read in fixed mode
    pub fixed_frac_bits: u32,
    /// accumulator width of the fixed datapath; only read in fixed mode
    pub fixed_acc: AccWidth,
    /// overflow policy of the fixed datapath; only read in fixed mode
    pub fixed_sat: Saturation,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            threads: 0,
            chunk: 64,
            parallel_min_batch: 128,
            level_parallel_min_ops: 8192,
            pool_mode: PoolMode::Persistent,
            pool_spin_us: 20,
            pool_park_ms: 100,
            shards: 1,
            shard_mode: ShardMode::Parallel,
            exec_mode: ExecMode::Float,
            fixed_frac_bits: 12,
            fixed_acc: AccWidth::W64,
            fixed_sat: Saturation::Saturate,
        }
    }
}

impl ExecConfig {
    /// Single-threaded variant (deterministic scheduling, no spawns).
    pub fn serial() -> Self {
        ExecConfig { threads: 1, ..ExecConfig::default() }
    }

    /// Environment overrides over the defaults, one per field:
    /// `LCCNN_EXEC_THREADS`, `LCCNN_EXEC_CHUNK`,
    /// `LCCNN_EXEC_PARALLEL_MIN_BATCH`, `LCCNN_EXEC_LEVEL_MIN_OPS`,
    /// `LCCNN_EXEC_POOL_MODE` (`scoped`|`persistent`),
    /// `LCCNN_EXEC_POOL_SPIN_US`, `LCCNN_EXEC_POOL_PARK_MS`,
    /// `LCCNN_EXEC_SHARDS`, `LCCNN_EXEC_SHARD_MODE` (`serial`|`parallel`),
    /// `LCCNN_EXEC_MODE` (`float`|`fixed`),
    /// `LCCNN_EXEC_FIXED_FRAC_BITS`, `LCCNN_EXEC_FIXED_ACC_BITS`
    /// (`32`|`64`), `LCCNN_EXEC_FIXED_SATURATION` (`saturate`|`wrap`).
    pub fn from_env() -> Self {
        Self::from_env_over(ExecConfig::default())
    }

    /// The same environment overrides layered over `base` — how a
    /// recipe's `[exec]` section and the deployment environment compose
    /// (file first, env on top).
    pub fn from_env_over(mut c: ExecConfig) -> Self {
        fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
            std::env::var(name).ok().and_then(|v| v.parse().ok())
        }
        if let Some(v) = env_parse::<usize>("LCCNN_EXEC_THREADS") {
            c.threads = v;
        }
        if let Some(v) = env_parse::<usize>("LCCNN_EXEC_CHUNK") {
            c.chunk = v.max(1);
        }
        if let Some(v) = env_parse::<usize>("LCCNN_EXEC_PARALLEL_MIN_BATCH") {
            c.parallel_min_batch = v;
        }
        if let Some(v) = env_parse::<usize>("LCCNN_EXEC_LEVEL_MIN_OPS") {
            c.level_parallel_min_ops = v;
        }
        if let Some(m) =
            std::env::var("LCCNN_EXEC_POOL_MODE").ok().as_deref().and_then(PoolMode::parse)
        {
            c.pool_mode = m;
        }
        if let Some(v) = env_parse::<u64>("LCCNN_EXEC_POOL_SPIN_US") {
            c.pool_spin_us = v;
        }
        if let Some(v) = env_parse::<u64>("LCCNN_EXEC_POOL_PARK_MS") {
            c.pool_park_ms = v;
        }
        if let Some(v) = env_parse::<usize>("LCCNN_EXEC_SHARDS") {
            c.shards = v.max(1);
        }
        if let Some(m) =
            std::env::var("LCCNN_EXEC_SHARD_MODE").ok().as_deref().and_then(ShardMode::parse)
        {
            c.shard_mode = m;
        }
        if let Some(m) = std::env::var("LCCNN_EXEC_MODE").ok().as_deref().and_then(ExecMode::parse)
        {
            c.exec_mode = m;
        }
        if let Some(v) = env_parse::<u32>("LCCNN_EXEC_FIXED_FRAC_BITS") {
            c.fixed_frac_bits = v.min(30);
        }
        if let Some(a) = std::env::var("LCCNN_EXEC_FIXED_ACC_BITS")
            .ok()
            .as_deref()
            .and_then(AccWidth::parse)
        {
            c.fixed_acc = a;
        }
        if let Some(s) = std::env::var("LCCNN_EXEC_FIXED_SATURATION")
            .ok()
            .as_deref()
            .and_then(Saturation::parse)
        {
            c.fixed_sat = s;
        }
        c
    }

    /// Apply the overrides of one parsed TOML section onto `base`.
    /// Shared by `[exec]`, the per-model `[serve.exec.<name>]` sections
    /// of a multi-model serve config, and compression recipes.
    pub(crate) fn overrides(t: &Sections, section: &str, mut c: ExecConfig) -> ExecConfig {
        // negative values are nonsense here (0 already means "auto" for
        // threads): ignore them instead of letting `as usize` wrap
        let read = |key: &str| -> Option<usize> {
            get(t, section, key)
                .and_then(TomlValue::as_int)
                .and_then(|v| usize::try_from(v).ok())
        };
        if let Some(v) = read("threads") {
            c.threads = v;
        }
        if let Some(v) = read("chunk") {
            c.chunk = v.max(1);
        }
        if let Some(v) = read("parallel_min_batch") {
            c.parallel_min_batch = v;
        }
        if let Some(v) = read("level_parallel_min_ops") {
            c.level_parallel_min_ops = v;
        }
        if let Some(v) =
            get(t, section, "pool_mode").and_then(TomlValue::as_str).and_then(PoolMode::parse)
        {
            c.pool_mode = v;
        }
        if let Some(v) = read("pool_spin_us") {
            c.pool_spin_us = v as u64;
        }
        if let Some(v) = read("pool_park_ms") {
            c.pool_park_ms = v as u64;
        }
        if let Some(v) = read("shards") {
            c.shards = v.max(1);
        }
        if let Some(v) =
            get(t, section, "shard_mode").and_then(TomlValue::as_str).and_then(ShardMode::parse)
        {
            c.shard_mode = v;
        }
        if let Some(v) =
            get(t, section, "exec_mode").and_then(TomlValue::as_str).and_then(ExecMode::parse)
        {
            c.exec_mode = v;
        }
        if let Some(v) = read("fixed_frac_bits") {
            c.fixed_frac_bits = (v as u32).min(30);
        }
        if let Some(v) = get(t, section, "fixed_acc_bits")
            .and_then(TomlValue::as_int)
            .and_then(|v| AccWidth::parse(&v.to_string()))
        {
            c.fixed_acc = v;
        }
        if let Some(v) = get(t, section, "fixed_saturation")
            .and_then(TomlValue::as_str)
            .and_then(Saturation::parse)
        {
            c.fixed_sat = v;
        }
        c
    }

    /// Overrides from an `[exec]` TOML section.
    pub fn from_toml(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let t = parse_toml(&text)?;
        Ok(Self::overrides(&t, "exec", ExecConfig::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = MlpPipelineConfig::default();
        assert!(c.train_steps > 0 && c.lr > 0.0);
        let r = ResnetPipelineConfig::default();
        assert!(r.eval_limit <= r.test_examples);
    }

    #[test]
    fn from_toml_overrides() {
        let dir = std::env::temp_dir().join(format!("lccnn-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(&p, "[mlp]\ntrain_steps = 42\nlambda = 0.5\nlcc_algo = \"fp\"\n").unwrap();
        let c = MlpPipelineConfig::from_toml(&p).unwrap();
        assert_eq!(c.train_steps, 42);
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.lcc_algo, LccAlgoConfig::Fp);
        // untouched fields keep defaults
        assert_eq!(c.lr, MlpPipelineConfig::default().lr);
    }

    #[test]
    fn algo_parse() {
        assert_eq!(LccAlgoConfig::parse("FS"), Some(LccAlgoConfig::Fs));
        assert_eq!(LccAlgoConfig::parse("nope"), None);
    }

    #[test]
    fn exec_defaults_and_toml_overrides() {
        let d = ExecConfig::default();
        assert!(d.chunk > 0);
        assert_eq!(d.pool_mode, PoolMode::Persistent);
        assert_eq!(ExecConfig::serial().threads, 1);
        let dir = std::env::temp_dir().join(format!("lccnn-exec-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("e.toml");
        std::fs::write(&p, "[exec]\nthreads = 2\nchunk = 16\nlevel_parallel_min_ops = 5\n")
            .unwrap();
        let c = ExecConfig::from_toml(&p).unwrap();
        assert_eq!(c.threads, 2);
        assert_eq!(c.chunk, 16);
        assert_eq!(c.level_parallel_min_ops, 5);
        assert_eq!(c.parallel_min_batch, d.parallel_min_batch);
        assert_eq!(c.pool_mode, d.pool_mode, "untouched pool fields keep defaults");
    }

    #[test]
    fn serve_from_toml_and_model_specs() {
        let dir = std::env::temp_dir().join(format!("lccnn-serve-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.toml");
        std::fs::write(
            &p,
            "[exec]\nthreads = 2\n\
             [serve]\nmax_batch = 8\nbatch_timeout_us = 500\n\
             [serve.models]\nmlp = \"ckpts/mlp\"\nresnet = \"ckpts/resnet\"\n\
             [serve.exec.resnet]\nchunk = 16\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&p).unwrap();
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.batch_timeout_us, 500);
        assert_eq!(c.workers, ServeConfig::default().workers, "untouched fields keep defaults");
        let models = serve_models_from_toml(&p).unwrap();
        assert_eq!(models.len(), 2);
        let mlp = models.iter().find(|m| m.name == "mlp").unwrap();
        assert_eq!(mlp.path, "ckpts/mlp");
        let mlp_exec = mlp.exec.expect("file-level [exec] applies to every model");
        assert_eq!(mlp_exec.threads, 2);
        assert_eq!(mlp_exec.chunk, ExecConfig::default().chunk, "no per-model override");
        let resnet = models.iter().find(|m| m.name == "resnet").unwrap();
        let exec = resnet.exec.expect("per-model override");
        assert_eq!(exec.chunk, 16, "per-model key applied");
        assert_eq!(exec.threads, 2, "per-model override layers over [exec]");
    }

    #[test]
    fn exec_from_env_over_keeps_base_when_env_unset() {
        // no LCCNN_EXEC_* set in the test environment for these fields'
        // uncommon values, so the base must survive untouched
        let base = ExecConfig { chunk: 123, parallel_min_batch: 456, ..ExecConfig::default() };
        let c = ExecConfig::from_env_over(base);
        if std::env::var("LCCNN_EXEC_CHUNK").is_err() {
            assert_eq!(c.chunk, 123);
        }
        if std::env::var("LCCNN_EXEC_PARALLEL_MIN_BATCH").is_err() {
            assert_eq!(c.parallel_min_batch, 456);
        }
    }

    #[test]
    fn serve_toml_reads_queue_capacity_and_recipe() {
        let dir = std::env::temp_dir().join(format!("lccnn-serve-shed-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("q.toml");
        std::fs::write(&p, "[serve]\nqueue_capacity = 7\nrecipe = \"r.toml\"\n").unwrap();
        let c = ServeConfig::from_toml(&p).unwrap();
        assert_eq!(c.queue_capacity, 7);
        assert_eq!(c.recipe.as_deref(), Some("r.toml"));
        assert!(ServeConfig::default().recipe.is_none());
    }

    #[test]
    fn serve_toml_reads_remote_shards_and_transport() {
        let dir = std::env::temp_dir().join(format!("lccnn-serve-remote-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("remote.toml");
        std::fs::write(
            &p,
            "[serve]\nremote_shards = [\"10.0.0.1:7411|10.0.0.3:7411\", \"10.0.0.2:7411\"]\n\
             [serve.remote]\nconnect_timeout_ms = 250\nread_timeout_ms = 900\n\
             retries = 1\nbackoff_ms = 20\ncooldown_ms = 125\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&p).unwrap();
        // replica lists ride through verbatim; the connector splits '|'
        assert_eq!(c.remote_shards, vec!["10.0.0.1:7411|10.0.0.3:7411", "10.0.0.2:7411"]);
        let want = RemoteConfig {
            connect_timeout_ms: 250,
            read_timeout_ms: 900,
            retries: 1,
            backoff_ms: 20,
            cooldown_ms: 125,
        };
        assert_eq!(c.remote, want);
        assert!(ServeConfig::default().remote_shards.is_empty());
        assert_eq!(ServeConfig::default().remote, RemoteConfig::default());
    }

    #[test]
    fn serve_from_toml_over_layers_instead_of_resetting() {
        let dir = std::env::temp_dir().join(format!("lccnn-serve-layer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("models-only.toml");
        std::fs::write(&p, "[serve.models]\nmlp = \"ckpts/mlp\"\n").unwrap();
        let base = ServeConfig { max_batch: 4, batch_timeout_us: 77, ..Default::default() };
        let c = ServeConfig::from_toml_over(&p, base).unwrap();
        assert_eq!(c.max_batch, 4, "file without [serve] must not reset the base");
        assert_eq!(c.batch_timeout_us, 77);
    }

    #[test]
    fn model_spec_parse() {
        assert_eq!(
            ModelSpec::parse("mlp=ckpts/mlp"),
            Some(ModelSpec { name: "mlp".into(), path: "ckpts/mlp".into(), exec: None })
        );
        assert_eq!(
            ModelSpec::parse(" a = b=c "),
            Some(ModelSpec { name: "a".into(), path: "b=c".into(), exec: None }),
            "first '=' splits; paths may contain '='"
        );
        assert!(ModelSpec::parse("no-equals").is_none());
        assert!(ModelSpec::parse("=path").is_none());
        assert!(ModelSpec::parse("name=").is_none());
    }

    #[test]
    fn shard_mode_parse_and_toml_overrides() {
        assert_eq!(ShardMode::parse("serial"), Some(ShardMode::Serial));
        assert_eq!(ShardMode::parse("PARALLEL"), Some(ShardMode::Parallel));
        assert_eq!(ShardMode::parse("nope"), None);
        assert_eq!(ShardMode::Serial.as_str(), "serial");
        assert_eq!(ExecConfig::default().shards, 1, "unsharded by default");
        let spec = ShardSpec::default();
        assert_eq!(spec.shards, 2);
        assert_eq!(spec.mode, ShardMode::Parallel);
        let dir = std::env::temp_dir().join(format!("lccnn-shard-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.toml");
        std::fs::write(&p, "[exec]\nshards = 3\nshard_mode = \"serial\"\n").unwrap();
        let c = ExecConfig::from_toml(&p).unwrap();
        assert_eq!(c.shards, 3);
        assert_eq!(c.shard_mode, ShardMode::Serial);
        // shards = 0 is clamped to 1 (unsharded), not wrapped
        std::fs::write(&p, "[exec]\nshards = 0\n").unwrap();
        assert_eq!(ExecConfig::from_toml(&p).unwrap().shards, 1);
    }

    #[test]
    fn exec_mode_parse_and_toml_overrides() {
        assert_eq!(ExecMode::parse("float"), Some(ExecMode::Float));
        assert_eq!(ExecMode::parse("FIXED"), Some(ExecMode::Fixed));
        assert_eq!(ExecMode::parse("int"), Some(ExecMode::Fixed));
        assert_eq!(ExecMode::parse("nope"), None);
        assert_eq!(ExecMode::Fixed.as_str(), "fixed");
        assert_eq!(AccWidth::parse("32"), Some(AccWidth::W32));
        assert_eq!(AccWidth::parse("i64"), Some(AccWidth::W64));
        assert_eq!(AccWidth::parse("16"), None);
        assert_eq!(AccWidth::W32.bits(), 32);
        assert_eq!(Saturation::parse("wrap"), Some(Saturation::Wrap));
        assert_eq!(Saturation::parse("SAT"), Some(Saturation::Saturate));
        assert_eq!(Saturation::parse("nope"), None);
        assert_eq!(Saturation::Wrap.as_str(), "wrap");
        let d = ExecConfig::default();
        assert_eq!(d.exec_mode, ExecMode::Float, "float engine by default");
        assert_eq!(d.fixed_acc, AccWidth::W64);
        assert_eq!(d.fixed_sat, Saturation::Saturate);
        let dir = std::env::temp_dir().join(format!("lccnn-mode-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.toml");
        std::fs::write(
            &p,
            "[exec]\nexec_mode = \"fixed\"\nfixed_frac_bits = 10\n\
             fixed_acc_bits = 32\nfixed_saturation = \"wrap\"\n",
        )
        .unwrap();
        let c = ExecConfig::from_toml(&p).unwrap();
        assert_eq!(c.exec_mode, ExecMode::Fixed);
        assert_eq!(c.fixed_frac_bits, 10);
        assert_eq!(c.fixed_acc, AccWidth::W32);
        assert_eq!(c.fixed_sat, Saturation::Wrap);
        // absurd frac widths are clamped, not taken literally
        std::fs::write(&p, "[exec]\nfixed_frac_bits = 99\n").unwrap();
        assert_eq!(ExecConfig::from_toml(&p).unwrap().fixed_frac_bits, 30);
    }

    #[test]
    fn pool_mode_parse_and_toml_overrides() {
        assert_eq!(PoolMode::parse("scoped"), Some(PoolMode::Scoped));
        assert_eq!(PoolMode::parse("PERSISTENT"), Some(PoolMode::Persistent));
        assert_eq!(PoolMode::parse("pool"), Some(PoolMode::Persistent));
        assert_eq!(PoolMode::parse("nope"), None);
        let dir = std::env::temp_dir().join(format!("lccnn-pool-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("p.toml");
        std::fs::write(&p, "[exec]\npool_mode = \"scoped\"\npool_spin_us = 0\npool_park_ms = 7\n")
            .unwrap();
        let c = ExecConfig::from_toml(&p).unwrap();
        assert_eq!(c.pool_mode, PoolMode::Scoped);
        assert_eq!(c.pool_spin_us, 0);
        assert_eq!(c.pool_park_ms, 7);
    }
}
