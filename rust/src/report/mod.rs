//! Text tables for bench output — every paper table/figure bench renders
//! its rows through this module so the output format is uniform and
//! grep-able in bench_output.txt.

/// Column-aligned text table.
#[derive(Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a compression ratio like the paper's tables ("46.5").
pub fn ratio(baseline: usize, compressed: usize) -> String {
    if compressed == 0 {
        return "inf".to_string();
    }
    format!("{:.1}", baseline as f64 / compressed as f64)
}

/// Format an accuracy like the paper's tables ("55.2 %").
pub fn percent(frac: f64) -> String {
    format!("{:.1} %", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ratio"]);
        t.add_row(vec!["fs".into(), "46.5".into()]);
        t.add_row(vec!["fp-long-name".into(), "2.0".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("fs") && r.contains("46.5"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn ratio_and_percent() {
        assert_eq!(ratio(100, 4), "25.0");
        assert_eq!(ratio(10, 0), "inf");
        assert_eq!(percent(0.552), "55.2 %");
    }
}
