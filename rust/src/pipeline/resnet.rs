//! The Table-I pipeline: residual CNN, group-lasso pruning with FK/PK
//! kernel groupings, LCC decomposition of every 3×3 conv layer with both
//! algorithms, exact adder accounting and artifact-based evaluation of
//! the LCC-approximated network.

use crate::config::ResnetPipelineConfig;
use crate::convert::{conv_positions, fk_matrices, pk_matrices, ConvCost};
use crate::data::{synth_tiny, Dataset};
use crate::lcc::{decompose, LccConfig};
use crate::nn::checkpoint::ParamStore;
use crate::nn::npy::NpyArray;
use crate::nn::resnet::{conv_kernel_names, param_specs, CHANNELS, IMG};
use crate::quant::{matrix_csd_adders, FixedPointFormat};
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::{Conv2dParams, Matrix, Padding, Tensor4};
use crate::train::{ConvGrouping, LossCurve, LrSchedule, ResnetTrainer};
use anyhow::Result;

/// Conv-to-matrix representation (paper Sec. III-D).
pub use crate::train::ConvGrouping as ConvRepr;

/// One Table-I cell: compression ratio + top-1 accuracy.
#[derive(Clone, Copy, Debug)]
pub struct TableCell {
    pub additions: usize,
    pub ratio: f64,
    pub accuracy: f64,
}

#[derive(Debug)]
pub struct ResnetPipelineOutput {
    pub baseline_accuracy: f64,
    pub baseline_additions: usize,
    pub baseline_curve: LossCurve,
    /// rows: (method name, FK cell, PK cell)
    pub rows: Vec<(String, TableCell, TableCell)>,
}

/// The 3×3 conv layers Table I compresses: (kernel name, input side,
/// stride). Stem, 1×1 projections and the fc layer are charged at fixed
/// CSD cost in every method.
pub fn conv_specs() -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut side = IMG;
    for si in 0..3usize {
        for bi in 0..2usize {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            out.push((format!("s{si}b{bi}_c1w"), side, stride));
            if stride == 2 {
                side /= 2;
            }
            out.push((format!("s{si}b{bi}_c2w"), side, 1));
        }
    }
    out
}

fn kernel_tensor(store: &ParamStore, name: &str) -> Tensor4 {
    let arr = store.get(name).unwrap_or_else(|| panic!("missing {name}"));
    let s = &arr.shape;
    Tensor4::from_vec(s[0], s[1], s[2], s[3], arr.data.clone())
}

/// Additions of one conv layer under a representation, with the
/// per-channel matrix cost injected (CSD for baselines, LCC for the
/// compressed rows).
pub fn conv_layer_additions(
    kernel: &Tensor4,
    in_side: usize,
    stride: usize,
    repr: ConvRepr,
    cost_fn: &mut dyn FnMut(&Matrix) -> usize,
) -> usize {
    let (kh, kw, _ci, co) = kernel.shape();
    let params = Conv2dParams { stride, padding: Padding::Same };
    let positions = conv_positions(in_side, in_side, kh, kw, params);
    match repr {
        ConvRepr::Fk => {
            let mats = fk_matrices(kernel);
            ConvCost::fk(positions, &mats, co, cost_fn).total()
        }
        ConvRepr::Pk => {
            let mats = pk_matrices(kernel);
            ConvCost::pk(positions, &mats, co, kw, cost_fn).total()
        }
    }
}

/// CSD additions of the layers every method leaves untouched (stem,
/// projections, fc), so totals compare like with like.
pub fn fixed_additions(store: &ParamStore, fmt: FixedPointFormat) -> usize {
    let mut total = 0usize;
    // stem: FK representation at CSD cost
    let stem = kernel_tensor(store, "stem_w");
    total += conv_layer_additions(&stem, IMG, 1, ConvRepr::Fk, &mut |m| {
        matrix_csd_adders(m, fmt)
    });
    // 1x1 projections
    for name in ["s1b0_projw", "s2b0_projw"] {
        if store.get(name).is_some() {
            let k = kernel_tensor(store, name);
            let side = if name.starts_with("s1") { IMG } else { IMG / 2 };
            total += conv_layer_additions(&k, side, 2, ConvRepr::Fk, &mut |m| {
                matrix_csd_adders(m, fmt)
            });
        }
    }
    // fc
    let fc = store.get("fc_w").unwrap();
    let fc_m = Matrix::from_vec(fc.shape[0], fc.shape[1], fc.data.clone());
    total += matrix_csd_adders(&fc_m, fmt);
    total
}

/// Total network additions under a representation + matrix cost model.
pub fn network_additions(
    store: &ParamStore,
    repr: ConvRepr,
    fmt: FixedPointFormat,
    cost_fn: &mut dyn FnMut(&Matrix) -> usize,
) -> usize {
    let mut total = fixed_additions(store, fmt);
    for (name, side, stride) in conv_specs() {
        let k = kernel_tensor(store, &name);
        total += conv_layer_additions(&k, side, stride, repr, cost_fn);
    }
    total
}

/// Replace every 3×3 kernel by its LCC reconstruction (per input-channel
/// matrix, in the given representation) — the network the accuracy
/// columns of the LCC rows actually evaluate.
pub fn lcc_approx_store(store: &ParamStore, repr: ConvRepr, cfg: &LccConfig) -> ParamStore {
    let mut out = store.clone();
    for name in conv_kernel_names() {
        let kernel = kernel_tensor(store, &name);
        let (kh, kw, _ci, co) = kernel.shape();
        let mut approx = kernel.clone();
        match repr {
            ConvRepr::Fk => {
                for (k, m) in fk_matrices(&kernel).iter().enumerate() {
                    if m.nnz() == 0 {
                        continue;
                    }
                    let dense = decompose(m, cfg).to_dense();
                    for n in 0..co {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                *approx.at_mut(ky, kx, k, n) = dense.at(n, ky * kw + kx);
                            }
                        }
                    }
                }
            }
            ConvRepr::Pk => {
                for (k, m) in pk_matrices(&kernel).iter().enumerate() {
                    if m.nnz() == 0 {
                        continue;
                    }
                    let dense = decompose(m, cfg).to_dense();
                    for n in 0..co {
                        for c in 0..kw {
                            for r in 0..kh {
                                *approx.at_mut(r, c, k, n) = dense.at(n * kw + c, r);
                            }
                        }
                    }
                }
            }
        }
        let (a, b, c, d) = approx.shape();
        out.insert(&name, NpyArray::f32(vec![a, b, c, d], approx.data().to_vec()));
    }
    out
}

/// Evaluate a parameter store through the `resnet_eval` artifact.
pub fn evaluate_store(
    rt: &Runtime,
    store: &ParamStore,
    data: &Dataset,
    limit: usize,
) -> Result<f64> {
    let exe = rt.get("resnet_eval")?;
    let specs = param_specs();
    let b = exe.spec.inputs[specs.len()].dims[0];
    let n = data.len().min(limit);
    let batches = (n / b).max(1).min(data.len() / b);
    let mut correct = 0.0;
    let mut seen = 0usize;
    for i in 0..batches {
        let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
        let (x, y) = data.gather(&idx);
        let mut inputs: Vec<HostTensor> = specs
            .iter()
            .map(|(name, shape)| {
                let arr = store.get(name).unwrap_or_else(|| panic!("missing {name}"));
                HostTensor::F32(shape.clone(), arr.data.clone())
            })
            .collect();
        inputs.push(HostTensor::F32(vec![b, IMG, IMG, CHANNELS], x));
        inputs.push(HostTensor::I32(vec![b], y));
        let outs = exe.run(&inputs)?;
        correct += outs[1].first();
        seen += b;
    }
    Ok(correct / seen.max(1) as f64)
}

fn lcc_cfg(base: LccConfig, target_rel_err: f64) -> LccConfig {
    let mut c = base;
    c.target_rel_err = target_rel_err;
    c
}

/// Run the full Table-I pipeline.
pub fn run_resnet_pipeline(
    rt: &Runtime,
    cfg: &ResnetPipelineConfig,
) -> Result<ResnetPipelineOutput> {
    let fmt = FixedPointFormat::default_weights();
    let sched = LrSchedule { base: cfg.lr, every: 100, factor: 0.9 };
    let train_data = synth_tiny::generate(cfg.train_examples, cfg.seed);
    let test_data = synth_tiny::generate(cfg.test_examples, cfg.seed + 1);

    // baseline: unregularized, FK representation at CSD cost
    log::info!("[resnet] baseline training ({} steps)", cfg.train_steps);
    let mut base_tr =
        ResnetTrainer::new(rt, &crate::nn::resnet::init_params(cfg.seed + 5), ConvGrouping::Fk)?;
    let baseline_curve = base_tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 6)?;
    let (_, baseline_accuracy) = base_tr.evaluate(&test_data)?;
    let base_store = base_tr.params_store();
    let baseline_additions =
        network_additions(&base_store, ConvRepr::Fk, fmt, &mut |m| matrix_csd_adders(m, fmt));

    let mut rows: Vec<(String, Vec<TableCell>)> = vec![
        ("reg. training".into(), Vec::new()),
        ("reg. training + LCC (FP algorithm)".into(), Vec::new()),
        ("reg. training + LCC (FS algorithm)".into(), Vec::new()),
    ];

    for grouping in [ConvGrouping::Fk, ConvGrouping::Pk] {
        log::info!("[resnet] regularized training ({grouping:?}, lambda={})", cfg.lambda);
        let mut tr =
            ResnetTrainer::new(rt, &crate::nn::resnet::init_params(cfg.seed + 7), grouping)?;
        tr.lambda = match grouping {
            ConvGrouping::Fk => cfg.lambda,
            ConvGrouping::Pk => cfg.lambda * cfg.lambda_pk_scale,
        };
        tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 8)?;
        let (_, reg_acc) = tr.evaluate(&test_data)?;
        let store = tr.params_store();

        let reg_adds = network_additions(&store, grouping, fmt, &mut |m| matrix_csd_adders(m, fmt));
        rows[0].1.push(TableCell {
            additions: reg_adds,
            ratio: baseline_additions as f64 / reg_adds.max(1) as f64,
            accuracy: reg_acc,
        });

        for (row_idx, base_cfg) in [(1usize, LccConfig::fp()), (2usize, LccConfig::fs())] {
            let lcfg = lcc_cfg(base_cfg, cfg.target_rel_err);
            let adds = network_additions(&store, grouping, fmt, &mut |m| {
                if m.nnz() == 0 {
                    0
                } else {
                    decompose(m, &lcfg).additions()
                }
            });
            let approx = lcc_approx_store(&store, grouping, &lcfg);
            let acc = evaluate_store(rt, &approx, &test_data, cfg.eval_limit)?;
            rows[row_idx].1.push(TableCell {
                additions: adds,
                ratio: baseline_additions as f64 / adds.max(1) as f64,
                accuracy: acc,
            });
        }
    }

    Ok(ResnetPipelineOutput {
        baseline_accuracy,
        baseline_additions,
        baseline_curve,
        rows: rows
            .into_iter()
            .map(|(name, cells)| (name, cells[0], cells[1]))
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::init_params;

    #[test]
    fn conv_specs_cover_all_kernels() {
        let specs = conv_specs();
        assert_eq!(specs.len(), 12);
        let names: Vec<&str> = specs.iter().map(|(n, _, _)| n.as_str()).collect();
        for k in conv_kernel_names() {
            assert!(names.contains(&k.as_str()), "missing {k}");
        }
        // spatial bookkeeping: strided layers halve the side
        assert_eq!(specs[0].1, 32);
        assert!(specs.iter().any(|(n, side, s)| n == "s2b0_c1w" && *side == 16 && *s == 2));
        assert!(specs.iter().any(|(n, side, _)| n == "s2b1_c2w" && *side == 8));
    }

    #[test]
    fn network_additions_positive_and_ordered() {
        let fmt = FixedPointFormat::default_weights();
        let store = init_params(0);
        let csd_fk =
            network_additions(&store, ConvRepr::Fk, fmt, &mut |m| matrix_csd_adders(m, fmt));
        assert!(csd_fk > 100_000, "suspiciously small: {csd_fk}");
        // zero-cost matvecs leave only the fixed part + recombination
        let floor = network_additions(&store, ConvRepr::Fk, fmt, &mut |_| 0);
        assert!(floor < csd_fk);
    }

    #[test]
    fn lcc_approx_store_preserves_shapes_and_closeness() {
        let store = init_params(1);
        let mut cfg = LccConfig::fs();
        cfg.target_rel_err = 0.02;
        let approx = lcc_approx_store(&store, ConvRepr::Fk, &cfg);
        for name in conv_kernel_names() {
            let a = store.get(&name).unwrap();
            let b = approx.get(&name).unwrap();
            assert_eq!(a.shape, b.shape);
            let num: f64 = a
                .data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum();
            let den: f64 = a.data.iter().map(|&x| (x as f64).powi(2)).sum();
            assert!(num / den.max(1e-12) < 0.01, "{name}: rel err {}", num / den);
        }
        // untouched params identical
        assert_eq!(store.get("fc_w").unwrap(), approx.get("fc_w").unwrap());
    }
}
