//! The Fig. 2 pipeline: MLP on synthetic digits, layer 1 regularized,
//! three compression stages measured against the unregularized CSD
//! baseline.

use crate::cluster::affinity::{cluster_columns, AffinityParams};
use crate::cluster::Clustering;
use crate::compress::{ModelState, Pipeline};
use crate::config::{ExecConfig, LccAlgoConfig, MlpPipelineConfig};
use crate::data::synth_mnist;
use crate::lcc::{LccConfig, LccDecomposition};
use crate::nn::compressed::{CompressedMlp, Layer1};
use crate::nn::mlp::MlpParams;
use crate::prune::{column_mask, compact_columns};
use crate::quant::{matrix_csd_adders, FixedPointFormat};
use crate::runtime::Runtime;
use crate::share::SharedLayer;
use crate::train::{LossCurve, LrSchedule, MlpTrainer};
use crate::util::Rng;
use anyhow::Result;

/// One Fig. 2 point.
#[derive(Clone, Debug)]
pub struct StageResult {
    pub stage: String,
    /// layer-1 additions (the quantity Fig. 2 tracks)
    pub additions: usize,
    /// baseline additions / stage additions
    pub ratio: f64,
    pub accuracy: f64,
    pub active_columns: usize,
    pub clusters: usize,
}

#[derive(Debug)]
pub struct MlpPipelineOutput {
    pub baseline_additions: usize,
    pub baseline_accuracy: f64,
    pub baseline_curve: LossCurve,
    pub reg_curve: LossCurve,
    pub stages: Vec<StageResult>,
    /// verification SQNR of the final LCC graph vs the shared matrix
    pub lcc_sqnr_db: f64,
    /// SQNR the CSD baseline's own quantization admits on that matrix —
    /// the fair yardstick for lcc_sqnr_db (joint quantization+computing)
    pub quant_sqnr_db: f64,
}

fn lcc_config(cfg: &MlpPipelineConfig) -> LccConfig {
    let mut c = match cfg.lcc_algo {
        LccAlgoConfig::Fp => LccConfig::fp(),
        LccAlgoConfig::Fs => LccConfig::fs(),
    };
    c.target_rel_err = cfg.target_rel_err;
    c
}

/// Map a compact-space clustering to artifact-space labels: active
/// column j gets its cluster exemplar's *original* index; pruned columns
/// point at themselves (so eq. 9 averaging never mixes them in).
pub fn artifact_labels(
    clustering: &Clustering,
    kept: &[usize],
    total: usize,
) -> Vec<i32> {
    let mut labels: Vec<i32> = (0..total as i32).collect();
    for (compact_j, &orig_j) in kept.iter().enumerate() {
        let exemplar_compact = clustering.exemplars[clustering.labels[compact_j]];
        labels[orig_j] = kept[exemplar_compact] as i32;
    }
    labels
}

/// Run the full Fig. 2 pipeline for one lambda.
pub fn run_mlp_pipeline(rt: &Runtime, cfg: &MlpPipelineConfig) -> Result<MlpPipelineOutput> {
    let fmt = FixedPointFormat::default_weights();
    let sched = LrSchedule { base: cfg.lr, every: cfg.lr_decay_every, factor: cfg.lr_decay };
    let train_data = synth_mnist::generate(cfg.train_examples, cfg.seed);
    let test_data = synth_mnist::generate(cfg.test_examples, cfg.seed + 1);

    // --- baseline: unregularized training, CSD cost of dense W1 ----------
    log::info!("[mlp] baseline training ({} steps)", cfg.train_steps);
    let mut base_tr = MlpTrainer::new(rt, &MlpParams::init(cfg.seed + 10))?;
    let baseline_curve = base_tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 20)?;
    let (_, baseline_accuracy) = base_tr.evaluate(&test_data)?;
    let baseline_w1 = base_tr.params().w1;
    let baseline_additions = matrix_csd_adders(&baseline_w1, fmt);

    // --- stage 1: regularized training (group lasso on W1 columns) -------
    log::info!("[mlp] regularized training (lambda={})", cfg.lambda);
    let mut reg_tr = MlpTrainer::new(rt, &MlpParams::init(cfg.seed + 11))?;
    reg_tr.lambda = cfg.lambda;
    let reg_curve = reg_tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 21)?;
    let reg_params = reg_tr.params();
    let mask = column_mask(&reg_params.w1, cfg.prune_eps);
    let compact = compact_columns(&reg_params.w1, cfg.prune_eps);
    log::info!("[mlp] pruning kept {}/{} input columns", compact.kept.len(), mask.len());

    let mut stages = Vec::new();
    let stage_a = CompressedMlp {
        kept: compact.kept.clone(),
        layer1: Layer1::Dense(compact.weights.clone()),
        b1: reg_params.b1.clone(),
        w2: reg_params.w2.clone(),
        b2: reg_params.b2.clone(),
    };
    let a_adds = stage_a.layer1_additions(fmt);
    stages.push(StageResult {
        stage: "reg-training".into(),
        additions: a_adds,
        ratio: baseline_additions as f64 / a_adds.max(1) as f64,
        accuracy: stage_a.accuracy(&test_data),
        active_columns: compact.kept.len(),
        clusters: 0,
    });

    // --- stage 2: weight sharing (cluster + retrain with eq. 9 tying) ----
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    log::info!(
        "[mlp] affinity propagation: {} clusters over {} columns",
        clustering.num_clusters(),
        compact.kept.len()
    );
    reg_tr.lambda = 0.0; // retraining only ties weights, no more pruning
    reg_tr.set_colmask(mask.clone());
    reg_tr.set_cluster_labels(artifact_labels(&clustering, &compact.kept, mask.len()));
    reg_tr.set_share_flag(true);
    let retrain_sched =
        LrSchedule { base: cfg.lr * 0.2, every: cfg.lr_decay_every, factor: cfg.lr_decay };
    reg_tr.train(&train_data, cfg.share_retrain_steps, retrain_sched, 20, cfg.seed + 22)?;
    let shared_params = reg_tr.params();
    let shared_compact = shared_params.w1.select_cols(&compact.kept);
    let shared_layer = SharedLayer::from_clustering(&shared_compact, &clustering);

    let stage_b = CompressedMlp {
        kept: compact.kept.clone(),
        layer1: Layer1::Shared(shared_layer.clone()),
        b1: shared_params.b1.clone(),
        w2: shared_params.w2.clone(),
        b2: shared_params.b2.clone(),
    };
    let b_adds = stage_b.layer1_additions(fmt);
    stages.push(StageResult {
        stage: "reg+sharing".into(),
        additions: b_adds,
        ratio: baseline_additions as f64 / b_adds.max(1) as f64,
        accuracy: stage_b.accuracy(&test_data),
        active_columns: compact.kept.len(),
        clusters: clustering.num_clusters(),
    });

    // --- stage 3: LCC decomposition of the centroid matrix ---------------
    // the compress pipeline's resume path: hand it the retrained shared
    // state and let the LCC stage lower + account it (engine tuning from
    // the LCCNN_EXEC_* environment, as before)
    let lcc_state =
        ModelState::from_shared(shared_compact, compact.kept.clone(), shared_layer.clone());
    let artifact = Pipeline::builder()
        .lcc(&lcc_config(cfg))
        .exec(ExecConfig::from_env())
        .build()?
        .run_state(lcc_state)?;
    let shared_lcc = artifact.lcc().expect("lcc stage ran");
    let lcc_sqnr_db = shared_lcc.decomposition.sqnr_db(&shared_layer.centroids);
    let quant_sqnr_db = {
        let (_, deq) = crate::quant::quantize_matrix(&shared_layer.centroids, fmt);
        crate::util::stats::sqnr_db(shared_layer.centroids.data(), deq.data())
    };
    let stage_c = CompressedMlp::from_compressed(
        artifact,
        shared_params.b1,
        shared_params.w2,
        shared_params.b2,
    );
    let c_adds = stage_c.layer1_additions(fmt);
    stages.push(StageResult {
        stage: "reg+sharing+LCC".into(),
        additions: c_adds,
        ratio: baseline_additions as f64 / c_adds.max(1) as f64,
        accuracy: stage_c.accuracy(&test_data),
        active_columns: compact.kept.len(),
        clusters: clustering.num_clusters(),
    });

    Ok(MlpPipelineOutput {
        baseline_additions,
        baseline_accuracy,
        baseline_curve,
        reg_curve,
        stages,
        lcc_sqnr_db,
        quant_sqnr_db,
    })
}

/// The paper's Sec. IV-A side claim: LCC applied directly to the dense,
/// unpruned matrix only doubles compression. Returns (additions, ratio).
pub fn lcc_only_reference(w1: &crate::tensor::Matrix, cfg: &MlpPipelineConfig) -> (usize, f64) {
    let fmt = FixedPointFormat::default_weights();
    let baseline = matrix_csd_adders(w1, fmt);
    let d: LccDecomposition = crate::lcc::decompose(w1, &lcc_config(cfg));
    let adds = d.additions();
    (adds, baseline as f64 / adds.max(1) as f64)
}

/// Deterministic fake trained weights for unit tests (no PJRT needed).
pub fn synthetic_reg_weights(seed: u64, active: usize) -> crate::tensor::Matrix {
    use crate::tensor::Matrix;
    let mut rng = Rng::new(seed);
    let mut w = Matrix::zeros(300, 784);
    // `active` surviving columns arranged in correlated groups of ~4
    let group = 4.max(active / 12);
    let mut col = 7usize;
    let mut placed = 0;
    while placed < active {
        let base = rng.normal_vec(300, 0.4);
        for _ in 0..group.min(active - placed) {
            for r in 0..300 {
                *w.at_mut(r, col) = base[r] + 0.01 * rng.normal_f32();
            }
            col = (col + 13) % 784;
            placed += 1;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::compact_columns;

    #[test]
    fn artifact_labels_identity_for_pruned() {
        let w = synthetic_reg_weights(0, 24);
        let compact = compact_columns(&w, 1e-6);
        let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
        let labels = artifact_labels(&clustering, &compact.kept, 784);
        // pruned columns point at themselves
        for j in 0..784 {
            if !compact.kept.contains(&j) {
                assert_eq!(labels[j], j as i32);
            }
        }
        // active columns point at an active exemplar
        for &j in &compact.kept {
            assert!(compact.kept.contains(&(labels[j] as usize)));
        }
    }

    #[test]
    fn artifact_labels_members_share_exemplar() {
        let w = synthetic_reg_weights(1, 16);
        let compact = compact_columns(&w, 1e-6);
        let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
        let labels = artifact_labels(&clustering, &compact.kept, 784);
        for (cj, &oj) in compact.kept.iter().enumerate() {
            let exemplar = clustering.exemplars[clustering.labels[cj]];
            assert_eq!(labels[oj], compact.kept[exemplar] as i32);
        }
    }

    #[test]
    fn lcc_only_reference_compresses_but_less() {
        let w = synthetic_reg_weights(2, 200);
        // dense-ish matrix: LCC alone should compress > 1x
        let cfg = MlpPipelineConfig::default();
        let (_, ratio) = lcc_only_reference(&w, &cfg);
        assert!(ratio > 1.0, "ratio {ratio}");
    }
}
