//! Pipeline coordinator: the paper's Algorithm 1 as staged jobs —
//! regularized training → prune/compact → cluster → sharing retrain →
//! LCC decomposition → verification → evaluation → report.
//!
//! [`mlp`] reproduces the Fig. 2 experiment, [`resnet`] the Table-I
//! experiment. Both drive training through the PJRT artifacts
//! ([`crate::train`]) and all compression through the rust substrates;
//! every adder count is backed by a verified adder graph.

pub mod mlp;
pub mod resnet;

pub use mlp::{run_mlp_pipeline, MlpPipelineOutput, StageResult};
pub use resnet::{run_resnet_pipeline, ResnetPipelineOutput, TableCell};
