//! Top-level LCC API: slice, decompose each slice with FP or FS, lower to
//! one adder graph, and report execution-backed addition counts.

use super::fp::{decompose_fp, FpParams};
use super::fs::{decompose_fs, FsParams};
use super::slicing;
use crate::graph::{decomposition_to_graph, AdderGraph};
use crate::tensor::Matrix;
use crate::util::stats;

/// Which LCC algorithm to run (paper Sec. III-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LccAlgorithm {
    FullyParallel { terms_per_row: usize, max_factors: usize },
    FullySequential { max_terms_per_row: usize },
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LccConfig {
    pub algo: LccAlgorithm,
    /// None = auto (≈ log2 rows, paper Sec. III-A)
    pub slice_width: Option<usize>,
    /// per-row relative approximation error target
    pub target_rel_err: f64,
    /// quantization step of the fixed-point baseline: rows never get
    /// approximated beyond the distortion round-to-nearest quantization
    /// already accepts (per-slice floor = step/2 * sqrt(width)). 0
    /// disables the floor.
    pub quant_step: f64,
    pub shift_range: (i32, i32),
}

impl LccConfig {
    pub fn fp() -> Self {
        LccConfig {
            algo: LccAlgorithm::FullyParallel { terms_per_row: 2, max_factors: 16 },
            slice_width: None,
            target_rel_err: 0.02,
            quant_step: crate::quant::FixedPointFormat::default_weights().step(),
            shift_range: (-14, 14),
        }
    }

    pub fn fs() -> Self {
        LccConfig {
            algo: LccAlgorithm::FullySequential { max_terms_per_row: 64 },
            slice_width: None,
            target_rel_err: 0.02,
            quant_step: crate::quant::FixedPointFormat::default_weights().step(),
            shift_range: (-14, 14),
        }
    }
}

/// Per-slice program: a factor chain (FP) or an unstructured graph (FS).
#[derive(Clone, Debug)]
pub enum SliceKind {
    Factors(Vec<super::factor::P2Factor>),
    Graph(AdderGraph),
}

#[derive(Clone, Debug)]
pub struct SliceDecomposition {
    pub col_start: usize,
    pub width: usize,
    pub kind: SliceKind,
}

/// Addition-count breakdown of a lowered decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdditionBreakdown {
    /// adds inside slice programs
    pub intra_slice: usize,
    /// adds combining slice outputs (eq. 3 recombination)
    pub cross_slice: usize,
}

impl AdditionBreakdown {
    pub fn total(&self) -> usize {
        self.intra_slice + self.cross_slice
    }
}

/// A complete decomposition of one matrix: slice programs plus the flat
/// adder graph that executes `W x` end to end.
#[derive(Clone, Debug)]
pub struct LccDecomposition {
    pub n_rows: usize,
    pub n_cols: usize,
    pub slices: Vec<SliceDecomposition>,
    graph: Option<AdderGraph>,
    breakdown: AdditionBreakdown,
}

impl LccDecomposition {
    /// Assemble from already-built slices (used by the graph builder's
    /// tests); `finalize` lowers the graph.
    pub fn from_parts(n_rows: usize, n_cols: usize, slices: Vec<SliceDecomposition>) -> Self {
        LccDecomposition {
            n_rows,
            n_cols,
            slices,
            graph: None,
            breakdown: AdditionBreakdown { intra_slice: 0, cross_slice: 0 },
        }
    }

    fn finalize(mut self) -> Self {
        let intra: usize = self
            .slices
            .iter()
            .map(|s| match &s.kind {
                SliceKind::Factors(fs) => fs.iter().map(|f| f.additions()).sum(),
                SliceKind::Graph(g) => g.additions(),
            })
            .sum();
        let g = decomposition_to_graph(&self);
        let total = g.additions();
        self.breakdown = AdditionBreakdown { intra_slice: intra, cross_slice: total - intra };
        self.graph = Some(g);
        self
    }

    /// The lowered shift-add program.
    pub fn graph(&self) -> &AdderGraph {
        self.graph.as_ref().expect("decomposition not finalized")
    }

    /// Total additions (== graph nodes, execution-backed).
    pub fn additions(&self) -> usize {
        self.graph().additions()
    }

    pub fn breakdown(&self) -> AdditionBreakdown {
        self.breakdown
    }

    /// Evaluate `W x` through the shift-add program.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.graph().execute(x)
    }

    /// Dense reconstruction (for error reporting).
    pub fn to_dense(&self) -> Matrix {
        let g = self.graph();
        let mut m = Matrix::zeros(self.n_rows, self.n_cols);
        let mut e = vec![0.0f32; self.n_cols];
        for c in 0..self.n_cols {
            e[c] = 1.0;
            let col = g.execute(&e);
            for r in 0..self.n_rows {
                *m.at_mut(r, c) = col[r];
            }
            e[c] = 0.0;
        }
        m
    }

    /// SQNR (dB) of the reconstruction against the original matrix.
    pub fn sqnr_db(&self, w: &Matrix) -> f64 {
        let approx = self.to_dense();
        stats::sqnr_db(w.data(), approx.data())
    }
}

/// Decompose `w` per the config: vertical slicing (eq. 3) + per-slice
/// FP/FS programs (eq. 4), lowered to one adder graph.
pub fn decompose(w: &Matrix, cfg: &LccConfig) -> LccDecomposition {
    let width = cfg
        .slice_width
        .unwrap_or_else(|| slicing::auto_width(w.rows(), w.cols()));
    let slices = slicing::slice_columns(w.cols(), width.max(1));
    let mut out = Vec::with_capacity(slices.len());
    for s in slices {
        let sub = w.slice_cols(s.start, s.width);
        // quantization-matched residual floor: round-to-nearest at
        // quant_step admits per-row error up to step/2 per entry
        let abs_err_floor = 0.5 * cfg.quant_step * (s.width as f64).sqrt();
        let kind = match cfg.algo {
            LccAlgorithm::FullyParallel { terms_per_row, max_factors } => {
                let p = FpParams {
                    terms_per_row,
                    max_factors,
                    shift_range: cfg.shift_range,
                    target_rel_err: cfg.target_rel_err,
                    abs_err_floor,
                };
                SliceKind::Factors(decompose_fp(&sub, &p))
            }
            LccAlgorithm::FullySequential { max_terms_per_row } => {
                let p = FsParams {
                    max_terms_per_row,
                    shift_range: cfg.shift_range,
                    target_rel_err: cfg.target_rel_err,
                    abs_err_floor,
                    ..Default::default()
                };
                SliceKind::Graph(decompose_fs(&sub, &p))
            }
        };
        out.push(SliceDecomposition { col_start: s.start, width: s.width, kind });
    }
    LccDecomposition::from_parts(w.rows(), w.cols(), out).finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::verify_against;
    use crate::quant::{matrix_csd_adders, FixedPointFormat};
    use crate::util::Rng;

    fn tall_matrix(seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(128, 24, 0.5, &mut rng)
    }

    #[test]
    fn fs_decomposition_verifies_numerically() {
        let w = tall_matrix(0);
        let d = decompose(&w, &LccConfig::fs());
        let mut rng = Rng::new(1);
        let rep = verify_against(d.graph(), &w, 8, &mut rng);
        assert!(rep.sqnr_db > 25.0, "{rep:?}");
    }

    #[test]
    fn fp_decomposition_verifies_numerically() {
        let w = tall_matrix(2);
        let d = decompose(&w, &LccConfig::fp());
        let mut rng = Rng::new(3);
        let rep = verify_against(d.graph(), &w, 8, &mut rng);
        assert!(rep.sqnr_db > 25.0, "{rep:?}");
    }

    #[test]
    fn lcc_beats_csd_baseline_on_tall_matrix() {
        // The headline property: LCC needs fewer additions than the CSD
        // dense baseline at comparable precision.
        let w = tall_matrix(4);
        let csd = matrix_csd_adders(&w, FixedPointFormat::default_weights());
        let fs = decompose(&w, &LccConfig::fs()).additions();
        let fp = decompose(&w, &LccConfig::fp()).additions();
        assert!(fs < csd, "FS {fs} !< CSD {csd}");
        assert!(fp < csd, "FP {fp} !< CSD {csd}");
    }

    #[test]
    fn fs_beats_fp_on_small_matrices() {
        // Table I's qualitative claim: FS wins when matrices are small
        let mut rng = Rng::new(5);
        let w = Matrix::randn(24, 12, 0.5, &mut rng);
        let fs = decompose(&w, &LccConfig::fs()).additions();
        let fp = decompose(&w, &LccConfig::fp()).additions();
        assert!(fs <= fp, "FS {fs} > FP {fp}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let w = tall_matrix(6);
        let d = decompose(&w, &LccConfig::fs());
        assert_eq!(d.breakdown().total(), d.additions());
        assert!(d.breakdown().cross_slice > 0); // >1 slice at K=24
    }

    #[test]
    fn apply_matches_dense_reconstruction() {
        let w = tall_matrix(7);
        let d = decompose(&w, &LccConfig::fs());
        let dense = d.to_dense();
        let mut rng = Rng::new(8);
        let x: Vec<f32> = rng.normal_vec(w.cols(), 1.0);
        let ya = d.apply(&x);
        let yd = dense.matvec(&x);
        for (a, b) in ya.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn explicit_slice_width_respected() {
        let w = tall_matrix(9);
        let mut cfg = LccConfig::fs();
        cfg.slice_width = Some(6);
        let d = decompose(&w, &cfg);
        assert_eq!(d.slices.len(), 4); // 24 / 6
        assert!(d.slices.iter().all(|s| s.width == 6));
    }

    #[test]
    fn sqnr_meets_target() {
        let w = tall_matrix(10);
        let mut cfg = LccConfig::fs();
        cfg.target_rel_err = 0.01;
        let d = decompose(&w, &cfg);
        assert!(d.sqnr_db(&w) > 35.0, "sqnr {}", d.sqnr_db(&w));
    }
}
