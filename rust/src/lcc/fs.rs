//! Fully sequential (FS) LCC algorithm (paper Sec. III-A; graph-based
//! greedy in the spirit of [Rosenberger et al., IZS 2024]).
//!
//! Unlike FP, computations need not be independent: every partial sum
//! computed for any output row becomes a reusable dictionary atom for all
//! later rows, so common subexpressions are shared across the whole
//! matrix. The result is emitted directly as an [`AdderGraph`]; its node
//! count is the addition cost.

use super::pursuit::{apply_pick, best_pick, Dict};
use crate::graph::{AdderGraph, Operand, OutputSpec};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct FsParams {
    /// cap on pursuit terms per output row
    pub max_terms_per_row: usize,
    /// allowed power-of-two exponents
    pub shift_range: (i32, i32),
    /// stop a row when ||r|| / ||w_row|| drops below this
    pub target_rel_err: f64,
    /// absolute per-row residual floor (quantization-matched; see
    /// [`super::fp::FpParams::abs_err_floor`])
    pub abs_err_floor: f64,
    /// cap on reusable dictionary atoms (memory/search-time guard)
    pub max_dict_atoms: usize,
}

impl Default for FsParams {
    fn default() -> Self {
        FsParams {
            max_terms_per_row: 48,
            shift_range: (-14, 14),
            target_rel_err: 0.02,
            abs_err_floor: 0.0,
            max_dict_atoms: 4096,
        }
    }
}

/// Decompose `w` into a shift-add graph over `w.cols()` inputs with
/// `w.rows()` outputs.
pub fn decompose_fs(w: &Matrix, p: &FsParams) -> AdderGraph {
    let n = w.rows();
    let k = w.cols();
    let mut graph = AdderGraph::new(k);
    // dictionary: value vectors + the operand that computes each
    let mut dict = Dict::identity(k);
    let mut handles: Vec<Operand> = (0..k).map(Operand::input).collect();
    let mut outputs: Vec<OutputSpec> = Vec::with_capacity(n);

    for i in 0..n {
        let t = w.row(i);
        let t_sq: f64 = t.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let target_sq =
            (t_sq * p.target_rel_err * p.target_rel_err).max(p.abs_err_floor * p.abs_err_floor);
        let mut r = t.to_vec();
        let mut partial: Option<(Operand, Vec<f32>)> = None;
        for _ in 0..p.max_terms_per_row {
            let r_sq: f64 = r.iter().map(|&v| (v as f64) * (v as f64)).sum();
            if r_sq <= target_sq {
                break;
            }
            let Some(pick) = best_pick(&r, &dict, p.shift_range) else {
                break;
            };
            let term_op = handles[pick.atom].scaled(pick.shift, pick.negative);
            let c = (pick.shift as f32).exp2() * if pick.negative { -1.0 } else { 1.0 };
            apply_pick(&mut r, &dict, &pick);
            partial = Some(match partial {
                None => {
                    // first term: a pure scaled reference, no adder yet
                    let val: Vec<f32> = dict.atom(pick.atom).iter().map(|&v| c * v).collect();
                    (term_op, val)
                }
                Some((prev_op, prev_val)) => {
                    let node = graph.push_add(prev_op, term_op);
                    let val: Vec<f32> = prev_val
                        .iter()
                        .zip(dict.atom(pick.atom))
                        .map(|(&pv, &av)| pv + c * av)
                        .collect();
                    // the new partial sum is a reusable subexpression
                    if dict.len() < p.max_dict_atoms {
                        dict.push(val.clone());
                        handles.push(node);
                    }
                    (node, val)
                }
            });
        }
        outputs.push(match partial {
            None => OutputSpec::Zero,
            Some((op, _)) => OutputSpec::Ref(op),
        });
    }
    graph.set_outputs(outputs);
    graph
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::verify_against;
    use crate::util::Rng;

    #[test]
    fn graph_approximates_matrix() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(48, 6, 1.0, &mut rng);
        let g = decompose_fs(&w, &FsParams::default());
        let rep = verify_against(&g, &w, 16, &mut rng);
        // target_rel_err is per row; pooled SQNR should be ~-20log10(0.02)
        assert!(rep.sqnr_db > 25.0, "{rep:?}");
    }

    #[test]
    fn reuse_beats_no_reuse_on_correlated_rows() {
        // duplicate rows: the second copy must cost 0 extra additions
        let mut rng = Rng::new(1);
        let base = Matrix::randn(1, 6, 1.0, &mut rng);
        let w = Matrix::from_vec(2, 6, [base.row(0), base.row(0)].concat());
        let g = decompose_fs(&w, &FsParams::default());
        let single = decompose_fs(&base, &FsParams::default());
        assert_eq!(g.additions(), single.additions(), "duplicate row should be free");
    }

    #[test]
    fn scaled_row_is_free() {
        // row1 = 2 * row0: one shift, zero additional adders
        let mut rng = Rng::new(2);
        let base: Vec<f32> = rng.normal_vec(5, 1.0);
        let scaled: Vec<f32> = base.iter().map(|&v| 2.0 * v).collect();
        let w = Matrix::from_vec(2, 5, [base, scaled].concat());
        let g = decompose_fs(&w, &FsParams::default());
        let single = decompose_fs(&w.select_rows(&[0]), &FsParams::default());
        assert_eq!(g.additions(), single.additions());
    }

    #[test]
    fn zero_rows_cost_nothing() {
        let mut w = Matrix::zeros(4, 5);
        *w.at_mut(1, 2) = 1.0; // one po2 entry: a pure shift
        let g = decompose_fs(&w, &FsParams::default());
        assert_eq!(g.additions(), 0);
        let y = g.execute(&[1.0, 1.0, 3.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn tighter_target_costs_more_adders() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(32, 6, 1.0, &mut rng);
        let loose = decompose_fs(&w, &FsParams { target_rel_err: 0.1, ..Default::default() });
        let tight = decompose_fs(&w, &FsParams { target_rel_err: 0.005, ..Default::default() });
        assert!(tight.additions() > loose.additions());
    }

    #[test]
    fn dict_cap_respected() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(64, 8, 1.0, &mut rng);
        let p = FsParams { max_dict_atoms: 10, ..Default::default() };
        let g = decompose_fs(&w, &p); // must not panic / grow unbounded
        assert!(g.additions() > 0);
    }
}
