//! Linear computation coding (paper Sec. III-A).
//!
//! LCC approximates a (tall) matrix by a product of sparse factors whose
//! nonzero entries are signed powers of two (eq. 3-4), turning the
//! matrix-vector product into a shift-add program. Two decomposition
//! algorithms are provided, mirroring the paper:
//!
//! * [`fp`] — **fully parallel**: every factor row holds at most `S`
//!   signed-po2 terms drawn from the *previous* factor's outputs, so all
//!   rows of a factor evaluate independently (shallow, wide graphs).
//! * [`fs`] — **fully sequential**: a graph-based greedy that may reuse
//!   *any* previously computed subexpression (deep, narrow graphs, better
//!   compression on small/ill-conditioned matrices).
//!
//! Wide matrices are vertically sliced into tall submatrices first
//! ([`slicing`]); LCC quality improves with the aspect ratio (paper
//! Sec. III-A properties).

pub mod decompose;
pub mod factor;
pub mod fp;
pub mod fs;
pub mod pursuit;
pub mod slicing;

pub use decompose::{
    decompose, AdditionBreakdown, LccAlgorithm, LccConfig, LccDecomposition, SliceDecomposition,
    SliceKind,
};
pub use factor::{chain_to_dense, P2Factor, Term};
