//! Sparse signed-power-of-two matrix factors (the `F_{e,p}` of eq. 4).

use crate::tensor::Matrix;

/// One term of a factor row: `±2^shift * source[src]` where `src` indexes
/// the previous factor's output vector (or the input slice for F_0).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Term {
    pub src: usize,
    pub shift: i32,
    pub negative: bool,
}

impl Term {
    pub fn coeff(&self) -> f32 {
        let m = (self.shift as f32).exp2();
        if self.negative { -m } else { m }
    }
}

/// A sparse matrix whose entries are signed powers of two, stored by row.
/// An empty row is an all-zero row.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct P2Factor {
    pub in_dim: usize,
    pub rows: Vec<Vec<Term>>,
}

impl P2Factor {
    pub fn new(in_dim: usize, out_dim: usize) -> Self {
        P2Factor { in_dim, rows: vec![Vec::new(); out_dim] }
    }

    pub fn out_dim(&self) -> usize {
        self.rows.len()
    }

    /// Additions to evaluate this factor: `max(terms - 1, 0)` per row.
    pub fn additions(&self) -> usize {
        self.rows.iter().map(|r| r.len().saturating_sub(1)).sum()
    }

    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }

    /// y = F x.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim, "factor apply dim mismatch");
        self.rows
            .iter()
            .map(|row| row.iter().map(|t| t.coeff() * x[t.src]).sum())
            .collect()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.out_dim(), self.in_dim);
        for (r, row) in self.rows.iter().enumerate() {
            for t in row {
                *m.at_mut(r, t.src) += t.coeff();
            }
        }
        m
    }
}

/// Dense matrix of a whole chain `F_P ... F_1 F_0` (F_0 first in the
/// slice).
pub fn chain_to_dense(factors: &[P2Factor]) -> Matrix {
    assert!(!factors.is_empty());
    let mut acc = factors[0].to_dense();
    for f in &factors[1..] {
        acc = f.to_dense().matmul(&acc);
    }
    acc
}

/// Apply a chain to a vector (F_0 first).
pub fn apply_chain(factors: &[P2Factor], x: &[f32]) -> Vec<f32> {
    let mut v = factors[0].apply(x);
    for f in &factors[1..] {
        v = f.apply(&v);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_factor() -> P2Factor {
        // rows: [2^1 x0 - 2^-1 x1, 2^0 x1, (zero row)]
        P2Factor {
            in_dim: 2,
            rows: vec![
                vec![
                    Term { src: 0, shift: 1, negative: false },
                    Term { src: 1, shift: -1, negative: true },
                ],
                vec![Term { src: 1, shift: 0, negative: false }],
                vec![],
            ],
        }
    }

    #[test]
    fn apply_matches_dense() {
        let f = simple_factor();
        let x = [3.0, 4.0];
        let y = f.apply(&x);
        let yd = f.to_dense().matvec(&x);
        assert_eq!(y, yd);
        assert_eq!(y, vec![6.0 - 2.0, 4.0, 0.0]);
    }

    #[test]
    fn additions_per_row() {
        let f = simple_factor();
        assert_eq!(f.additions(), 1); // 2-term row costs 1, others 0
        assert_eq!(f.nnz(), 3);
    }

    #[test]
    fn chain_matches_explicit_product() {
        let f0 = simple_factor(); // 3x2
        let f1 = P2Factor {
            in_dim: 3,
            rows: vec![vec![
                Term { src: 0, shift: 0, negative: false },
                Term { src: 2, shift: 2, negative: false },
            ]],
        }; // 1x3
        let x = [1.0, -2.0];
        let y = apply_chain(&[f0.clone(), f1.clone()], &x);
        let dense = chain_to_dense(&[f0, f1]);
        assert_eq!(dense.rows(), 1);
        assert_eq!(dense.cols(), 2);
        let yd = dense.matvec(&x);
        for (a, b) in y.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
