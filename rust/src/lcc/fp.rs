//! Fully parallel (FP) LCC algorithm (paper Sec. III-A).
//!
//! Factor after factor, every target row is re-approximated with at most
//! `S` signed-po2 terms over the rows of the *current* product
//! `F_p ... F_0` — all rows of a factor depend only on the previous
//! factor's outputs, so the resulting adder graph is level-parallel:
//! ideal for FPGA row-pipelining, at the cost of efficiency on small or
//! ill-behaved matrices (which Table I of the paper demonstrates).

use super::factor::{P2Factor, Term};
use super::pursuit::{pursue, Dict};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct FpParams {
    /// S: max nonzero terms per factor row
    pub terms_per_row: usize,
    /// P cap: maximum number of factors
    pub max_factors: usize,
    /// allowed power-of-two exponents
    pub shift_range: (i32, i32),
    /// stop when every row's relative residual ||r||/||w_row|| is below
    /// this
    pub target_rel_err: f64,
    /// absolute per-row residual floor: LCC never spends adders below the
    /// distortion the fixed-point baseline already accepts (the paper's
    /// joint quantization+computing framing)
    pub abs_err_floor: f64,
}

impl Default for FpParams {
    fn default() -> Self {
        FpParams {
            terms_per_row: 2,
            max_factors: 16,
            shift_range: (-14, 14),
            target_rel_err: 0.02, // ~34 dB per row
            abs_err_floor: 0.0,
        }
    }
}

/// Decompose a (tall) matrix into a chain of P2 factors, F_0 first
/// (F_0 consumes the input slice; later factors consume the previous
/// factor's N outputs).
pub fn decompose_fp(w: &Matrix, p: &FpParams) -> Vec<P2Factor> {
    let n = w.rows();
    let k = w.cols();
    assert!(p.terms_per_row >= 1 && p.max_factors >= 1);

    let row_norms_sq: Vec<f64> = (0..n)
        .map(|r| w.row(r).iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect();
    let floor_sq = p.abs_err_floor * p.abs_err_floor;
    let targets_sq: Vec<f64> = row_norms_sq
        .iter()
        .map(|&nsq| (nsq * p.target_rel_err * p.target_rel_err).max(floor_sq))
        .collect();

    let mut factors: Vec<P2Factor> = Vec::new();
    let mut dict = Dict::identity(k);

    for _ in 0..p.max_factors {
        let mut factor = P2Factor::new(dict.len(), n);
        let mut approx_rows: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut all_converged = true;
        for i in 0..n {
            let (picks, residual) =
                pursue(w.row(i), &dict, p.terms_per_row, targets_sq[i], p.shift_range);
            let mut row_val = vec![0.0f32; k];
            for pk in &picks {
                factor.rows[i].push(Term { src: pk.atom, shift: pk.shift, negative: pk.negative });
                let c = (pk.shift as f32).exp2() * if pk.negative { -1.0 } else { 1.0 };
                for (rv, &av) in row_val.iter_mut().zip(dict.atom(pk.atom)) {
                    *rv += c * av;
                }
            }
            let res_sq: f64 = residual.iter().map(|&v| (v as f64) * (v as f64)).sum();
            if res_sq > targets_sq[i] {
                all_converged = false;
            }
            approx_rows.push(row_val);
        }
        factors.push(factor);
        if all_converged {
            break;
        }
        dict = Dict::from_atoms(approx_rows);
    }
    factors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lcc::factor::chain_to_dense;
    use crate::util::Rng;

    fn rel_err(w: &Matrix, approx: &Matrix) -> f64 {
        let mut diff = approx.clone();
        diff.sub_assign(w);
        diff.frobenius() / w.frobenius()
    }

    #[test]
    fn error_decreases_with_factors() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(64, 6, 1.0, &mut rng);
        let mut errs = Vec::new();
        for max_f in [1, 2, 4, 8] {
            let p = FpParams { max_factors: max_f, target_rel_err: 0.0, ..Default::default() };
            let f = decompose_fp(&w, &p);
            errs.push(rel_err(&w, &chain_to_dense(&f)));
        }
        assert!(errs.windows(2).all(|w| w[1] <= w[0] + 1e-9), "{errs:?}");
        assert!(errs.last().unwrap() < &0.05, "{errs:?}");
    }

    #[test]
    fn converges_to_target() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(128, 7, 1.0, &mut rng);
        let p = FpParams::default();
        let f = decompose_fp(&w, &p);
        let approx = chain_to_dense(&f);
        // per-row check
        for i in 0..w.rows() {
            let wn: f64 = w.row(i).iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
            let en: f64 = w
                .row(i)
                .iter()
                .zip(approx.row(i))
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(en <= wn * p.target_rel_err * 1.5, "row {i}: {en} vs {wn}");
        }
    }

    #[test]
    fn respects_terms_per_row() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(32, 5, 1.0, &mut rng);
        let p = FpParams {
            terms_per_row: 3,
            target_rel_err: 0.0,
            max_factors: 4,
            ..Default::default()
        };
        for f in decompose_fp(&w, &p) {
            assert!(f.rows.iter().all(|r| r.len() <= 3));
        }
    }

    #[test]
    fn zero_matrix_gives_empty_rows() {
        let w = Matrix::zeros(8, 4);
        let f = decompose_fp(&w, &FpParams::default());
        assert_eq!(f.len(), 1);
        assert!(f[0].rows.iter().all(|r| r.is_empty()));
        assert_eq!(f[0].additions(), 0);
    }

    #[test]
    fn power_of_two_matrix_exact_one_factor() {
        let w = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, -0.5]]);
        let p = FpParams { terms_per_row: 2, ..Default::default() };
        let f = decompose_fp(&w, &p);
        let approx = chain_to_dense(&f);
        assert!(rel_err(&w, &approx) < 1e-7);
        assert_eq!(f[0].additions(), 0); // single-term rows: shifts only
    }
}
