//! Greedy signed-power-of-two matching pursuit — the shared inner loop of
//! both LCC algorithms.
//!
//! Given a target vector `t` and a dictionary of atoms, repeatedly pick
//! the (atom, ±2^shift) pair that maximally reduces the residual energy
//! `||r - c a||^2`, i.e. maximizes `2 c <r,a> - c^2 ||a||^2` over the
//! power-of-two grid. The optimal unconstrained coefficient is
//! `<r,a>/||a||^2`; only the two nearest powers of two need checking
//! (the reduction is unimodal in log-space).

/// A selected pursuit term.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pick {
    pub atom: usize,
    pub shift: i32,
    pub negative: bool,
    /// residual energy after applying this pick
    pub residual_sq: f64,
}

/// Dictionary with cached squared norms.
pub struct Dict {
    atoms: Vec<Vec<f32>>,
    norms_sq: Vec<f64>,
    dim: usize,
}

impl Dict {
    pub fn new(dim: usize) -> Self {
        Dict { atoms: Vec::new(), norms_sq: Vec::new(), dim }
    }

    pub fn from_atoms(atoms: Vec<Vec<f32>>) -> Self {
        assert!(!atoms.is_empty());
        let dim = atoms[0].len();
        let mut d = Dict::new(dim);
        for a in atoms {
            d.push(a);
        }
        d
    }

    /// Unit-vector dictionary e_0..e_{dim-1}.
    pub fn identity(dim: usize) -> Self {
        let mut d = Dict::new(dim);
        for i in 0..dim {
            let mut e = vec![0.0; dim];
            e[i] = 1.0;
            d.push(e);
        }
        d
    }

    pub fn push(&mut self, atom: Vec<f32>) {
        assert_eq!(atom.len(), self.dim, "atom dim mismatch");
        let nsq = atom.iter().map(|&v| (v as f64) * (v as f64)).sum();
        self.atoms.push(atom);
        self.norms_sq.push(nsq);
    }

    pub fn len(&self) -> usize {
        self.atoms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.atoms.is_empty()
    }

    pub fn atom(&self, i: usize) -> &[f32] {
        &self.atoms[i]
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Round `c` to the best signed power of two within `shift_range`,
/// measured by residual reduction `2 c d - c^2 n` (d = <r,a>, n = ||a||^2).
/// Returns None when no po2 coefficient reduces the residual.
fn best_po2(d: f64, nsq: f64, shift_range: (i32, i32)) -> Option<(i32, bool, f64)> {
    if nsq <= 0.0 || d == 0.0 {
        return None;
    }
    let c_opt = d / nsq;
    let mag = c_opt.abs();
    let negative = c_opt < 0.0;
    let raw = mag.log2();
    let mut best: Option<(i32, bool, f64)> = None;
    for shift in [raw.floor() as i32, raw.ceil() as i32] {
        let shift = shift.clamp(shift_range.0, shift_range.1);
        let c = (shift as f64).exp2() * if negative { -1.0 } else { 1.0 };
        let reduction = 2.0 * c * d - c * c * nsq;
        if reduction > 0.0 && best.map(|b| reduction > b.2).unwrap_or(true) {
            best = Some((shift, negative, reduction));
        }
    }
    best
}

/// Chunked f32 dot product (perf: the f64-widening scalar loop inhibits
/// vectorization and this dot dominates both LCC algorithms — see
/// EXPERIMENTS.md §Perf). f32 accumulation in 8 lanes is accurate enough
/// here: dims are small (slice widths ≤ ~32) and picks only need the
/// argmax, not exact energies.
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let (xa, xb) = (&a[i * 8..i * 8 + 8], &b[i * 8..i * 8 + 8]);
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s as f64
}

/// One pursuit step: the best (atom, signed po2) pick over the whole
/// dictionary for residual `r`, or None if nothing reduces the energy.
pub fn best_pick(r: &[f32], dict: &Dict, shift_range: (i32, i32)) -> Option<Pick> {
    let r_sq: f64 = dot_f32(r, r);
    let mut best: Option<(Pick, f64)> = None;
    for ai in 0..dict.len() {
        let a = dict.atom(ai);
        let d: f64 = dot_f32(r, a);
        if let Some((shift, negative, reduction)) = best_po2(d, dict.norms_sq[ai], shift_range) {
            if best.as_ref().map(|b| reduction > b.1).unwrap_or(true) {
                best = Some((
                    Pick { atom: ai, shift, negative, residual_sq: r_sq - reduction },
                    reduction,
                ));
            }
        }
    }
    best.map(|(p, _)| p)
}

/// Subtract `±2^shift * atom` from the residual in place.
pub fn apply_pick(r: &mut [f32], dict: &Dict, pick: &Pick) {
    let c = (pick.shift as f32).exp2() * if pick.negative { -1.0 } else { 1.0 };
    for (rv, &av) in r.iter_mut().zip(dict.atom(pick.atom)) {
        *rv -= c * av;
    }
}

/// Greedy pursuit of `t` with up to `max_terms` picks, stopping early when
/// the residual energy falls below `target_res_sq`. Returns the picks and
/// the final residual.
pub fn pursue(
    t: &[f32],
    dict: &Dict,
    max_terms: usize,
    target_res_sq: f64,
    shift_range: (i32, i32),
) -> (Vec<Pick>, Vec<f32>) {
    let mut r = t.to_vec();
    let mut picks = Vec::new();
    for _ in 0..max_terms {
        let r_sq: f64 = r.iter().map(|&v| (v as f64) * (v as f64)).sum();
        if r_sq <= target_res_sq {
            break;
        }
        match best_pick(&r, dict, shift_range) {
            Some(p) => {
                apply_pick(&mut r, dict, &p);
                picks.push(p);
            }
            None => break,
        }
    }
    (picks, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn best_po2_exact_power() {
        // c_opt = 0.5 exactly
        let (shift, neg, red) = best_po2(0.5, 1.0, (-8, 8)).unwrap();
        assert_eq!((shift, neg), (-1, false));
        assert!((red - 0.25).abs() < 1e-12); // 2*0.5*0.5 - 0.25*1
    }

    #[test]
    fn best_po2_negative() {
        let (shift, neg, _) = best_po2(-2.0, 1.0, (-8, 8)).unwrap();
        assert_eq!((shift, neg), (1, true));
    }

    #[test]
    fn best_po2_zero_dot_is_none() {
        assert!(best_po2(0.0, 1.0, (-8, 8)).is_none());
    }

    #[test]
    fn pursuit_recovers_po2_combination() {
        // t = 2 a0 - 0.25 a2 should be found exactly in 2 picks
        let dict = Dict::identity(4);
        let t = vec![2.0, 0.0, -0.25, 0.0];
        let (picks, r) = pursue(&t, &dict, 4, 1e-12, (-8, 8));
        assert_eq!(picks.len(), 2);
        assert!(r.iter().all(|&v| v.abs() < 1e-7), "{r:?}");
    }

    #[test]
    fn pursuit_monotone_residual() {
        let mut rng = Rng::new(0);
        let atoms: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(6, 1.0)).collect();
        let dict = Dict::from_atoms(atoms);
        let t = rng.normal_vec(6, 1.0);
        let (picks, _) = pursue(&t, &dict, 12, 0.0, (-10, 10));
        let mut prev = f64::INFINITY;
        for p in &picks {
            assert!(p.residual_sq <= prev + 1e-9, "residual increased");
            prev = p.residual_sq;
        }
        assert!(!picks.is_empty());
    }

    #[test]
    fn pursuit_respects_target() {
        let dict = Dict::identity(3);
        let t = vec![1.0, 1.0, 1.0];
        // target = 2.5 allows stopping after one pick (residual 2.0)
        let (picks, _) = pursue(&t, &dict, 10, 2.5, (-8, 8));
        assert_eq!(picks.len(), 1);
    }

    #[test]
    fn shift_clamped_to_range() {
        let dict = Dict::identity(1);
        let t = vec![1024.0];
        let (picks, _) = pursue(&t, &dict, 1, 0.0, (-2, 2));
        assert_eq!(picks[0].shift, 2);
    }
}
