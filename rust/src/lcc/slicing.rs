//! Vertical slicing of wide matrices into tall submatrices (paper eq. 3).
//!
//! LCC wants an exponential aspect ratio: for an N-row matrix the
//! per-slice width should scale like log2(N) [Lehnert et al. 2023], so a
//! wide `N x K` matrix is cut into `ceil(K / w)` slices of width
//! `w ≈ log2(N)`.

/// A vertical slice: columns `[start, start + width)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slice {
    pub start: usize,
    pub width: usize,
}

/// Heuristic slice width for an `rows x cols` matrix.
pub fn auto_width(rows: usize, cols: usize) -> usize {
    if cols == 0 {
        return 0;
    }
    let w = (rows.max(2) as f64).log2().round() as usize;
    w.clamp(1, cols)
}

/// Partition `cols` columns into slices of width `w` (last may be
/// narrower).
pub fn slice_columns(cols: usize, w: usize) -> Vec<Slice> {
    assert!(w > 0 || cols == 0, "slice width must be positive");
    let mut out = Vec::new();
    let mut start = 0;
    while start < cols {
        let width = w.min(cols - start);
        out.push(Slice { start, width });
        start += width;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_width_grows_with_rows() {
        assert_eq!(auto_width(256, 100), 8);
        assert_eq!(auto_width(1024, 100), 10);
        assert!(auto_width(2, 100) >= 1);
    }

    #[test]
    fn auto_width_clamped_by_cols() {
        assert_eq!(auto_width(1 << 20, 5), 5);
    }

    #[test]
    fn slices_cover_without_overlap() {
        let slices = slice_columns(23, 5);
        assert_eq!(slices.len(), 5);
        let mut covered = 0;
        for s in &slices {
            assert_eq!(s.start, covered);
            covered += s.width;
        }
        assert_eq!(covered, 23);
        assert_eq!(slices.last().unwrap().width, 3);
    }

    #[test]
    fn exact_division() {
        let slices = slice_columns(20, 5);
        assert_eq!(slices.len(), 4);
        assert!(slices.iter().all(|s| s.width == 5));
    }

    #[test]
    fn zero_cols_empty() {
        assert!(slice_columns(0, 4).is_empty());
    }
}
