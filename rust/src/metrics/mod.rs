//! Process-wide metrics registry: counters, gauges and latency
//! histograms, shared between the pipeline coordinator and the serving
//! layer, rendered as text by the CLI and benches.

use crate::util::stats;
use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    /// Raise a counter to an externally tracked monotonic value, so
    /// counters owned elsewhere (e.g. the exec pool's task totals) can be
    /// republished idempotently without double counting.
    pub fn counter_to(&self, name: &str, value: u64) {
        let mut m = self.inner.lock().unwrap();
        let c = m.counters.entry(name.to_string()).or_insert(0);
        *c = (*c).max(value);
    }

    pub fn observe(&self, name: &str, value: f64) {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .entry(name.to_string())
            .or_default()
            .push(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// All counters whose name starts with `prefix`, sorted by name —
    /// how per-group series are enumerated without this registry
    /// knowing the group members (the serving layer's
    /// `Server::models_seen` recovers the `model.<name>.*` roster,
    /// including hot-removed models, this way).
    pub fn counters_with_prefix(&self, prefix: &str) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// (count, mean, p50, p99) of a histogram.
    pub fn summary(&self, name: &str) -> Option<(usize, f64, f64, f64)> {
        let m = self.inner.lock().unwrap();
        let h = m.histograms.get(name)?;
        Some((h.len(), stats::mean(h), stats::percentile(h, 50.0), stats::percentile(h, 99.0)))
    }

    /// Render every metric as aligned text.
    pub fn render(&self) -> String {
        let m = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &m.counters {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in &m.gauges {
            out.push_str(&format!("gauge   {k} = {v:.6}\n"));
        }
        for (k, h) in &m.histograms {
            out.push_str(&format!(
                "hist    {k}: n={} mean={:.6} p50={:.6} p99={:.6}\n",
                h.len(),
                stats::mean(h),
                stats::percentile(h, 50.0),
                stats::percentile(h, 99.0),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("a", 2);
        m.incr("a", 3);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn counter_to_is_idempotent_and_monotone() {
        let m = Metrics::new();
        m.counter_to("pool.tasks", 10);
        m.counter_to("pool.tasks", 10);
        assert_eq!(m.counter("pool.tasks"), 10, "republishing must not double count");
        m.counter_to("pool.tasks", 25);
        assert_eq!(m.counter("pool.tasks"), 25);
        m.counter_to("pool.tasks", 7);
        assert_eq!(m.counter("pool.tasks"), 25, "counters never regress");
    }

    #[test]
    fn counters_with_prefix_selects_the_group() {
        let m = Metrics::new();
        m.incr("model.a.requests", 2);
        m.incr("model.b.requests", 5);
        m.incr("model.a.batches", 1);
        m.incr("requests", 7);
        let a = m.counters_with_prefix("model.a.");
        assert_eq!(
            a,
            vec![("model.a.batches".to_string(), 1), ("model.a.requests".to_string(), 2)]
        );
        assert_eq!(m.counters_with_prefix("model.").len(), 3);
        assert!(m.counters_with_prefix("nope.").is_empty());
    }

    #[test]
    fn histogram_summary() {
        let m = Metrics::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.observe("lat", v);
        }
        let (n, mean, p50, _) = m.summary("lat").unwrap();
        assert_eq!(n, 4);
        assert!((mean - 2.5).abs() < 1e-9);
        assert!(p50 >= 2.0 && p50 <= 3.0);
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n", 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
    }

    #[test]
    fn render_contains_everything() {
        let m = Metrics::new();
        m.incr("c", 1);
        m.gauge("g", 2.0);
        m.observe("h", 3.0);
        let r = m.render();
        assert!(r.contains("counter c") && r.contains("gauge   g") && r.contains("hist    h"));
    }
}
