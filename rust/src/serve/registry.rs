//! Multi-model registry: the named engines a serving process hosts.
//!
//! A deployment serves many compressed models at once (per-layer adder
//! graphs, MLP and ResNet variants — EIE-style one-engine-per-model),
//! all sharing the process-wide persistent worker pool. The registry
//! owns those engines behind names: models can be registered from an
//! already-built [`Executor`], lowered from an [`AdderGraph`], or loaded
//! from an `.npy` checkpoint at runtime through a compression
//! [`Recipe`] (pruned + shared + LCC'd per the recipe — artifact dirs
//! carrying a `recipe.toml` reproduce their exact build), each with its
//! own [`ExecConfig`] override.
//! Hot add/remove is safe under load: every accepted request holds an
//! `Arc<ModelEntry>`, so removing a model only stops *new* submits —
//! in-flight batches keep their engine alive until they complete.

use super::backend::{BatchEvaluator, ExecutorBackend};
use crate::compress::{NetworkCheckpoint, NetworkPipeline, Pipeline, Recipe};
use crate::config::ExecConfig;
use crate::exec::{ExecError, ExecHealth, Executor, RemoteOptions};
use crate::graph::AdderGraph;
use crate::metrics::Metrics;
use crate::nn::load_weight_matrix;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, RwLock};

/// One served model: a named evaluator, plus the executor and engine
/// tuning when the model came in through the exec path (registry-built
/// engines always do; opaque [`BatchEvaluator`] backends registered via
/// [`ModelRegistry::register_evaluator`] have neither).
pub struct ModelEntry {
    name: String,
    evaluator: Arc<dyn BatchEvaluator>,
    executor: Option<Arc<dyn Executor>>,
    exec_cfg: Option<ExecConfig>,
    /// in-flight requests (router submit → response sent); the router's
    /// load shedding admits against this
    pub(crate) queued: AtomicUsize,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The batch evaluator the router drains batches into.
    pub fn evaluator(&self) -> &Arc<dyn BatchEvaluator> {
        &self.evaluator
    }

    /// The underlying executor, when the model is exec-backed.
    pub fn executor(&self) -> Option<&Arc<dyn Executor>> {
        self.executor.as_ref()
    }

    /// The per-model engine tuning the entry was built with.
    pub fn exec_config(&self) -> Option<&ExecConfig> {
        self.exec_cfg.as_ref()
    }

    /// Per-shard health snapshot of the executor backing this model:
    /// a single always-ready entry for local engines, probed worker
    /// state for remote shards and replicas. Opaque evaluator backends
    /// report nothing. `Server::metrics_text` publishes these as
    /// `model.<name>.health[.<label>]` gauges.
    pub fn health_report(&self) -> Vec<(String, ExecHealth)> {
        self.executor.as_ref().map(|e| e.health_report()).unwrap_or_default()
    }

    /// Input dimension each request must provide (exec-backed models
    /// know it; opaque evaluators do not).
    pub fn input_dim(&self) -> Option<usize> {
        self.executor.as_ref().map(|e| e.num_inputs())
    }

    /// Preferred batch size (the router caps batches at the smaller of
    /// this and the server-wide `ServeConfig::max_batch`).
    pub fn max_batch(&self) -> usize {
        self.evaluator.max_batch().max(1)
    }

    /// In-flight requests currently admitted against this model
    /// (router submit → response sent).
    pub fn queued(&self) -> usize {
        self.queued.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Evaluate one batch on this model.
    pub fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.evaluator.eval_batch(xs)
    }

    /// Typed-error variant: the router dispatches through this so a
    /// dead remote shard ([`ExecError::Unavailable`]) sheds the batch
    /// instead of counting as a model failure.
    pub fn try_eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, ExecError> {
        self.evaluator.try_eval_batch(xs)
    }
}

impl std::fmt::Debug for ModelEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelEntry")
            .field("name", &self.name)
            .field("backend", &self.evaluator.name())
            .field("input_dim", &self.input_dim())
            .field("max_batch", &self.max_batch())
            .finish()
    }
}

/// Named model registry shared between the router and whoever manages
/// the deployment (CLI, tests, a future control plane). All methods take
/// `&self`; an `RwLock` keeps lookups on the submit path cheap.
///
/// ```
/// use lccnn::graph::{AdderGraph, Operand, OutputSpec};
/// use lccnn::serve::ModelRegistry;
///
/// let mut g = AdderGraph::new(2);
/// let n = g.push_add(Operand::input(0), Operand::input(1));
/// g.set_outputs(vec![OutputSpec::Ref(n)]);
/// let registry = ModelRegistry::new();
/// registry.register_graph("sum", &g, lccnn::config::ExecConfig::serial(), 16);
/// let entry = registry.get("sum").unwrap();
/// let y = entry.eval_batch(&[vec![1.0, 2.0]]).unwrap();
/// assert_eq!(y, vec![vec![3.0]]);
/// ```
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert an entry, returning (new, previous) under one lock
    /// acquisition — callers that need the freshly registered entry
    /// must not re-read the map (a concurrent remove/swap could land in
    /// between).
    fn insert(&self, entry: ModelEntry) -> (Arc<ModelEntry>, Option<Arc<ModelEntry>>) {
        let arc = Arc::new(entry);
        let prev = self.models.write().unwrap().insert(arc.name.clone(), Arc::clone(&arc));
        (arc, prev)
    }

    fn insert_executor(
        &self,
        name: &str,
        executor: Arc<dyn Executor>,
        exec_cfg: ExecConfig,
        max_batch: usize,
    ) -> (Arc<ModelEntry>, Option<Arc<ModelEntry>>) {
        let evaluator: Arc<dyn BatchEvaluator> =
            Arc::new(ExecutorBackend::new(Arc::clone(&executor), max_batch));
        self.insert(ModelEntry {
            name: name.to_string(),
            evaluator,
            executor: Some(executor),
            exec_cfg: Some(exec_cfg),
            queued: AtomicUsize::new(0),
        })
    }

    /// Register an executor under `name` (replacing — and returning —
    /// any previous model of that name: hot swap). `exec_cfg` records
    /// the tuning the engine was built with, for introspection.
    pub fn register(
        &self,
        name: &str,
        executor: Arc<dyn Executor>,
        exec_cfg: ExecConfig,
        max_batch: usize,
    ) -> Option<Arc<ModelEntry>> {
        self.insert_executor(name, executor, exec_cfg, max_batch).1
    }

    /// Lower an adder graph into an engine (sharing the process-wide
    /// worker pool) and register it. `exec_cfg.shards > 1` partitions
    /// the graph across an output-range [`crate::exec::ShardedExecutor`];
    /// otherwise a single [`crate::exec::BatchEngine`] serves it.
    pub fn register_graph(
        &self,
        name: &str,
        graph: &AdderGraph,
        exec_cfg: ExecConfig,
        max_batch: usize,
    ) -> Option<Arc<ModelEntry>> {
        let engine = crate::exec::engine_for_graph(graph, exec_cfg);
        self.register(name, engine, exec_cfg, max_batch)
    }

    /// Register an opaque batch evaluator (the single-model `Server`
    /// shim and non-exec backends such as the PJRT baseline use this).
    pub fn register_evaluator(
        &self,
        name: &str,
        evaluator: Arc<dyn BatchEvaluator>,
    ) -> Option<Arc<ModelEntry>> {
        self.insert(ModelEntry {
            name: name.to_string(),
            evaluator,
            executor: None,
            exec_cfg: None,
            queued: AtomicUsize::new(0),
        })
        .1
    }

    /// Load a weight matrix from `path` — either a single 2-D `.npy`
    /// file or a checkpoint directory holding one (a `weight.npy` entry,
    /// or the directory's only 2-D array) — run it through a compression
    /// recipe, and register the lowered [`crate::compress::PipelineExecutor`]
    /// under `name`. Served models are whatever the recipe says —
    /// pruned + shared + LCC'd, not LCC-only. This is the runtime
    /// model-loading path the `serve` CLI uses.
    ///
    /// `recipe = None` discovers the recipe: an artifact directory
    /// carrying a `recipe.toml` (what `lccnn compress --out` writes) is
    /// loaded through it; anything else gets the legacy LCC-only load
    /// with env-tuned engine settings.
    ///
    /// A directory carrying a `network.toml` manifest is a *multi-layer*
    /// checkpoint and dispatches to [`ModelRegistry::load_network`].
    pub fn load_checkpoint_with_recipe(
        &self,
        name: &str,
        path: &Path,
        recipe: Option<&Recipe>,
        max_batch: usize,
    ) -> Result<Arc<ModelEntry>> {
        if NetworkCheckpoint::is_network_dir(path) {
            return self.load_network(name, path, recipe, max_batch);
        }
        let w = load_weight_matrix(path)
            .with_context(|| format!("model {name:?} from {}", path.display()))?;
        let discovered;
        let recipe = match recipe {
            Some(r) => r,
            None => {
                discovered = Recipe::for_checkpoint(path)?;
                &discovered
            }
        };
        let model = Pipeline::from_recipe(recipe)?
            .run(&w)
            .with_context(|| format!("compressing model {name:?}"))?;
        let report = model.report();
        log::info!(
            "model {name:?}: {}x{} weight -> [{}] -> {} adds ({:.2}x, rel err {:.2e}, {} shard(s))",
            w.rows(),
            w.cols(),
            report.stages.iter().map(|s| s.stage.as_str()).collect::<Vec<_>>().join(" -> "),
            report.final_additions(),
            report.final_ratio(),
            report.final_rel_err(),
            model.shard_spec().map(|s| s.shards).unwrap_or(1),
        );
        let exec_cfg = recipe.exec;
        let executor: Arc<dyn Executor> = Arc::new(model.into_executor());
        // single insert, no re-read: a concurrent remove/swap between a
        // register and a lookup must not be able to panic this path
        Ok(self.insert_executor(name, executor, exec_cfg, max_batch).0)
    }

    /// Load a multi-layer network checkpoint directory (a `network.toml`
    /// manifest + `layer<k>.weight.npy` files), compress every layer
    /// through the recipe (per-layer `[compress.layer.<k>]` overrides
    /// apply), and register the chained
    /// [`crate::compress::NetworkExecutor`] under `name`. Per-layer
    /// timing/additions/bound telemetry surfaces through the entry's
    /// executor as `model.<name>.layer.<k>.*` gauges in
    /// `Server::metrics_text`.
    ///
    /// `recipe = None` discovers the recipe exactly like
    /// [`ModelRegistry::load_checkpoint_with_recipe`]: network artifact
    /// directories carrying a `recipe.toml` reproduce their exact build.
    pub fn load_network(
        &self,
        name: &str,
        path: &Path,
        recipe: Option<&Recipe>,
        max_batch: usize,
    ) -> Result<Arc<ModelEntry>> {
        let ckpt = NetworkCheckpoint::load(path)
            .with_context(|| format!("network model {name:?} from {}", path.display()))?;
        let discovered;
        let recipe = match recipe {
            Some(r) => r,
            None => {
                discovered = Recipe::for_checkpoint(path)?;
                &discovered
            }
        };
        let net = NetworkPipeline::from_recipe(recipe)?
            .run(&ckpt)
            .with_context(|| format!("compressing network model {name:?}"))?;
        let report = net.report();
        log::info!(
            "model {name:?}: {} layers ({} -> {} dims) -> {} adds ({:.2}x, max rel err {:.2e})",
            report.num_layers(),
            ckpt.input_dim(),
            ckpt.output_dim(),
            report.total_additions(),
            report.total_ratio(),
            report.max_rel_err(),
        );
        let exec_cfg = recipe.exec;
        let executor: Arc<dyn Executor> = Arc::new(net.into_executor()?);
        Ok(self.insert_executor(name, executor, exec_cfg, max_batch).0)
    }

    /// Connect to remote `shard-worker` addresses, gather them behind
    /// one [`crate::exec::ShardedExecutor`] and register it under
    /// `name`. Addresses reporting the same output range (or listed as
    /// `host:port|host:port`) become replicas with in-order failover.
    /// The entry serves like any local model; a dead shard sheds its
    /// batches with typed errors instead of hanging them, counted on
    /// `metrics` (`shard.<i>.dead` / `shard.<i>.retries` /
    /// `shard.<i>.recovered` / `shard.<i>.failover`).
    pub fn register_remote_sharded(
        &self,
        name: &str,
        addrs: &[String],
        opts: RemoteOptions,
        exec_cfg: ExecConfig,
        metrics: Arc<Metrics>,
        max_batch: usize,
    ) -> Result<Arc<ModelEntry>> {
        let sharded = crate::exec::remote_sharded_executor(addrs, opts, exec_cfg, metrics)
            .with_context(|| format!("remote model {name:?}"))?;
        log::info!(
            "model {name:?}: {} remote shard(s) [{}], {} inputs -> {} outputs",
            sharded.num_shards(),
            addrs.join(", "),
            crate::exec::Executor::num_inputs(&sharded),
            crate::exec::Executor::num_outputs(&sharded),
        );
        let executor: Arc<dyn Executor> = Arc::new(sharded);
        Ok(self.insert_executor(name, executor, exec_cfg, max_batch).0)
    }

    /// Remove (and return) a model. In-flight requests that already
    /// resolved their entry keep executing on it; only new submits see
    /// the removal.
    pub fn remove(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.write().unwrap().remove(name)
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.models.read().unwrap().contains_key(name)
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRegistry").field("models", &self.names()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Operand, OutputSpec};
    use crate::lcc::LccConfig;
    use crate::nn::npy::NpyArray;
    use crate::nn::ParamStore;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn sum_graph(inputs: usize) -> AdderGraph {
        let mut g = AdderGraph::new(inputs);
        let root = g.push_sum((0..inputs).map(Operand::input).collect()).unwrap();
        g.set_outputs(vec![OutputSpec::Ref(root)]);
        g
    }

    #[test]
    fn register_get_remove_roundtrip() {
        let r = ModelRegistry::new();
        assert!(r.is_empty());
        r.register_graph("a", &sum_graph(3), ExecConfig::serial(), 8);
        r.register_graph("b", &sum_graph(2), ExecConfig::serial(), 8);
        assert_eq!(r.len(), 2);
        assert_eq!(r.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(r.contains("a") && !r.contains("c"));
        let a = r.get("a").unwrap();
        assert_eq!(a.input_dim(), Some(3));
        assert_eq!(a.name(), "a");
        assert_eq!(a.exec_config().unwrap().threads, 1);
        let removed = r.remove("a").unwrap();
        assert!(Arc::ptr_eq(&removed, &a));
        assert!(r.get("a").is_none());
        assert_eq!(r.len(), 1);
        // the removed entry still executes (in-flight safety)
        assert_eq!(removed.eval_batch(&[vec![1.0, 2.0, 3.0]]).unwrap(), vec![vec![6.0]]);
    }

    #[test]
    fn register_replaces_and_returns_previous() {
        let r = ModelRegistry::new();
        assert!(r.register_graph("m", &sum_graph(2), ExecConfig::serial(), 8).is_none());
        let old = r.get("m").unwrap();
        let prev = r.register_graph("m", &sum_graph(4), ExecConfig::serial(), 8).unwrap();
        assert!(Arc::ptr_eq(&prev, &old));
        assert_eq!(r.get("m").unwrap().input_dim(), Some(4));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn register_graph_shards_when_configured() {
        // several outputs so sharding actually engages
        let mut g = AdderGraph::new(4);
        let a = g.push_add(Operand::input(0), Operand::input(1));
        let b = g.push_add(Operand::input(2), Operand::input(3));
        let c = g.push_add(a, b);
        g.set_outputs(vec![OutputSpec::Ref(a), OutputSpec::Ref(b), OutputSpec::Ref(c)]);
        let r = ModelRegistry::new();
        r.register_graph("plain", &g, ExecConfig::serial(), 8);
        r.register_graph("sharded", &g, ExecConfig { shards: 2, ..ExecConfig::serial() }, 8);
        let plain = r.get("plain").unwrap();
        let sharded = r.get("sharded").unwrap();
        assert_eq!(plain.executor().unwrap().name(), "batch-engine");
        assert_eq!(sharded.executor().unwrap().name(), "sharded-exec");
        assert_eq!(sharded.input_dim(), Some(4));
        let xs = vec![vec![1.0, 2.0, 3.0, 4.0], vec![-1.0, 0.5, 2.0, -3.0]];
        assert_eq!(
            plain.eval_batch(&xs).unwrap(),
            sharded.eval_batch(&xs).unwrap(),
            "sharded registration serves bit-identically"
        );
    }

    #[test]
    fn entry_validates_arity_for_exec_models() {
        let r = ModelRegistry::new();
        r.register_graph("m", &sum_graph(3), ExecConfig::serial(), 8);
        let e = r.get("m").unwrap();
        assert!(e.eval_batch(&[vec![1.0]]).is_err(), "wrong arity must error, not panic");
    }

    fn lcc_serial() -> Recipe {
        Recipe::lcc_only(&LccConfig::fs(), ExecConfig::serial())
    }

    #[test]
    fn load_checkpoint_from_npy_and_dir() {
        let mut rng = Rng::new(11);
        let w = Matrix::randn(32, 8, 0.5, &mut rng);
        let dir = std::env::temp_dir().join(format!("lccnn-reg-ckpt-{}", std::process::id()));
        let mut store = ParamStore::new();
        store.insert("weight", NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()));
        store.save(&dir).unwrap();

        let r = ModelRegistry::new();
        // from the directory
        let e = r.load_checkpoint_with_recipe("ckpt", &dir, Some(&lcc_serial()), 16).unwrap();
        assert_eq!(e.input_dim(), Some(8));
        // from the bare .npy file
        let e2 = r
            .load_checkpoint_with_recipe(
                "ckpt-file",
                &dir.join("weight.npy"),
                Some(&lcc_serial()),
                16,
            )
            .unwrap();
        assert_eq!(e2.input_dim(), Some(8));

        // the served model approximates W x at LCC fidelity
        let x: Vec<f32> = rng.normal_vec(8, 1.0);
        let want = w.matvec(&x);
        let got = e.eval_batch(&[x.clone()]).unwrap().pop().unwrap();
        let num: f64 = want.iter().zip(&got).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = want.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(num / den.max(1e-12) < 1e-2, "rel err {}", num / den);
        // both registrations lower the same matrix: identical programs
        let got2 = e2.eval_batch(&[x]).unwrap().pop().unwrap();
        assert_eq!(got, got2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_checkpoint_rejects_bad_shapes() {
        let dir = std::env::temp_dir().join(format!("lccnn-reg-bad-{}", std::process::id()));
        let mut store = ParamStore::new();
        store.insert("weight", NpyArray::f32(vec![4], vec![0.0; 4]));
        store.save(&dir).unwrap();
        let r = ModelRegistry::new();
        assert!(r.load_checkpoint_with_recipe("bad", &dir, Some(&lcc_serial()), 8).is_err());
        assert!(!r.contains("bad"));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A checkpoint directory carrying a `network.toml` manifest
    /// dispatches to the network path and serves bit-identically to the
    /// directly built chained executor (and its hand-chained oracle).
    #[test]
    fn network_dir_auto_detected_and_served() {
        let ckpt = crate::compress::demo_network(&[10, 8, 4], 41);
        let dir = std::env::temp_dir().join(format!("lccnn-reg-net-{}", std::process::id()));
        ckpt.save(&dir).unwrap();
        let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
        recipe.save(&dir.join("recipe.toml")).unwrap();

        let r = ModelRegistry::new();
        // the generic load path dispatches on the manifest
        let e = r.load_checkpoint_with_recipe("net", &dir, None, 16).unwrap();
        assert_eq!(e.input_dim(), Some(10));
        assert_eq!(e.executor().unwrap().name(), "network-exec");
        assert_eq!(e.executor().unwrap().layer_stats().len(), 2);

        let direct = NetworkPipeline::from_recipe(&recipe).unwrap().run(&ckpt).unwrap();
        let mut rng = Rng::new(42);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(10, 1.0)).collect();
        let want = direct.executor().unwrap().execute_batch(&xs);
        assert_eq!(e.eval_batch(&xs).unwrap(), want);
        assert_eq!(want, direct.oracle_forward_batch(&xs), "serving matches the chained oracle");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// An artifact directory with a `recipe.toml` is loaded through it:
    /// the served model is pruned+shared+LCC'd, not LCC-only.
    #[test]
    fn artifact_dir_recipe_discovered_and_applied() {
        let w = crate::compress::demo_weights(16, 3, 4, 31);
        let dir = std::env::temp_dir().join(format!("lccnn-reg-artifact-{}", std::process::id()));
        let mut store = ParamStore::new();
        store.insert("weight", NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()));
        store.save(&dir).unwrap();
        let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
        recipe.save(&dir.join("recipe.toml")).unwrap();

        let r = ModelRegistry::new();
        let e = r.load_checkpoint_with_recipe("art", &dir, None, 16).unwrap();
        // requests still carry the original (pre-prune) input dimension
        assert_eq!(e.input_dim(), Some(w.cols()));
        // bit-identical to running the same recipe directly
        let direct = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
        let exec = direct.executor();
        let mut rng = Rng::new(32);
        let xs: Vec<Vec<f32>> = (0..7).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        assert_eq!(e.eval_batch(&xs).unwrap(), crate::exec::Executor::execute_batch(&exec, &xs));
        std::fs::remove_dir_all(&dir).ok();
    }
}
