//! Inference backends for the serving layer. Every adder-graph path
//! funnels through the unified [`crate::exec`] engine: the compressed
//! model backend batches whole requests through
//! [`CompressedMlp::forward_batch`], and [`ExecutorBackend`] serves any
//! [`Executor`] (raw graph serving, future sharded/multi-backend
//! engines) directly. Engines dispatch parallel work on the process-wide
//! persistent worker pool (`crate::exec::global_pool`) unless built with
//! an engine-private one — so a server hosting many models shares one
//! set of hot worker threads instead of spawning per batch.

use crate::exec::{ExecError, Executor};
use crate::nn::compressed::CompressedMlp;
use crate::nn::mlp::INPUT;
use crate::runtime::{HostTensor, PjrtService};
use anyhow::Result;
use std::sync::Arc;

/// Evaluates one batch of flattened inputs to one output vector each.
pub trait BatchEvaluator: Send + Sync {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Typed variant the router dispatches through: an
    /// [`ExecError::Unavailable`] (dead remote shard) sheds the batch
    /// with `ServeError::Shed` semantics instead of failing the model.
    /// The default wraps [`BatchEvaluator::eval_batch`], mapping any
    /// error to [`ExecError::Failed`] — backends over an [`Executor`]
    /// override it to preserve the distinction.
    fn try_eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, ExecError> {
        self.eval_batch(xs).map_err(|e| ExecError::Failed { message: format!("{e:#}") })
    }

    /// Preferred batch size (the batcher aims for it; backends must
    /// accept anything from 1 up to this).
    fn max_batch(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// The compressed model on the unified execution engine (the "FPGA"
/// path): the batcher's whole batch is evaluated batch-major instead of
/// sample by sample.
pub struct CompressedMlpBackend {
    pub model: Arc<CompressedMlp>,
}

impl BatchEvaluator for CompressedMlpBackend {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.model.forward_batch(xs))
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn name(&self) -> &'static str {
        "compressed-exec"
    }
}

/// Serve a bare adder-graph executor: requests are the graph inputs,
/// responses its outputs. The extension point for serving future
/// [`Executor`] implementations without a model wrapper.
pub struct ExecutorBackend {
    exec: Arc<dyn Executor>,
    max_batch: usize,
}

impl ExecutorBackend {
    pub fn new(exec: Arc<dyn Executor>, max_batch: usize) -> Self {
        ExecutorBackend { exec, max_batch: max_batch.max(1) }
    }
}

impl BatchEvaluator for ExecutorBackend {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.try_eval_batch(xs).map_err(anyhow::Error::from)
    }

    fn try_eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, ExecError> {
        for (i, x) in xs.iter().enumerate() {
            if x.len() != self.exec.num_inputs() {
                let message = format!(
                    "request {i}: {} inputs, executor wants {}",
                    x.len(),
                    self.exec.num_inputs()
                );
                return Err(ExecError::Failed { message });
            }
        }
        let mut ys = Vec::new();
        self.exec.try_execute_batch_into(xs, &mut ys)?;
        Ok(ys)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn name(&self) -> &'static str {
        "adder-exec"
    }
}

/// The dense model through the PJRT `mlp_fwd` artifact, via the
/// thread-confined [`PjrtService`] (the xla handles are !Send). Partial
/// batches are zero-padded to the artifact's fixed batch and the padding
/// discarded.
pub struct PjrtMlpBackend {
    service: Arc<PjrtService>,
    params: Vec<HostTensor>,
    batch: usize,
}

impl PjrtMlpBackend {
    /// `params` = [W1, b1, W2, b2]; `batch` must match the lowered
    /// `mlp_fwd` batch dimension (32 in the default manifest).
    pub fn new(service: Arc<PjrtService>, params: Vec<HostTensor>, batch: usize) -> Self {
        PjrtMlpBackend { service, params, batch }
    }
}

impl BatchEvaluator for PjrtMlpBackend {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch) {
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::from_rows_padded(chunk, self.batch, INPUT)?);
            let outs = self.service.call("mlp_fwd", inputs)?;
            out.extend(outs[0].to_rows_first(chunk.len())?);
        }
        Ok(out)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &'static str {
        "dense-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::BatchEngine;
    use crate::graph::{AdderGraph, Operand, OutputSpec};
    use crate::nn::compressed::Layer1;
    use crate::tensor::Matrix;

    fn tiny_model() -> CompressedMlp {
        CompressedMlp {
            kept: vec![0, 1],
            layer1: Layer1::Dense(Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]])),
            b1: vec![0.0, 0.0],
            w2: Matrix::from_rows(&[&[1.0, 1.0]]),
            b2: vec![0.0],
        }
    }

    #[test]
    fn compressed_backend_batches() {
        let be = CompressedMlpBackend { model: Arc::new(tiny_model()) };
        let xs = vec![vec![1.0, 2.0], vec![3.0, -4.0]];
        let ys = be.eval_batch(&xs).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0], vec![3.0]); // relu(1)+relu(2)
        assert_eq!(ys[1], vec![3.0]); // relu(3)+relu(-4)=3
    }

    #[test]
    fn executor_backend_serves_raw_graphs() {
        let mut g = AdderGraph::new(2);
        let n = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        g.set_outputs(vec![OutputSpec::Ref(n)]);
        let be = ExecutorBackend::new(Arc::new(BatchEngine::new(&g)), 16);
        let ys = be.eval_batch(&[vec![1.0, 2.0], vec![3.0, 0.5]]).unwrap();
        assert_eq!(ys, vec![vec![5.0], vec![4.0]]);
        assert!(be.eval_batch(&[vec![1.0]]).is_err(), "arity must be validated");
        assert_eq!(be.name(), "adder-exec");
    }

    #[test]
    fn executor_backend_dispatches_on_the_worker_pool() {
        use crate::config::{ExecConfig, PoolMode};
        use crate::exec::WorkerPool;
        let mut g = AdderGraph::new(2);
        let n = g.push_add(Operand::input(0), Operand::input(1).scaled(1, false));
        g.set_outputs(vec![OutputSpec::Ref(n)]);
        let pool = Arc::new(WorkerPool::new(2, 0, 20));
        let cfg = ExecConfig {
            threads: 2,
            chunk: 1,
            parallel_min_batch: 2,
            pool_mode: PoolMode::Persistent,
            ..ExecConfig::default()
        };
        let be = ExecutorBackend::new(
            Arc::new(BatchEngine::with_workers(&g, cfg, Arc::clone(&pool))),
            16,
        );
        let ys = be.eval_batch(&[vec![1.0, 2.0], vec![3.0, 0.5]]).unwrap();
        assert_eq!(ys, vec![vec![5.0], vec![4.0]]);
        assert!(pool.stats().tasks_run > 0, "batch must have run on the pool");
    }
}
