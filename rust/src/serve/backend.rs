//! Inference backends for the serving layer.

use crate::nn::compressed::CompressedMlp;
use crate::nn::mlp::{INPUT, OUTPUT};
use crate::runtime::{HostTensor, PjrtService};
use anyhow::Result;
use std::sync::Arc;

/// Evaluates one batch of flattened inputs to one output vector each.
pub trait BatchEvaluator: Send + Sync {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// Preferred batch size (the batcher aims for it; backends must
    /// accept anything from 1 up to this).
    fn max_batch(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// The compressed model on the shift-add VM (the "FPGA" path).
pub struct CompressedMlpBackend {
    pub model: Arc<CompressedMlp>,
}

impl BatchEvaluator for CompressedMlpBackend {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(xs.iter().map(|x| self.model.forward_one(x)).collect())
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn name(&self) -> &'static str {
        "compressed-vm"
    }
}

/// The dense model through the PJRT `mlp_fwd` artifact, via the
/// thread-confined [`PjrtService`] (the xla handles are !Send). Partial
/// batches are zero-padded to the artifact's fixed batch and the padding
/// discarded.
pub struct PjrtMlpBackend {
    service: Arc<PjrtService>,
    params: Vec<HostTensor>,
    batch: usize,
}

impl PjrtMlpBackend {
    /// `params` = [W1, b1, W2, b2]; `batch` must match the lowered
    /// `mlp_fwd` batch dimension (32 in the default manifest).
    pub fn new(service: Arc<PjrtService>, params: Vec<HostTensor>, batch: usize) -> Self {
        PjrtMlpBackend { service, params, batch }
    }
}

impl BatchEvaluator for PjrtMlpBackend {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch) {
            let mut flat = vec![0.0f32; self.batch * INPUT];
            for (i, x) in chunk.iter().enumerate() {
                flat[i * INPUT..(i + 1) * INPUT].copy_from_slice(x);
            }
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::F32(vec![self.batch, INPUT], flat));
            let outs = self.service.call("mlp_fwd", inputs)?;
            let logits = outs[0].as_f32()?;
            for i in 0..chunk.len() {
                out.push(logits[i * OUTPUT..(i + 1) * OUTPUT].to_vec());
            }
        }
        Ok(out)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &'static str {
        "dense-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::compressed::Layer1;
    use crate::tensor::Matrix;

    fn tiny_model() -> CompressedMlp {
        CompressedMlp {
            kept: vec![0, 1],
            layer1: Layer1::Dense(Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]])),
            b1: vec![0.0, 0.0],
            w2: Matrix::from_rows(&[&[1.0, 1.0]]),
            b2: vec![0.0],
        }
    }

    #[test]
    fn compressed_backend_batches() {
        let be = CompressedMlpBackend { model: Arc::new(tiny_model()) };
        let xs = vec![vec![1.0, 2.0], vec![3.0, -4.0]];
        let ys = be.eval_batch(&xs).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0], vec![3.0]); // relu(1)+relu(2)
        assert_eq!(ys[1], vec![3.0]); // relu(3)+relu(-4)=3
    }
}
