//! Dynamic-batching request server.

use super::backend::BatchEvaluator;
use crate::config::ServeConfig;
use crate::metrics::Metrics;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>, String>>,
}

/// Snapshot of serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
}

/// In-process inference server: submit() from any thread; a batcher
/// thread groups requests (up to max_batch, waiting at most
/// batch_timeout) and runs them on the backend.
pub struct Server {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    /// exec worker pool whose stats `metrics_text` publishes — the
    /// process-wide one unless the backend's engine was built with a
    /// private pool (see [`Server::with_pool_metrics`])
    exec_pool: Arc<crate::exec::WorkerPool>,
}

impl Server {
    pub fn start(backend: Arc<dyn BatchEvaluator>, cfg: ServeConfig) -> Self {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let m = Arc::clone(&metrics);
        let max_batch = cfg.max_batch.min(backend.max_batch()).max(1);
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let worker = std::thread::Builder::new()
            .name("lccnn-serve-batcher".into())
            .spawn(move || batcher_loop(rx, backend, max_batch, timeout, m))
            .expect("spawn batcher");
        Server {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            exec_pool: crate::exec::global_pool(),
        }
    }

    /// Report `pool`'s stats from [`Server::metrics_text`] instead of the
    /// process-wide pool — for backends whose engine was built with an
    /// engine-private pool (`BatchEngine::with_workers`), so the metrics
    /// reflect the pool actually dispatching this server's batches.
    pub fn with_pool_metrics(mut self, pool: Arc<crate::exec::WorkerPool>) -> Self {
        self.exec_pool = pool;
        self
    }

    /// Submit one request; returns a receiver for the response.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<Result<Vec<f32>, String>> {
        let (resp_tx, resp_rx) = channel();
        let req = Request { x, enqueued: Instant::now(), resp: resp_tx };
        self.tx.as_ref().expect("server alive").send(req).expect("batcher alive");
        resp_rx
    }

    /// Blocking convenience call.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>, String> {
        self.submit(x).recv().map_err(|e| e.to_string())?
    }

    pub fn stats(&self) -> ServerStats {
        let (n, mean, _, _) = self.metrics.summary("batch_size").unwrap_or((0, 0.0, 0.0, 0.0));
        let (_, _, p50, p99) = self.metrics.summary("latency_us").unwrap_or((0, 0.0, 0.0, 0.0));
        ServerStats {
            requests: self.metrics.counter("requests"),
            batches: n as u64,
            mean_batch_size: mean,
            p50_latency_us: p50,
            p99_latency_us: p99,
        }
    }

    /// Render the server's metrics registry as text, refreshed with the
    /// exec worker pool's counters (`exec_pool.*`; the process-wide pool
    /// unless overridden via [`Server::with_pool_metrics`]) — one blob
    /// for logs and debugging. Exec-backed backends dispatch their
    /// parallel work on that pool, so its task/busy counters belong next
    /// to the serving latency histograms.
    pub fn metrics_text(&self) -> String {
        self.exec_pool.publish(&self.metrics);
        self.metrics.render()
    }

    /// Stop the batcher and join (drains the queue first).
    pub fn shutdown(mut self) -> ServerStats {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    backend: Arc<dyn BatchEvaluator>,
    max_batch: usize,
    timeout: Duration,
    metrics: Arc<Metrics>,
) {
    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.incr("requests", batch.len() as u64);
        metrics.observe("batch_size", batch.len() as f64);
        let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
        match backend.eval_batch(&xs) {
            Ok(ys) => {
                for (req, y) in batch.into_iter().zip(ys) {
                    metrics.observe(
                        "latency_us",
                        req.enqueued.elapsed().as_secs_f64() * 1e6,
                    );
                    let _ = req.resp.send(Ok(y));
                }
            }
            Err(e) => {
                let msg = format!("backend error: {e:#}");
                metrics.incr("errors", 1);
                for req in batch {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// A Mutex-wrapped evaluator adapter for backends that need &mut access.
pub struct MutexEvaluator<F> {
    inner: Mutex<F>,
    max_batch: usize,
    name: &'static str,
}

impl<F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>> + Send> MutexEvaluator<F> {
    pub fn new(f: F, max_batch: usize, name: &'static str) -> Self {
        MutexEvaluator { inner: Mutex::new(f), max_batch, name }
    }
}

impl<F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>> + Send> BatchEvaluator for MutexEvaluator<F> {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        (self.inner.lock().unwrap())(xs)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    fn echo_backend() -> Arc<dyn BatchEvaluator> {
        Arc::new(MutexEvaluator::new(
            |xs: &[Vec<f32>]| Ok(xs.iter().map(|x| vec![x.iter().sum()]).collect()),
            8,
            "echo",
        ))
    }

    #[test]
    fn serves_requests() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let y = server.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(y, vec![6.0]);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let cfg = ServeConfig { max_batch: 16, batch_timeout_us: 20_000, ..Default::default() };
        let server = Arc::new(Server::start(echo_backend(), cfg));
        let receivers: Vec<_> = (0..12)
            .map(|i| server.submit(vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 12);
        assert!(stats.mean_batch_size > 1.0, "no batching happened: {stats:?}");
    }

    #[test]
    fn errors_propagate() {
        let failing: Arc<dyn BatchEvaluator> = Arc::new(MutexEvaluator::new(
            |_: &[Vec<f32>]| anyhow::bail!("boom"),
            4,
            "fail",
        ));
        let server = Server::start(failing, ServeConfig::default());
        let err = server.infer(vec![1.0]).unwrap_err();
        assert!(err.contains("boom"));
    }

    #[test]
    fn shutdown_joins() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let _ = server.infer(vec![1.0]);
        let stats = server.shutdown(); // must not hang
        assert!(stats.requests >= 1);
    }

    #[test]
    fn metrics_text_includes_exec_pool_stats() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let _ = server.infer(vec![1.0]);
        let text = server.metrics_text();
        assert!(text.contains("requests"), "{text}");
        assert!(text.contains("exec_pool.workers"), "{text}");
        assert!(text.contains("exec_pool.tasks_run"), "{text}");
    }

    #[test]
    fn metrics_text_can_track_a_private_pool() {
        let pool = Arc::new(crate::exec::WorkerPool::new(2, 0, 20));
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for _ in 0..3 {
                tasks.push(Box::new(|| {}));
            }
            pool.run_scoped(tasks).unwrap();
        }
        let server = Server::start(echo_backend(), ServeConfig::default())
            .with_pool_metrics(Arc::clone(&pool));
        let _ = server.infer(vec![1.0]);
        let text = server.metrics_text();
        assert!(text.contains("exec_pool.tasks_run = 3"), "{text}");
    }
}
