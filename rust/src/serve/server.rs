//! The serving front end: a [`ModelRegistry`] plus a [`Router`] behind
//! one handle. Multi-model serving is the native shape —
//! [`Server::start_registry`] — and the historical single-model API
//! ([`Server::start`]) is a thin shim that registers its backend as the
//! [`DEFAULT_MODEL`] and routes to it.

use super::backend::BatchEvaluator;
use super::registry::ModelRegistry;
use super::router::{Response, Router, ServeError};
use crate::config::ServeConfig;
use crate::metrics::Metrics;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};

/// The model name the single-model shim registers its backend under.
pub const DEFAULT_MODEL: &str = "default";

/// Snapshot of serving statistics (global, or per model via
/// [`Server::model_stats`]).
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
}

/// In-process inference server over a model registry: `submit_to(model,
/// x)` from any thread; the router thread batches per model with fair
/// round-robin draining and runs each batch on that model's engine.
/// Models can be added/removed from [`Server::registry`] while serving.
pub struct Server {
    registry: Arc<ModelRegistry>,
    router: Router,
    metrics: Arc<Metrics>,
    /// exec worker pool whose stats `metrics_text` publishes — the
    /// process-wide one unless overridden (see [`Server::with_pool_metrics`])
    exec_pool: Arc<crate::exec::WorkerPool>,
}

impl Server {
    /// Single-model shim: registers `backend` as [`DEFAULT_MODEL`] in a
    /// fresh registry and serves it. [`Server::submit`]/[`Server::infer`]
    /// route to that model.
    pub fn start(backend: Arc<dyn BatchEvaluator>, cfg: ServeConfig) -> Self {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_evaluator(DEFAULT_MODEL, backend);
        Self::start_registry(registry, cfg)
    }

    /// Serve every model in `registry` (hot add/remove supported while
    /// running).
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let router = Router::start(&cfg, Arc::clone(&metrics));
        Server { registry, router, metrics, exec_pool: crate::exec::global_pool() }
    }

    /// Report `pool`'s stats from [`Server::metrics_text`] instead of the
    /// process-wide pool — for deployments whose engines were built with
    /// a private pool (`BatchEngine::with_workers`), so the metrics
    /// reflect the pool actually dispatching this server's batches.
    pub fn with_pool_metrics(mut self, pool: Arc<crate::exec::WorkerPool>) -> Self {
        self.exec_pool = pool;
        self
    }

    /// The registry this server routes over — hot add/remove models here.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Submit one request to a named model; returns a receiver for the
    /// response. An unknown model yields an immediate typed `Err`
    /// response (never a panic or a hang): submits race hot removal by
    /// design. A model at its `queue_capacity` sheds with
    /// [`ServeError::Shed`].
    pub fn submit_to(&self, model: &str, x: Vec<f32>) -> Receiver<Response> {
        match self.registry.get(model) {
            Some(entry) => self.router.submit(entry, x),
            None => {
                self.metrics.incr("rejected", 1);
                let (tx, rx) = channel();
                let _ = tx.send(Err(ServeError::UnknownModel { model: model.to_string() }));
                rx
            }
        }
    }

    /// Submit one request to the [`DEFAULT_MODEL`] (single-model shim).
    pub fn submit(&self, x: Vec<f32>) -> Receiver<Response> {
        self.submit_to(DEFAULT_MODEL, x)
    }

    /// Blocking convenience call against a named model (errors rendered
    /// to `String`; use [`Server::submit_to`] for the typed
    /// [`ServeError`]).
    pub fn infer_model(&self, model: &str, x: Vec<f32>) -> Result<Vec<f32>, String> {
        match self.submit_to(model, x).recv() {
            Ok(resp) => resp.map_err(|e| e.to_string()),
            Err(_) => Err(ServeError::Disconnected.to_string()),
        }
    }

    /// Blocking convenience call (single-model shim).
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>, String> {
        self.infer_model(DEFAULT_MODEL, x)
    }

    fn stats_from(&self, counter_prefix: &str) -> ServerStats {
        let (n, mean, _, _) = self
            .metrics
            .summary(&format!("{counter_prefix}batch_size"))
            .unwrap_or((0, 0.0, 0.0, 0.0));
        let (_, _, p50, p99) = self
            .metrics
            .summary(&format!("{counter_prefix}latency_us"))
            .unwrap_or((0, 0.0, 0.0, 0.0));
        ServerStats {
            requests: self.metrics.counter(&format!("{counter_prefix}requests")),
            batches: n as u64,
            mean_batch_size: mean,
            p50_latency_us: p50,
            p99_latency_us: p99,
        }
    }

    /// Aggregate statistics across every model.
    pub fn stats(&self) -> ServerStats {
        self.stats_from("")
    }

    /// Statistics for one model (zeros if it never served a request).
    pub fn model_stats(&self, model: &str) -> ServerStats {
        self.stats_from(&format!("model.{model}."))
    }

    /// Names of every model that has served at least one request in
    /// this server's lifetime — including models since hot-removed from
    /// the registry (their counters remain), which
    /// [`ModelRegistry::names`] no longer lists.
    pub fn models_seen(&self) -> Vec<String> {
        self.metrics
            .counters_with_prefix("model.")
            .into_iter()
            .filter_map(|(k, _)| {
                k.strip_prefix("model.")?.strip_suffix(".requests").map(str::to_string)
            })
            .collect()
    }

    /// The server's metrics registry (global `requests`/`batch_size`/
    /// `latency_us`/`errors` plus per-model `model.<name>.*` series).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Render the server's metrics registry as text — global and
    /// per-model serving series, the current model count
    /// (`serve.models`), per-shard health gauges
    /// (`model.<name>.health[.<label>]`: `1` ready, `0.5` draining,
    /// `0` dead, `-1` unknown), per-layer gauges for chained network
    /// executors (`model.<name>.layer.<k>.batch_us` mean layer-step
    /// time, `.additions` when the layer has a lowered program, and
    /// `.err_bound`), and the exec worker pool's counters
    /// (`exec_pool.*`; the process-wide pool unless overridden via
    /// [`Server::with_pool_metrics`]) — one blob for logs and debugging.
    pub fn metrics_text(&self) -> String {
        self.metrics.gauge("serve.models", self.registry.len() as f64);
        for name in self.registry.names() {
            let Some(entry) = self.registry.get(&name) else { continue };
            for (label, h) in entry.health_report() {
                let key = if label.is_empty() {
                    format!("model.{name}.health")
                } else {
                    format!("model.{name}.health.{label}")
                };
                self.metrics.gauge(&key, h.as_gauge());
            }
            if let Some(exec) = entry.executor() {
                for s in exec.layer_stats() {
                    let p = format!("model.{name}.layer.{}", s.index);
                    self.metrics.gauge(&format!("{p}.batch_us"), s.mean_batch_us());
                    if let Some(adds) = s.additions {
                        self.metrics.gauge(&format!("{p}.additions"), adds as f64);
                    }
                    self.metrics.gauge(&format!("{p}.err_bound"), s.err_bound);
                }
            }
        }
        self.exec_pool.publish(&self.metrics);
        self.metrics.render()
    }

    /// Stop the router and join (drains every model's queue first).
    pub fn shutdown(mut self) -> ServerStats {
        self.router.shutdown();
        self.stats()
    }
}

/// A Mutex-wrapped evaluator adapter for backends that need &mut access.
pub struct MutexEvaluator<F> {
    inner: Mutex<F>,
    max_batch: usize,
    name: &'static str,
}

impl<F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>> + Send> MutexEvaluator<F> {
    pub fn new(f: F, max_batch: usize, name: &'static str) -> Self {
        MutexEvaluator { inner: Mutex::new(f), max_batch, name }
    }
}

impl<F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>> + Send> BatchEvaluator for MutexEvaluator<F> {
    fn eval_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        (self.inner.lock().unwrap())(xs)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecConfig, ServeConfig};
    use crate::graph::{AdderGraph, Operand, OutputSpec};

    fn echo_backend() -> Arc<dyn BatchEvaluator> {
        Arc::new(MutexEvaluator::new(
            |xs: &[Vec<f32>]| Ok(xs.iter().map(|x| vec![x.iter().sum()]).collect()),
            8,
            "echo",
        ))
    }

    fn scale_graph(shift: i32) -> AdderGraph {
        let mut g = AdderGraph::new(2);
        let n = g.push_add(Operand::input(0), Operand::input(1));
        g.set_outputs(vec![OutputSpec::Ref(n.scaled(shift, false))]);
        g
    }

    #[test]
    fn serves_requests() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let y = server.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(y, vec![6.0]);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let cfg = ServeConfig { max_batch: 16, batch_timeout_us: 20_000, ..Default::default() };
        let server = Arc::new(Server::start(echo_backend(), cfg));
        let receivers: Vec<_> = (0..12)
            .map(|i| server.submit(vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 12);
        assert!(stats.mean_batch_size > 1.0, "no batching happened: {stats:?}");
    }

    #[test]
    fn errors_propagate() {
        let failing: Arc<dyn BatchEvaluator> = Arc::new(MutexEvaluator::new(
            |_: &[Vec<f32>]| anyhow::bail!("boom"),
            4,
            "fail",
        ));
        let server = Server::start(failing, ServeConfig::default());
        let err = server.infer(vec![1.0]).unwrap_err();
        assert!(err.contains("boom"));
    }

    #[test]
    fn shutdown_joins() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let _ = server.infer(vec![1.0]);
        let stats = server.shutdown(); // must not hang
        assert!(stats.requests >= 1);
    }

    #[test]
    fn metrics_text_includes_exec_pool_stats() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let _ = server.infer(vec![1.0]);
        let text = server.metrics_text();
        assert!(text.contains("requests"), "{text}");
        assert!(text.contains("exec_pool.workers"), "{text}");
        assert!(text.contains("exec_pool.tasks_run"), "{text}");
    }

    #[test]
    fn metrics_text_can_track_a_private_pool() {
        let pool = Arc::new(crate::exec::WorkerPool::new(2, 0, 20));
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for _ in 0..3 {
                tasks.push(Box::new(|| {}));
            }
            pool.run_scoped(tasks).unwrap();
        }
        let server = Server::start(echo_backend(), ServeConfig::default())
            .with_pool_metrics(Arc::clone(&pool));
        let _ = server.infer(vec![1.0]);
        let text = server.metrics_text();
        assert!(text.contains("exec_pool.tasks_run = 3"), "{text}");
    }

    #[test]
    fn multi_model_routing_and_per_model_stats() {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_graph("x1", &scale_graph(0), ExecConfig::serial(), 8);
        registry.register_graph("x4", &scale_graph(2), ExecConfig::serial(), 8);
        let server = Server::start_registry(Arc::clone(&registry), ServeConfig::default());
        assert_eq!(server.infer_model("x1", vec![1.0, 2.0]).unwrap(), vec![3.0]);
        assert_eq!(server.infer_model("x4", vec![1.0, 2.0]).unwrap(), vec![12.0]);
        assert_eq!(server.infer_model("x4", vec![2.0, 2.0]).unwrap(), vec![16.0]);
        assert_eq!(server.model_stats("x1").requests, 1);
        assert_eq!(server.model_stats("x4").requests, 2);
        assert_eq!(server.stats().requests, 3);
        let text = server.metrics_text();
        assert!(text.contains("model.x1.requests = 1"), "{text}");
        assert!(text.contains("model.x4.requests = 2"), "{text}");
        assert!(text.contains("serve.models"), "{text}");
        // exec-backed models publish health gauges (local engines are
        // always ready = 1)
        assert!(text.contains("model.x1.health = 1"), "{text}");
        assert!(text.contains("model.x4.health = 1"), "{text}");
    }

    /// Chained network models surface `model.<name>.layer.<k>.*` gauges
    /// with exactly this naming through `metrics_text`.
    #[test]
    fn network_model_publishes_per_layer_gauges() {
        use crate::compress::{demo_network, NetworkPipeline, Recipe};
        let ckpt = demo_network(&[8, 6, 4], 51);
        let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
        let net = NetworkPipeline::from_recipe(&recipe).unwrap().run(&ckpt).unwrap();
        let registry = Arc::new(ModelRegistry::new());
        registry.register("mlp", Arc::new(net.into_executor().unwrap()), recipe.exec, 8);
        let server = Server::start_registry(Arc::clone(&registry), ServeConfig::default());
        let y = server.infer_model("mlp", vec![0.5; 8]).unwrap();
        assert_eq!(y.len(), 4);
        let text = server.metrics_text();
        for k in 1..=2 {
            assert!(text.contains(&format!("model.mlp.layer.{k}.batch_us")), "{text}");
            assert!(text.contains(&format!("model.mlp.layer.{k}.additions")), "{text}");
            assert!(text.contains(&format!("model.mlp.layer.{k}.err_bound")), "{text}");
        }
        // plain single-engine models publish no layer series
        assert!(!text.contains("model.x1.layer."), "{text}");
    }

    #[test]
    fn unknown_model_errors_immediately() {
        let server = Server::start(echo_backend(), ServeConfig::default());
        let err = server.infer_model("nope", vec![1.0]).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
        assert_eq!(server.metrics().counter("rejected"), 1);
    }

    #[test]
    fn hot_add_and_remove_while_serving() {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_graph("a", &scale_graph(0), ExecConfig::serial(), 8);
        let server = Server::start_registry(Arc::clone(&registry), ServeConfig::default());
        assert_eq!(server.infer_model("a", vec![1.0, 1.0]).unwrap(), vec![2.0]);
        // hot add
        registry.register_graph("b", &scale_graph(1), ExecConfig::serial(), 8);
        assert_eq!(server.infer_model("b", vec![1.0, 1.0]).unwrap(), vec![4.0]);
        // hot remove: new submits rejected, the other model unaffected
        registry.remove("a");
        assert!(server.infer_model("a", vec![1.0, 1.0]).is_err());
        assert_eq!(server.infer_model("b", vec![2.0, 1.0]).unwrap(), vec![6.0]);
        // the stats roster still remembers the removed model
        assert_eq!(server.models_seen(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(registry.names(), vec!["b".to_string()]);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 3, "rejected submits never count as served");
    }
}
