//! Serving layer: an in-process inference service with a dynamic batcher
//! and a worker pool — the deployment context the paper motivates
//! (FPGA-accelerated datacenter inference, Sec. I).
//!
//! Requests are queued; a batcher thread drains up to `max_batch`
//! requests (waiting at most `batch_timeout`) and hands the batch to a
//! [`BatchEvaluator`]. Backends: the compressed model on the unified
//! [`crate::exec`] engine (batch-major — what the FPGA would run), a raw
//! [`crate::exec::Executor`] server, and the dense PJRT executable (the
//! DSP baseline). Exec-backed backends share the process-wide persistent
//! worker pool, whose counters `Server::metrics_text` publishes
//! alongside the serving histograms.

mod backend;
mod server;

pub use backend::{BatchEvaluator, CompressedMlpBackend, ExecutorBackend, PjrtMlpBackend};
pub use server::{MutexEvaluator, Server, ServerStats};
