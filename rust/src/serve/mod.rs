//! Serving layer: an in-process, multi-model inference service — the
//! deployment context the paper motivates (FPGA-accelerated datacenter
//! inference, Sec. I), where one process hosts many compressed models
//! at once.
//!
//! * [`ModelRegistry`] owns the named engines (`Arc<dyn Executor>`
//!   behind [`BatchEvaluator`] adapters): register an executor, lower a
//!   graph, or load an `.npy` checkpoint at runtime, each with its own
//!   `ExecConfig`; hot add/remove is safe under load.
//! * [`Router`] tags every submit with its resolved model entry and
//!   batches per model with fair round-robin draining (deep backlog on
//!   one model cannot starve the rest). `ServeConfig::queue_capacity`
//!   caps each model's in-flight requests: overload is load-shed with a
//!   typed [`ServeError::Shed`] and a `model.<name>.shed` counter,
//!   never by dropping an accepted request.
//! * [`Server`] is the front end: `submit_to(model, x)` from any
//!   thread; the historical single-model API (`Server::start` +
//!   `submit`) is a thin shim that serves its backend as
//!   [`DEFAULT_MODEL`].
//!
//! Backends: the compressed model on the unified [`crate::exec`] engine
//! (batch-major — what the FPGA would run), any raw
//! [`crate::exec::Executor`], and the dense PJRT executable (the DSP
//! baseline). Exec-backed models share the process-wide persistent
//! worker pool, whose counters `Server::metrics_text` publishes
//! alongside the global and per-model serving series.

mod backend;
mod registry;
mod router;
mod server;

pub use backend::{BatchEvaluator, CompressedMlpBackend, ExecutorBackend, PjrtMlpBackend};
pub use registry::{ModelEntry, ModelRegistry};
pub use router::{Response, Router, ServeError};
pub use server::{MutexEvaluator, Server, ServerStats, DEFAULT_MODEL};
