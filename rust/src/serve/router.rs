//! Model-tagged request routing with per-model batching.
//!
//! One router thread serves every model in a [`ModelRegistry`]: submits
//! are tagged with the resolved [`ModelEntry`], drained into per-model
//! queues, and served one batch per model in fair round-robin order —
//! a model with a deep backlog cannot starve the others, because after
//! each batch the cursor moves on. Batches are capped at the smaller of
//! the server-wide `max_batch` and the model's own preference, and a
//! request keeps its entry `Arc` from submit to response, so hot
//! removal never drops an accepted request.
//!
//! Overload protection: `ServeConfig::queue_capacity` caps each model's
//! in-flight requests (submit → response). A submit beyond the cap is
//! load-shed immediately with [`ServeError::Shed`] and counted on the
//! `model.<name>.shed` series — accepted requests are never dropped.
//! A batch that fails with [`ExecError::Unavailable`] (a dead remote
//! shard) is also shed, not errored: the model is degraded, and later
//! batches retry the shard.

use super::registry::ModelEntry;
use crate::config::ServeConfig;
use crate::exec::ExecError;
use crate::metrics::Metrics;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why a request did not produce an output row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// no model of that name is registered (or it was hot-removed)
    UnknownModel { model: String },
    /// the request was load-shed, not served: the model's in-flight
    /// queue is at `ServeConfig::queue_capacity`, or a remote shard it
    /// needs is unavailable
    Shed { model: String },
    /// the model's backend failed evaluating the batch
    Backend { model: String, message: String },
    /// the server went away before responding
    Disconnected,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel { model } => write!(f, "unknown model {model:?}"),
            ServeError::Shed { model } => {
                write!(f, "model {model:?} shed the request: overloaded or shard down")
            }
            ServeError::Backend { model, message } => {
                write!(f, "model {model:?} backend error: {message}")
            }
            ServeError::Disconnected => write!(f, "server disconnected before responding"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a request resolves to: the output row, or a typed error.
pub type Response = Result<Vec<f32>, ServeError>;

/// RAII in-flight slot: decrements the model's queue depth when the
/// request is dropped (response sent, or request discarded on any exit
/// path), so admission accounting can never leak.
struct QueueSlot(Arc<ModelEntry>);

impl Drop for QueueSlot {
    fn drop(&mut self) {
        self.0.queued.fetch_sub(1, Ordering::SeqCst);
    }
}

struct RoutedRequest {
    entry: Arc<ModelEntry>,
    x: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Response>,
    /// present when admission control is on
    _slot: Option<QueueSlot>,
}

/// The routing/batching half of a multi-model server: owns the intake
/// channel, the router thread and the admission control. [`super::Server`]
/// wraps it together with the registry and metrics.
pub struct Router {
    tx: Option<Sender<RoutedRequest>>,
    worker: Option<JoinHandle<()>>,
    queue_capacity: usize,
    metrics: Arc<Metrics>,
}

impl Router {
    /// Start the router thread. `metrics` receives the global
    /// (`requests`, `batch_size`, `latency_us`, `errors`, `shed`) and
    /// per-model (`model.<name>.*`) series.
    pub fn start(cfg: &ServeConfig, metrics: Arc<Metrics>) -> Self {
        let (tx, rx) = channel::<RoutedRequest>();
        let max_batch = cfg.max_batch.max(1);
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let loop_metrics = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("lccnn-serve-router".into())
            .spawn(move || router_loop(rx, max_batch, timeout, loop_metrics))
            .expect("spawn router");
        Router { tx: Some(tx), worker: Some(worker), queue_capacity: cfg.queue_capacity, metrics }
    }

    /// Submit one request to an already-resolved model entry; returns
    /// the receiver for its response. When the model's in-flight queue
    /// is at `ServeConfig::queue_capacity` the request is load-shed: the
    /// receiver resolves immediately to [`ServeError::Shed`] and the
    /// `shed` / `model.<name>.shed` counters tick.
    pub fn submit(&self, entry: Arc<ModelEntry>, x: Vec<f32>) -> Receiver<Response> {
        let (resp_tx, resp_rx) = channel();
        let slot = if self.queue_capacity > 0 {
            // admit-then-check: fetch_add returns the prior depth, so at
            // most `queue_capacity` submits can ever be in flight — a
            // losing racer undoes its increment and sheds
            let prior = entry.queued.fetch_add(1, Ordering::SeqCst);
            if prior >= self.queue_capacity {
                entry.queued.fetch_sub(1, Ordering::SeqCst);
                let model = entry.name().to_string();
                self.metrics.incr("shed", 1);
                self.metrics.incr(&format!("model.{model}.shed"), 1);
                let _ = resp_tx.send(Err(ServeError::Shed { model }));
                return resp_rx;
            }
            Some(QueueSlot(Arc::clone(&entry)))
        } else {
            None
        };
        let req = RoutedRequest { entry, x, enqueued: Instant::now(), resp: resp_tx, _slot: slot };
        self.tx.as_ref().expect("router alive").send(req).expect("router thread alive");
        resp_rx
    }

    /// Stop accepting and join the router thread; every queued request
    /// is served first (the thread drains all per-model queues before
    /// exiting). Idempotent.
    pub fn shutdown(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Pending work: per-model FIFO queues plus the round-robin order the
/// router serves them in.
#[derive(Default)]
struct Pending {
    queues: BTreeMap<String, VecDeque<RoutedRequest>>,
    /// model names with a non-empty queue, in service order
    rr: VecDeque<String>,
}

impl Pending {
    /// Enqueue a request; returns true when its model's queue now holds
    /// a full batch (given the server-wide `max_batch` cap), so the
    /// idle batching window can dispatch early instead of waiting out
    /// the timeout.
    fn push(&mut self, req: RoutedRequest, max_batch: usize) -> bool {
        let cap = max_batch.min(req.entry.max_batch()).max(1);
        let name = req.entry.name().to_string();
        let q = self.queues.entry(name.clone()).or_default();
        if q.is_empty() {
            self.rr.push_back(name);
        }
        q.push_back(req);
        q.len() >= cap
    }

    fn is_empty(&self) -> bool {
        self.rr.is_empty()
    }

    /// Take the next batch in round-robin order: up to `max_batch`
    /// requests from the head of the next model's queue, all sharing
    /// one entry `Arc` (a hot-swapped model's old and new engines are
    /// never mixed in one batch). The model goes to the back of the
    /// rotation if it still has work.
    fn next_batch(&mut self, max_batch: usize) -> Option<Vec<RoutedRequest>> {
        let name = self.rr.pop_front()?;
        let q = self.queues.get_mut(&name).expect("rr names a queued model");
        let entry = Arc::clone(&q.front().expect("queue non-empty").entry);
        let cap = max_batch.min(entry.max_batch()).max(1);
        let mut batch = Vec::with_capacity(cap.min(q.len()));
        while batch.len() < cap
            && q.front().map_or(false, |r| Arc::ptr_eq(&r.entry, &entry))
        {
            batch.push(q.pop_front().expect("checked front"));
        }
        if q.is_empty() {
            self.queues.remove(&name);
        } else {
            self.rr.push_back(name);
        }
        Some(batch)
    }
}

fn router_loop(
    rx: Receiver<RoutedRequest>,
    max_batch: usize,
    timeout: Duration,
    metrics: Arc<Metrics>,
) {
    let mut pending = Pending::default();
    let mut connected = true;
    loop {
        if pending.is_empty() {
            if !connected {
                return; // drained and disconnected: clean exit
            }
            // idle: block for the first request of the next cycle, then
            // hold a batching window so a burst can coalesce — cut
            // short the moment a model's queue holds a full batch
            let full = match rx.recv() {
                Ok(r) => pending.push(r, max_batch),
                Err(_) => return,
            };
            if !full {
                let deadline = Instant::now() + timeout;
                loop {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            if pending.push(r, max_batch) {
                                break;
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            connected = false;
                            break;
                        }
                    }
                }
            }
        } else if connected {
            // busy: absorb whatever has already arrived without waiting
            // (backlog is the batching signal; no added latency)
            loop {
                match rx.try_recv() {
                    Ok(r) => {
                        pending.push(r, max_batch);
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        connected = false;
                        break;
                    }
                }
            }
        }
        if let Some(batch) = pending.next_batch(max_batch) {
            serve_batch(batch, &metrics);
        }
    }
}

fn serve_batch(batch: Vec<RoutedRequest>, metrics: &Metrics) {
    let entry = Arc::clone(&batch[0].entry);
    let model = entry.name();
    let n = batch.len() as u64;
    metrics.incr("requests", n);
    metrics.incr(&format!("model.{model}.requests"), n);
    metrics.incr(&format!("model.{model}.batches"), 1);
    metrics.observe("batch_size", batch.len() as f64);
    metrics.observe(&format!("model.{model}.batch_size"), batch.len() as f64);
    let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
    match entry.try_eval_batch(&xs) {
        Ok(ys) => {
            let latency_key = format!("model.{model}.latency_us");
            for (req, y) in batch.into_iter().zip(ys) {
                let us = req.enqueued.elapsed().as_secs_f64() * 1e6;
                metrics.observe("latency_us", us);
                metrics.observe(&latency_key, us);
                let _ = req.resp.send(Ok(y));
            }
        }
        // a dead remote shard sheds the batch (the model is degraded,
        // not broken: a later batch may find the shard back) — the
        // backend already counted shard.<i>.dead on its own metrics
        Err(ExecError::Unavailable { shard, message }) => {
            let what = format!("shard {shard} unavailable, shedding {n} request(s)");
            log::warn!("model {model:?}: {what}: {message}");
            metrics.incr("shed", n);
            metrics.incr(&format!("model.{model}.shed"), n);
            let err = ServeError::Shed { model: model.to_string() };
            for req in batch {
                let _ = req.resp.send(Err(err.clone()));
            }
        }
        Err(ExecError::Failed { message }) => {
            let err = ServeError::Backend { model: model.to_string(), message };
            metrics.incr("errors", 1);
            metrics.incr(&format!("model.{model}.errors"), 1);
            for req in batch {
                let _ = req.resp.send(Err(err.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecConfig;
    use crate::graph::{AdderGraph, Operand, OutputSpec};
    use crate::serve::ModelRegistry;

    fn scale_graph(inputs: usize, shift: i32) -> AdderGraph {
        // y = 2^shift * (x0 + x1 + ...): distinguishable per model
        let mut g = AdderGraph::new(inputs);
        let root = g.push_sum((0..inputs).map(Operand::input).collect()).unwrap();
        g.set_outputs(vec![OutputSpec::Ref(root.scaled(shift, false))]);
        g
    }

    #[test]
    fn round_robin_interleaves_models_fairly() {
        let r = ModelRegistry::new();
        r.register_graph("a", &scale_graph(1, 0), ExecConfig::serial(), 4);
        r.register_graph("b", &scale_graph(1, 1), ExecConfig::serial(), 4);
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::start(
            &ServeConfig { max_batch: 4, batch_timeout_us: 20_000, ..Default::default() },
            Arc::clone(&metrics),
        );
        let a = r.get("a").unwrap();
        let b = r.get("b").unwrap();
        // deep backlog on a, a single request on b: b must not wait for
        // a's whole backlog (it is served after at most one a-batch)
        let rx_a: Vec<_> = (0..12).map(|i| router.submit(Arc::clone(&a), vec![i as f32])).collect();
        let rx_b = router.submit(Arc::clone(&b), vec![100.0]);
        assert_eq!(rx_b.recv().unwrap().unwrap(), vec![200.0]);
        for (i, rx) in rx_a.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        router.shutdown();
        assert_eq!(metrics.counter("model.a.requests"), 12);
        assert_eq!(metrics.counter("model.b.requests"), 1);
        assert!(metrics.counter("model.a.batches") >= 3, "max_batch 4 over 12 requests");
    }

    #[test]
    fn batches_cap_at_model_preference() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(1, 0), ExecConfig::serial(), 2);
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::start(
            &ServeConfig { max_batch: 64, batch_timeout_us: 20_000, ..Default::default() },
            Arc::clone(&metrics),
        );
        let m = r.get("m").unwrap();
        let rxs: Vec<_> = (0..6).map(|i| router.submit(Arc::clone(&m), vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        router.shutdown();
        let (_, mean, _, _) = metrics.summary("model.m.batch_size").unwrap();
        assert!(mean <= 2.0 + 1e-9, "model max_batch=2 must cap batches, mean {mean}");
    }

    #[test]
    fn hot_swap_never_mixes_engines_in_one_batch() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(1, 0), ExecConfig::serial(), 64);
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::start(
            &ServeConfig { max_batch: 64, batch_timeout_us: 50_000, ..Default::default() },
            metrics,
        );
        let old = r.get("m").unwrap();
        let rx_old: Vec<_> =
            (0..3).map(|i| router.submit(Arc::clone(&old), vec![i as f32])).collect();
        // swap while the old requests are still queued
        r.register_graph("m", &scale_graph(1, 2), ExecConfig::serial(), 64);
        let new = r.get("m").unwrap();
        let rx_new: Vec<_> =
            (0..3).map(|i| router.submit(Arc::clone(&new), vec![i as f32])).collect();
        for (i, rx) in rx_old.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32], "old engine answers");
        }
        for (i, rx) in rx_new.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![4.0 * i as f32], "new engine answers");
        }
        router.shutdown();
    }

    #[test]
    fn full_batch_dispatches_before_the_window_expires() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(1, 0), ExecConfig::serial(), 4);
        let mut router = Router::start(
            // a deliberately huge window: only the full-batch early exit
            // can serve these requests quickly
            &ServeConfig { max_batch: 4, batch_timeout_us: 2_000_000, ..Default::default() },
            Arc::new(Metrics::new()),
        );
        let m = r.get("m").unwrap();
        let start = std::time::Instant::now();
        let rxs: Vec<_> = (0..4).map(|i| router.submit(Arc::clone(&m), vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        assert!(
            start.elapsed() < Duration::from_millis(1500),
            "full batch must dispatch early, waited {:?}",
            start.elapsed()
        );
        router.shutdown();
    }

    #[test]
    fn queue_capacity_sheds_with_typed_error_and_counter() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(1, 0), ExecConfig::serial(), 64);
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::start(
            // a long batching window holds submissions in flight so the
            // cap is deterministically reachable from this thread
            &ServeConfig {
                max_batch: 64,
                batch_timeout_us: 1_000_000,
                queue_capacity: 3,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        let m = r.get("m").unwrap();
        let rxs: Vec<_> = (0..8).map(|i| router.submit(Arc::clone(&m), vec![i as f32])).collect();
        let mut served = 0;
        let mut shed = 0;
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv().unwrap() {
                Ok(y) => {
                    assert_eq!(y, vec![i as f32]);
                    served += 1;
                }
                Err(ServeError::Shed { model }) => {
                    assert_eq!(model, "m");
                    shed += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(served + shed, 8);
        assert!(served >= 3, "capacity admits up to 3 concurrently, served {served}");
        assert!(shed >= 1, "overload must shed");
        assert_eq!(metrics.counter("model.m.shed"), shed);
        assert_eq!(metrics.counter("shed"), shed);
        assert_eq!(metrics.counter("model.m.requests"), served);
        router.shutdown();
        assert_eq!(m.queued(), 0, "every slot released");
    }

    #[test]
    fn zero_capacity_disables_shedding() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(1, 0), ExecConfig::serial(), 64);
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::start(
            &ServeConfig {
                max_batch: 4,
                batch_timeout_us: 100,
                queue_capacity: 0,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        let m = r.get("m").unwrap();
        let rxs: Vec<_> = (0..64).map(|i| router.submit(Arc::clone(&m), vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        assert_eq!(metrics.counter("shed"), 0);
        router.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drains() {
        let r = ModelRegistry::new();
        r.register_graph("m", &scale_graph(2, 0), ExecConfig::serial(), 8);
        let mut router = Router::start(&ServeConfig::default(), Arc::new(Metrics::new()));
        let m = r.get("m").unwrap();
        let rx = router.submit(m, vec![1.0, 2.0]);
        router.shutdown();
        router.shutdown();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![3.0]);
    }
}
