//! # lccnn — Coding for Computation
//!
//! Reproduction of *"Coding for Computation: Efficient Compression of Neural
//! Networks for Reconfigurable Hardware"* (Rosenberger, Fischer, Fröhlich,
//! Bereyhi, Müller; 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The library compresses neural networks so that inference on
//! reconfigurable hardware (FPGAs) needs as few *additions* as possible:
//!
//! 1. **Pruning via group-lasso regularized training** (proximal gradient,
//!    block soft-thresholding) removes input neurons / kernel columns while
//!    keeping weight matrices dense — which is what LCC needs.
//! 2. **Weight sharing** ties highly correlated columns to shared centroids
//!    found by affinity propagation, turning `W x` into a small centroid
//!    matrix times pre-summed inputs (scalar additions only).
//! 3. **Linear computation coding (LCC)** factorizes the remaining dense
//!    matrix into sparse factors whose entries are signed powers of two, so
//!    the matrix-vector product becomes a shift-add adder graph.
//!
//! The crate also contains every substrate the paper depends on: a CSD
//! (canonical signed digit) cost model for the baseline, an adder-graph IR
//! plus a shift-add virtual machine that simulates the FPGA datapath, the
//! unified batch-major execution engine ([`exec`]) every runtime path
//! funnels through, conv layer reformulations (full-kernel /
//! partial-kernel), an affinity propagation implementation, synthetic
//! dataset generators, a PJRT runtime that executes the AOT-compiled JAX
//! training/eval artifacts, and a pipeline coordinator + serving layer.
//!
//! The [`compress`] module ties the stages together as one recipe-driven
//! pipeline: a serializable [`compress::Recipe`] deterministically
//! reproduces a prune → share → quantize → LCC run, reports per-stage
//! addition accounting, and lowers straight to an exec-servable
//! artifact the multi-model registry can load —
//! [`compress::Pipeline`] for one matrix, [`compress::NetworkPipeline`]
//! for whole multi-layer checkpoints (chained by
//! [`compress::NetworkExecutor`], guarded by the accuracy gate), and
//! [`compress::tune`] to search recipe space and keep the
//! (additions, rel-err) Pareto frontier. The [`serve`] layer puts any
//! resulting engine behind a multi-model batching server, locally or
//! sharded across remote workers ([`exec::remote`]).
//!
//! See `ARCHITECTURE.md` at the repository root for the module map and
//! the checkpoint → recipe → artifact → engine → server data flow.

pub mod util;
pub mod tensor;
pub mod quant;
pub mod lcc;
pub mod graph;
pub mod exec;
pub mod cluster;
pub mod prune;
pub mod share;
pub mod convert;
pub mod nn;
pub mod data;
pub mod config;
pub mod metrics;
pub mod compress;
pub mod runtime;
pub mod train;
pub mod pipeline;
pub mod serve;
pub mod report;
