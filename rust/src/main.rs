//! lccnn CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                          list artifacts + platform
//!   fig2    [--lambda F] [...]    run the Fig. 2 MLP pipeline for one λ
//!   table1  [--steps N] [...]     run the Table-I residual-CNN pipeline
//!   decompose --rows N --cols K   LCC vs CSD on a random matrix
//!
//! First-party flag parsing (offline build: no clap); every flag has the
//! form --name value.

use anyhow::{bail, Context, Result};
use lccnn::config::{MlpPipelineConfig, ResnetPipelineConfig};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::quant::{matrix_csd_adders, FixedPointFormat};
use lccnn::report::{percent, ratio, Table};
use lccnn::runtime::Runtime;
use lccnn::tensor::Matrix;
use lccnn::util::{logger, Rng};
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            bail!("expected --flag, got {k:?}");
        }
        let v = args.get(i + 1).with_context(|| format!("missing value for {k}"))?;
        flags.insert(k[2..].to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(name) {
        None => Ok(default),
        Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
    }
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.artifact_names() {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_fig2(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = MlpPipelineConfig::default();
    cfg.lambda = flag(&flags, "lambda", cfg.lambda)?;
    cfg.train_steps = flag(&flags, "steps", cfg.train_steps)?;
    cfg.share_retrain_steps = flag(&flags, "retrain-steps", cfg.share_retrain_steps)?;
    cfg.train_examples = flag(&flags, "train-examples", cfg.train_examples)?;
    cfg.seed = flag(&flags, "seed", cfg.seed)?;
    if let Some(algo) = flags.get("lcc") {
        cfg.lcc_algo = lccnn::config::LccAlgoConfig::parse(algo)
            .with_context(|| format!("--lcc {algo:?} (use fp|fs)"))?;
    }
    let rt = Runtime::open_default()?;
    let out = lccnn::pipeline::run_mlp_pipeline(&rt, &cfg)?;
    let mut t = Table::new(
        &format!("Fig. 2 point (lambda = {})", cfg.lambda),
        &["stage", "layer-1 adds", "ratio", "accuracy", "cols", "clusters"],
    );
    t.add_row(vec![
        "baseline (dense CSD)".into(),
        out.baseline_additions.to_string(),
        "1.0".into(),
        percent(out.baseline_accuracy),
        "784".into(),
        "-".into(),
    ]);
    for s in &out.stages {
        t.add_row(vec![
            s.stage.clone(),
            s.additions.to_string(),
            ratio(out.baseline_additions, s.additions),
            percent(s.accuracy),
            s.active_columns.to_string(),
            if s.clusters > 0 { s.clusters.to_string() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    println!("final LCC SQNR: {:.1} dB", out.lcc_sqnr_db);
    Ok(())
}

fn cmd_table1(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = ResnetPipelineConfig::default();
    cfg.train_steps = flag(&flags, "steps", cfg.train_steps)?;
    cfg.lambda = flag(&flags, "lambda", cfg.lambda)?;
    cfg.train_examples = flag(&flags, "train-examples", cfg.train_examples)?;
    cfg.eval_limit = flag(&flags, "eval-limit", cfg.eval_limit)?;
    cfg.seed = flag(&flags, "seed", cfg.seed)?;
    let rt = Runtime::open_default()?;
    let out = lccnn::pipeline::run_resnet_pipeline(&rt, &cfg)?;
    let mut t = Table::new(
        &format!(
            "Table I (baseline acc {} / {} adds)",
            percent(out.baseline_accuracy),
            out.baseline_additions
        ),
        &["method", "FK ratio", "FK acc", "PK ratio", "PK acc"],
    );
    for (name, fk, pk) in &out.rows {
        t.add_row(vec![
            name.clone(),
            format!("{:.1}", fk.ratio),
            percent(fk.accuracy),
            format!("{:.1}", pk.ratio),
            percent(pk.accuracy),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_decompose(flags: HashMap<String, String>) -> Result<()> {
    let rows: usize = flag(&flags, "rows", 128)?;
    let cols: usize = flag(&flags, "cols", 16)?;
    let seed: u64 = flag(&flags, "seed", 0)?;
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(rows, cols, 0.5, &mut rng);
    let fmt = FixedPointFormat::default_weights();
    let csd = matrix_csd_adders(&w, fmt);
    let mut t = Table::new(
        &format!("LCC vs CSD on random {rows}x{cols}"),
        &["method", "adds", "ratio", "sqnr dB", "depth"],
    );
    t.add_row(vec!["CSD".into(), csd.to_string(), "1.0".into(), "-".into(), "-".into()]);
    for (name, cfg) in [("LCC-FP", LccConfig::fp()), ("LCC-FS", LccConfig::fs())] {
        let d = decompose(&w, &cfg);
        let sched = lccnn::graph::schedule(d.graph());
        t.add_row(vec![
            name.into(),
            d.additions().to_string(),
            ratio(csd, d.additions()),
            format!("{:.1}", d.sqnr_db(&w)),
            sched.depth.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn main() -> Result<()> {
    logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("usage: lccnn <info|fig2|table1|decompose> [--flag value ...]");
            return Ok(());
        }
    };
    match cmd {
        "info" => cmd_info(),
        "fig2" => cmd_fig2(parse_flags(&rest)?),
        "table1" => cmd_table1(parse_flags(&rest)?),
        "decompose" => cmd_decompose(parse_flags(&rest)?),
        other => bail!("unknown command {other:?}"),
    }
}
