//! lccnn CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                          list artifacts + platform
//!   fig2    [--lambda F] [...]    run the Fig. 2 MLP pipeline for one λ
//!   table1  [--steps N] [...]     run the Table-I residual-CNN pipeline
//!   decompose --rows N --cols K   LCC vs CSD on a random matrix
//!   compress [--recipe r.toml] [--checkpoint w.npy | --demo N | --network dir|demo]
//!            [--out dir] [--shards N] [--exec-mode float|fixed]
//!                                 recipe -> artifact -> served engine,
//!                                 self-verified (nonzero exit on mismatch;
//!                                 fixed mode verifies within the lowered
//!                                 plan's analytic error bound). --network
//!                                 compresses a multi-layer checkpoint
//!                                 directory through the per-layer recipe
//!                                 path and verifies the chained
//!                                 NetworkExecutor against the hand-chained
//!                                 NaiveExecutor oracle
//!   gate    [--recipe r.toml] [--epsilon F] [--steps N] [--train N] [--test N]
//!                                 the accuracy gate: train the
//!                                 LeNet-300-100-shaped MLP on synth-MNIST,
//!                                 compress it as a network, and fail unless
//!                                 the compressed accuracy stays within
//!                                 epsilon of the dense baseline
//!   tune    [--spec tune.toml] [--demo | --checkpoint w.npy | --network dir|demo]
//!           [--budget N] [--seed N] [--measure] [--out dir] [--recipe base.toml]
//!                                 recipe autotuner: sweep prune/share/LCC/
//!                                 exec axes over the target, flag the
//!                                 (additions, rel-err) Pareto frontier and
//!                                 emit per-point recipe.toml + best.toml +
//!                                 sweep.json/tsv/md into --out
//!   serve   [--model name=path]... [--shards N] [--exec-mode float|fixed]
//!           [--remote-shard host:port[|host:port...]]... [--remote-name name]
//!           [--remote-check artifact-dir] [--recheck-delay-ms MS]
//!           [--client-delay-ms MS]
//!           [--remote-layer host:port]... [--remote-layer-check network-dir]
//!                                 multi-model registry server driver;
//!                                 remote shards gather behind one model,
//!                                 `|`-joined addresses are replicas of the
//!                                 same range; --recheck-delay-ms reruns the
//!                                 remote check after a pause (recovery
//!                                 window), --client-delay-ms paces the
//!                                 hammer so failures can be injected mid-run;
//!                                 repeated --remote-layer flags chain
//!                                 layer-range workers, in order, into one
//!                                 served model (checked bit-exact against a
//!                                 local rebuild via --remote-layer-check)
//!   shard-worker --artifact dir [--listen host:port]
//!           [--shards N --index I | --range a..b | --layer-range a..b]
//!           [--exec-mode m] [--drain-on path]
//!                                 serve one output-column range of an
//!                                 artifact over the remote batch
//!                                 protocol until killed; network artifact
//!                                 dirs serve a layer range (--layer-range,
//!                                 0-based) instead of a column range; with
//!                                 --drain-on the worker polls for that
//!                                 file, then drains (finish in-flight,
//!                                 refuse new batches) and exits cleanly
//!
//! First-party flag parsing (offline build: no clap); every flag has the
//! form --name value and may repeat (`--model a=p1 --model b=p2`).
//! `lccnn <cmd> --help` (or `lccnn help <cmd>`) prints each command's
//! flags; bare boolean flags exist only where the doc above shows them
//! valueless (`tune --demo`, `tune --measure`).

use anyhow::{bail, Context, Result};
use lccnn::compress::{
    demo_network, demo_weights, tune, ChainedExecutor, CompressedModel, CompressedNetwork, LccSpec,
    NetworkCheckpoint, NetworkExecutor, NetworkPipeline, Pipeline, PruneSpec, Recipe, StageSpec,
    TuneSpec,
};
use lccnn::config::{
    ExecConfig, ExecMode, MlpPipelineConfig, ModelSpec, ResnetPipelineConfig, ServeConfig,
    ShardSpec,
};
use lccnn::data::synth_mnist;
use lccnn::exec::{even_ranges, Executor, NaiveExecutor, RemoteExecutor, RemoteOptions, ShardWorker};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::metrics::Metrics;
use lccnn::nn::mlp3::argmax;
use lccnn::nn::npy::NpyArray;
use lccnn::nn::{load_weight_matrix, Mlp3, ParamStore};
use lccnn::quant::{matrix_csd_adders, FixedPointFormat};
use lccnn::report::{percent, ratio, Table};
use lccnn::runtime::Runtime;
use lccnn::serve::{ModelRegistry, Server};
use lccnn::tensor::Matrix;
use lccnn::util::{logger, Rng};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Parsed `--name value` flags; a flag may repeat (all values kept, in
/// order — `get` returns the last, `get_all` every one).
struct Flags(HashMap<String, Vec<String>>);

impl Flags {
    fn get(&self, name: &str) -> Option<&String> {
        self.0.get(name).and_then(|vs| vs.last())
    }

    fn get_all(&self, name: &str) -> &[String] {
        self.0.get(name).map(Vec::as_slice).unwrap_or(&[])
    }
}

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags: HashMap<String, Vec<String>> = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            bail!("expected --flag, got {k:?}");
        }
        let v = args.get(i + 1).with_context(|| format!("missing value for {k}"))?;
        flags.entry(k[2..].to_string()).or_default().push(v.clone());
        i += 2;
    }
    Ok(Flags(flags))
}

/// Insert an explicit `"1"` after bare boolean flags so commands with
/// valueless flags (`tune --demo --budget 8`) still parse under the
/// uniform `--name value` grammar; `--demo 1` stays untouched.
fn normalize_bool_flags(args: &[String], bools: &[&str]) -> Vec<String> {
    let mut out = Vec::with_capacity(args.len() + bools.len());
    for (i, a) in args.iter().enumerate() {
        out.push(a.clone());
        let is_bool = a.strip_prefix("--").is_some_and(|name| bools.contains(&name));
        let bare = args.get(i + 1).map(|v| v.starts_with("--")).unwrap_or(true);
        if is_bool && bare {
            out.push("1".to_string());
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &Flags, name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(name) {
        None => Ok(default),
        Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
    }
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.artifact_names() {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_fig2(flags: Flags) -> Result<()> {
    let mut cfg = MlpPipelineConfig::default();
    cfg.lambda = flag(&flags, "lambda", cfg.lambda)?;
    cfg.train_steps = flag(&flags, "steps", cfg.train_steps)?;
    cfg.share_retrain_steps = flag(&flags, "retrain-steps", cfg.share_retrain_steps)?;
    cfg.train_examples = flag(&flags, "train-examples", cfg.train_examples)?;
    cfg.seed = flag(&flags, "seed", cfg.seed)?;
    if let Some(algo) = flags.get("lcc") {
        cfg.lcc_algo = lccnn::config::LccAlgoConfig::parse(algo)
            .with_context(|| format!("--lcc {algo:?} (use fp|fs)"))?;
    }
    let rt = Runtime::open_default()?;
    let out = lccnn::pipeline::run_mlp_pipeline(&rt, &cfg)?;
    let mut t = Table::new(
        &format!("Fig. 2 point (lambda = {})", cfg.lambda),
        &["stage", "layer-1 adds", "ratio", "accuracy", "cols", "clusters"],
    );
    t.add_row(vec![
        "baseline (dense CSD)".into(),
        out.baseline_additions.to_string(),
        "1.0".into(),
        percent(out.baseline_accuracy),
        "784".into(),
        "-".into(),
    ]);
    for s in &out.stages {
        t.add_row(vec![
            s.stage.clone(),
            s.additions.to_string(),
            ratio(out.baseline_additions, s.additions),
            percent(s.accuracy),
            s.active_columns.to_string(),
            if s.clusters > 0 { s.clusters.to_string() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    println!("final LCC SQNR: {:.1} dB", out.lcc_sqnr_db);
    Ok(())
}

fn cmd_table1(flags: Flags) -> Result<()> {
    let mut cfg = ResnetPipelineConfig::default();
    cfg.train_steps = flag(&flags, "steps", cfg.train_steps)?;
    cfg.lambda = flag(&flags, "lambda", cfg.lambda)?;
    cfg.train_examples = flag(&flags, "train-examples", cfg.train_examples)?;
    cfg.eval_limit = flag(&flags, "eval-limit", cfg.eval_limit)?;
    cfg.seed = flag(&flags, "seed", cfg.seed)?;
    let rt = Runtime::open_default()?;
    let out = lccnn::pipeline::run_resnet_pipeline(&rt, &cfg)?;
    let mut t = Table::new(
        &format!(
            "Table I (baseline acc {} / {} adds)",
            percent(out.baseline_accuracy),
            out.baseline_additions
        ),
        &["method", "FK ratio", "FK acc", "PK ratio", "PK acc"],
    );
    for (name, fk, pk) in &out.rows {
        t.add_row(vec![
            name.clone(),
            format!("{:.1}", fk.ratio),
            percent(fk.accuracy),
            format!("{:.1}", pk.ratio),
            percent(pk.accuracy),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_decompose(flags: Flags) -> Result<()> {
    let rows: usize = flag(&flags, "rows", 128)?;
    let cols: usize = flag(&flags, "cols", 16)?;
    let seed: u64 = flag(&flags, "seed", 0)?;
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(rows, cols, 0.5, &mut rng);
    let fmt = FixedPointFormat::default_weights();
    let csd = matrix_csd_adders(&w, fmt);
    let mut t = Table::new(
        &format!("LCC vs CSD on random {rows}x{cols}"),
        &["method", "adds", "ratio", "sqnr dB", "depth"],
    );
    t.add_row(vec!["CSD".into(), csd.to_string(), "1.0".into(), "-".into(), "-".into()]);
    for (name, cfg) in [("LCC-FP", LccConfig::fp()), ("LCC-FS", LccConfig::fs())] {
        let d = decompose(&w, &cfg);
        let sched = lccnn::graph::schedule(d.graph());
        t.add_row(vec![
            name.into(),
            d.additions().to_string(),
            ratio(csd, d.additions()),
            format!("{:.1}", d.sqnr_db(&w)),
            sched.depth.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `compress`: run a compression recipe end to end — raw weights →
/// pruned/shared/LCC'd artifact → exec-servable engine — with
/// self-verification at both seams: executor outputs vs the
/// `NaiveExecutor`-composed oracle, and a serve round-trip through the
/// emitted artifact directory (whose `recipe.toml` must reproduce the
/// exact engine). Nonzero exit on any mismatch — the CI smoke.
fn cmd_compress(flags: Flags) -> Result<()> {
    let base = match flags.get("recipe") {
        Some(p) => Recipe::from_toml(Path::new(p))?,
        None => Recipe::default(),
    };
    let mut recipe = Recipe::from_env_over(base);
    // --shards N overrides the recipe's [compress.shard] section; the
    // artifact's recipe.toml carries it, so the serve round-trip below
    // reloads a *sharded* engine and verifies it bit-exact
    let shards: usize = flag(&flags, "shards", 0)?;
    if shards > 0 {
        recipe.shard = Some(ShardSpec { shards, mode: recipe.exec.shard_mode });
    }
    if let Some(m) = flags.get("exec-mode") {
        recipe.exec.exec_mode =
            ExecMode::parse(m).with_context(|| format!("--exec-mode {m:?} (use float|fixed)"))?;
    }
    let demo: usize = flag(&flags, "demo", 0)?;
    let requests: usize = flag(&flags, "requests", 32)?.max(1);
    let seed: u64 = flag(&flags, "seed", 0)?;

    // --network dir|demo: the whole-model path — every layer through its
    // resolved per-layer recipe, chained into one NetworkExecutor
    if let Some(src) = flags.get("network").cloned() {
        return compress_network(&flags, recipe, &src, requests, seed);
    }

    let mut jobs: Vec<(String, Matrix)> = Vec::new();
    if let Some(ck) = flags.get("checkpoint") {
        let path = Path::new(ck);
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("model").to_string();
        jobs.push((name, load_weight_matrix(path)?));
    }
    for i in 0..demo {
        let (rows, groups, per) = (24 + 8 * i, 4 + i, 4);
        jobs.push((format!("demo-{i}"), demo_weights(rows, groups, per, seed + i as u64)));
    }
    if jobs.is_empty() {
        bail!("nothing to compress: pass --checkpoint w.npy (file or dir) or --demo N");
    }

    if let Some(s) = recipe.shard_spec() {
        println!("serving engines sharded x{} ({})", s.shards, s.mode.as_str());
    }
    if recipe.exec.exec_mode == ExecMode::Fixed {
        println!(
            "exec mode: fixed shift-add (frac_bits {}, {}-bit {} accumulator)",
            recipe.exec.fixed_frac_bits,
            recipe.exec.fixed_acc.bits(),
            recipe.exec.fixed_sat.as_str()
        );
    }
    let pipeline = Pipeline::from_recipe(&recipe)?;
    let metrics = Metrics::new();
    let mut failures = 0usize;
    for (name, w) in &jobs {
        println!("compressing {name:?} ({}x{})", w.rows(), w.cols());
        let model = pipeline.run_with_metrics(w, &metrics)?;
        println!("{}", model.report().render());
        failures += verify_against_oracle(name, &model, requests, seed + 17);

        let (dir, ephemeral) = match flags.get("out") {
            Some(d) if jobs.len() == 1 => (PathBuf::from(d), false),
            Some(d) => (Path::new(d).join(name), false),
            None => (
                std::env::temp_dir()
                    .join(format!("lccnn-compress-{}-{name}", std::process::id())),
                true,
            ),
        };
        write_artifact(&dir, w, &recipe, &model)?;
        println!("artifact: {}", dir.display());
        failures += serve_roundtrip(name, &dir, &model.executor(), requests, seed + 23)?;
        if ephemeral {
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    println!("{}", metrics.render());
    if failures > 0 {
        bail!("{failures} verification mismatches");
    }
    println!(
        "compress: {} model(s) verified recipe -> artifact -> registry -> serve, {}",
        jobs.len(),
        if recipe.exec.exec_mode == ExecMode::Fixed {
            "within the fixed-point error bound (serve round-trip bit-identical)"
        } else {
            "bit-identical"
        }
    );
    Ok(())
}

/// Executor outputs vs the oracle-composed reference (gather kept →
/// segment sums → `NaiveExecutor` over the LCC graph; dense math for
/// pre-LCC recipes). Float engines must match bit-exact; the fixed
/// datapath is held to its lowered plan's analytic error bound (plus
/// slack for the float oracle's own rounding). Returns the mismatch
/// count.
fn verify_against_oracle(name: &str, model: &CompressedModel, n: usize, seed: u64) -> usize {
    let exec = model.executor();
    let bound = exec.max_error_bound();
    let oracle = model.lcc().map(|s| NaiveExecutor::new(s.graph().clone()));
    let mut rng = Rng::new(seed);
    let mut bad = 0;
    for _ in 0..n {
        let x = rng.normal_vec(exec.num_inputs(), 1.0);
        let got = exec.execute_one(&x);
        let xk: Vec<f32> = model.kept().iter().map(|&i| x[i]).collect();
        let want = match (&oracle, model.lcc()) {
            (Some(o), Some(slcc)) => o.execute_one(&slcc.layer.segment_sums(&xk)),
            _ => match model.state().shared() {
                Some(s) => s.apply(&xk),
                None => model.state().dense().matvec(&xk),
            },
        };
        let ok = if bound == 0.0 {
            got == want
        } else {
            got.len() == want.len()
                && got.iter().zip(&want).all(|(g, w)| {
                    ((g - w).abs() as f64) <= bound + 1e-4 * (1.0 + w.abs() as f64)
                })
        };
        if !ok {
            eprintln!("{name:?}: executor {got:?} != oracle {want:?} (bound {bound:e})");
            bad += 1;
        }
    }
    bad
}

/// Write the exec-servable artifact: the original weights, the recipe
/// that reproduces the engine, and the per-stage report.
fn write_artifact(dir: &Path, w: &Matrix, recipe: &Recipe, model: &CompressedModel) -> Result<()> {
    let mut store = ParamStore::new();
    store.insert("weight", NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()));
    store.save(dir)?;
    recipe.save(&dir.join("recipe.toml"))?;
    std::fs::write(dir.join("report.tsv"), model.report().to_tsv())
        .with_context(|| format!("write {}", dir.join("report.tsv").display()))?;
    Ok(())
}

/// Load the artifact back through the registry (recipe discovery) and
/// serve it, comparing every response bit-exact with the local engine —
/// the registry rebuild is deterministic, so even fixed-mode answers
/// must match bit for bit. Works for single-matrix artifacts
/// (pipeline-exec) and network directories (network-exec) alike.
fn serve_roundtrip(
    name: &str,
    dir: &Path,
    exec: &dyn Executor,
    n: usize,
    seed: u64,
) -> Result<usize> {
    let registry = Arc::new(ModelRegistry::new());
    let entry = registry.load_checkpoint_with_recipe(name, dir, None, 16)?;
    anyhow::ensure!(
        entry.executor().map(|e| e.name()) == Some(exec.name()),
        "artifact reload chose backend {:?}, local engine is {:?}",
        entry.executor().map(|e| e.name()),
        exec.name()
    );
    anyhow::ensure!(
        entry.input_dim() == Some(exec.num_inputs()),
        "artifact reload changed the input dim: {:?} vs {}",
        entry.input_dim(),
        exec.num_inputs()
    );
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig { max_batch: 8, batch_timeout_us: 200, ..Default::default() },
    );
    let mut rng = Rng::new(seed);
    let mut bad = 0;
    for _ in 0..n {
        let x = rng.normal_vec(exec.num_inputs(), 1.0);
        let want = exec.execute_one(&x);
        match server.infer_model(name, x) {
            Ok(y) if y == want => {}
            Ok(y) => {
                eprintln!("{name:?}: served {y:?} != local {want:?}");
                bad += 1;
            }
            Err(e) => {
                eprintln!("{name:?}: serve round-trip failed: {e}");
                bad += 1;
            }
        }
    }
    let stats = server.shutdown();
    println!("  round-trip served {} requests through the registry", stats.requests);
    Ok(bad)
}

/// `compress --network`: the whole-model variant. Load (or synthesize,
/// for `--network demo`) a multi-layer checkpoint directory, run every
/// layer through its resolved per-layer recipe, verify the chained
/// `NetworkExecutor` against the hand-chained `NaiveExecutor` oracle
/// (bit-exact in float mode, within the propagated analytic bound in
/// fixed mode), then round-trip the network artifact through the
/// registry — which must auto-detect the directory — and the server.
fn compress_network(
    flags: &Flags,
    recipe: Recipe,
    src: &str,
    requests: usize,
    seed: u64,
) -> Result<()> {
    let (ckpt, name) = if src == "demo" {
        (demo_network(&[12, 10, 8, 6], seed), "demo-net".to_string())
    } else {
        let p = Path::new(src);
        let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("network").to_string();
        (NetworkCheckpoint::load(p)?, name)
    };
    println!(
        "compressing network {name:?}: {} layer(s), {} -> {} dims",
        ckpt.num_layers(),
        ckpt.input_dim(),
        ckpt.output_dim()
    );
    if recipe.exec.exec_mode == ExecMode::Fixed {
        println!(
            "exec mode: fixed shift-add (frac_bits {}, {}-bit {} accumulator)",
            recipe.exec.fixed_frac_bits,
            recipe.exec.fixed_acc.bits(),
            recipe.exec.fixed_sat.as_str()
        );
    }
    let metrics = Metrics::new();
    let net = NetworkPipeline::from_recipe(&recipe)?.run_with_metrics(&ckpt, &metrics)?;
    println!("{}", net.report().render());
    let mut failures = verify_network_against_oracle(&name, &net, requests, seed + 17)?;

    let tmp = std::env::temp_dir().join(format!("lccnn-compress-net-{}", std::process::id()));
    let (dir, ephemeral) = match flags.get("out") {
        Some(d) => (PathBuf::from(d), false),
        None => (tmp, true),
    };
    ckpt.save(&dir)?;
    recipe.save(&dir.join("recipe.toml"))?;
    std::fs::write(dir.join("report.tsv"), net.report().to_tsv())
        .with_context(|| format!("write {}", dir.join("report.tsv").display()))?;
    println!("artifact: {}", dir.display());
    failures += serve_roundtrip(&name, &dir, &net.executor()?, requests, seed + 23)?;
    if ephemeral {
        std::fs::remove_dir_all(&dir).ok();
    }
    println!("{}", metrics.render());
    if failures > 0 {
        bail!("{failures} verification mismatches");
    }
    println!(
        "compress: network {name:?} verified recipe -> artifact -> registry -> serve, {}",
        if recipe.exec.exec_mode == ExecMode::Fixed {
            "within the propagated error bound (serve round-trip bit-identical)"
        } else {
            "bit-identical to the hand-chained oracle"
        }
    );
    Ok(())
}

/// The network analogue of [`verify_against_oracle`]: the chained
/// batch-major engine vs per-layer `NaiveExecutor` graphs composed by
/// hand (`CompressedNetwork::oracle_forward`). Float chains must match
/// bit-exact; the fixed datapath is held to the network's propagated
/// bound — per-layer analytic bounds composed through the operator
/// inf-norms, ReLU being 1-Lipschitz — plus float-rounding slack.
fn verify_network_against_oracle(
    name: &str,
    net: &CompressedNetwork,
    n: usize,
    seed: u64,
) -> Result<usize> {
    let exec = net.executor()?;
    let bound = exec.max_error_bound();
    let mut rng = Rng::new(seed);
    let mut bad = 0;
    for _ in 0..n {
        let x = rng.normal_vec(exec.num_inputs(), 1.0);
        let got = exec.execute_one(&x);
        let want = net.oracle_forward(&x);
        let ok = if bound == 0.0 {
            got == want
        } else {
            got.len() == want.len()
                && got.iter().zip(&want).all(|(g, w)| {
                    ((g - w).abs() as f64) <= bound + 1e-3 * (1.0 + w.abs() as f64)
                })
        };
        if !ok {
            eprintln!(
                "{name:?}: network engine {got:?} != chained oracle {want:?} (bound {bound:e})"
            );
            bad += 1;
        }
    }
    Ok(bad)
}

/// The default gate recipe: prune + LCC (FS tuning). Weight sharing is
/// deliberately absent — affinity clustering over *trained*,
/// uncorrelated columns collapses the very features the net learned,
/// which is exactly the failure mode the accuracy gate exists to catch.
fn gate_default_recipe() -> Recipe {
    Recipe {
        stages: vec![StageSpec::Prune(PruneSpec::default()), StageSpec::Lcc(LccSpec::default())],
        gate_epsilon: Some(0.05),
        ..Recipe::default()
    }
}

/// `gate`: the accuracy gate. Train the paper's LeNet-300-100-shaped
/// MLP on `data::synth_mnist` (in-process SGD, deterministic given the
/// seed), compress it through the full-network pipeline, and fail —
/// nonzero exit — unless the compressed network's test accuracy stays
/// within `gate_epsilon` of the dense baseline. This is the CI leg that
/// keeps compression honest about end-task quality, not just SQNR.
fn cmd_gate(flags: Flags) -> Result<()> {
    let train_n: usize = flag(&flags, "train", 2000)?.max(1);
    let test_n: usize = flag(&flags, "test", 500)?.max(1);
    let steps: usize = flag(&flags, "steps", 300)?;
    let batch: usize = flag(&flags, "batch", 32)?.max(1);
    let lr: f32 = flag(&flags, "lr", 0.1)?;
    let seed: u64 = flag(&flags, "seed", 0)?;
    let base = match flags.get("recipe") {
        Some(p) => Recipe::from_toml(Path::new(p))?,
        None => gate_default_recipe(),
    };
    let mut recipe = Recipe::from_env_over(base);
    if let Some(m) = flags.get("exec-mode") {
        recipe.exec.exec_mode =
            ExecMode::parse(m).with_context(|| format!("--exec-mode {m:?} (use float|fixed)"))?;
    }
    let epsilon: f64 = flag(&flags, "epsilon", recipe.gate_epsilon.unwrap_or(0.05))?;
    anyhow::ensure!(epsilon > 0.0, "--epsilon must be positive");

    let (train, test) = synth_mnist::generate(train_n + test_n, seed).split_off(test_n);
    let mut mlp = Mlp3::lenet_300_100(seed + 1);
    mlp.train_sgd(&train, steps, batch, lr, seed + 2);
    let dense = mlp.accuracy(&test);
    println!(
        "dense baseline: {:.1}% on {} held-out examples ({} train, {steps} SGD steps)",
        100.0 * dense,
        test.len(),
        train.len()
    );

    let ckpt = mlp.to_network_checkpoint()?;
    let net = NetworkPipeline::from_recipe(&recipe)?.run(&ckpt)?;
    println!("{}", net.report().render());
    let exec = net.executor()?;
    let mut correct = 0usize;
    for i in 0..test.len() {
        if argmax(&exec.execute_one(test.example(i))) == test.labels[i] as usize {
            correct += 1;
        }
    }
    let compressed = correct as f64 / test.len() as f64;
    println!(
        "compressed accuracy: {:.1}% ({} mode, {:.1}x additions ratio, epsilon {epsilon})",
        100.0 * compressed,
        recipe.exec.exec_mode.as_str(),
        net.report().total_ratio()
    );
    if compressed + 1e-12 < dense - epsilon {
        bail!(
            "accuracy gate FAILED: compressed {:.3} < dense {:.3} - epsilon {epsilon}",
            compressed,
            dense
        );
    }
    println!("accuracy gate passed: {:.3} within {epsilon} of dense {:.3}", compressed, dense);
    Ok(())
}

/// `tune`: the recipe autotuner — sweep recipe space over a target
/// (demo matrix, checkpoint, or network) and keep the Pareto frontier,
/// closing the loop from `CompressionReport` back to `Recipe`. The
/// sweep axes come from `--spec tune.toml` (a `[tune]` section) layered
/// under `LCCNN_TUNE_*` env and the `--budget`/`--seed`/`--measure`
/// flags; `--recipe` sets the base recipe the axes are written over.
/// With `--out` the sweep directory gets one `recipe-<id>.toml` per
/// evaluated point, the frontier's cheapest as `best.toml`, the spec as
/// `tune.toml`, and `sweep.json`/`sweep.tsv`/`sweep.md` — every emitted
/// recipe re-runs through `compress --recipe` to bit-identical
/// additions/rel-err. Nonzero exit on an empty frontier.
fn cmd_tune(flags: Flags) -> Result<()> {
    let mut spec = TuneSpec::from_env_over(match flags.get("spec") {
        Some(p) => TuneSpec::from_toml(Path::new(p))?,
        None => TuneSpec::default(),
    });
    spec.budget = flag(&flags, "budget", spec.budget)?;
    spec.seed = flag(&flags, "seed", spec.seed)?;
    if let Some(v) = flags.get("measure") {
        spec.measure = !v.is_empty() && v != "0" && v != "false";
    }
    let base = match flags.get("recipe") {
        Some(p) => Recipe::from_toml(Path::new(p))?,
        None => Recipe::default(),
    };
    let seed = spec.seed;
    let result = if let Some(src) = flags.get("network") {
        let ckpt = if src == "demo" {
            demo_network(&[12, 10, 8, 6], seed)
        } else {
            NetworkCheckpoint::load(Path::new(src))?
        };
        tune::sweep_network(&spec, &base, &ckpt)?
    } else if let Some(ck) = flags.get("checkpoint") {
        tune::sweep_matrix(&spec, &base, &load_weight_matrix(Path::new(ck))?)?
    } else if flags.get("demo").is_some() {
        // the exact matrix `compress --demo 1 --seed <seed>` compresses
        // as job 0, so any emitted recipe round-trips through compress
        // to the numbers this sweep reports
        tune::sweep_matrix(&spec, &base, &demo_weights(24, 4, 4, seed))?
    } else {
        bail!("nothing to tune: pass --demo, --checkpoint w.npy or --network dir|demo");
    };
    println!("{}", result.render());
    println!(
        "frontier: {} of {} evaluated point(s) ({} in the full grid)",
        result.frontier().len(),
        result.points.len(),
        result.grid_size
    );
    if let Some(out) = flags.get("out") {
        let dir = PathBuf::from(out);
        result.write(&dir)?;
        spec.save(&dir.join("tune.toml"))?;
        println!("sweep artifacts: {}", dir.display());
    }
    if let Some(best) = result.best() {
        println!("best (fewest additions on the frontier): id {} ({})", best.id, best.label());
    }
    anyhow::ensure!(!result.frontier().is_empty(), "empty Pareto frontier: nothing evaluated");
    Ok(())
}

/// Parse an `a..b` output-column range.
fn parse_range(s: &str) -> Result<std::ops::Range<usize>> {
    let (a, b) = s.split_once("..").with_context(|| format!("--range {s:?} (use a..b)"))?;
    let lo: usize = a.trim().parse().map_err(|e| anyhow::anyhow!("--range {s:?}: {e}"))?;
    let hi: usize = b.trim().parse().map_err(|e| anyhow::anyhow!("--range {s:?}: {e}"))?;
    anyhow::ensure!(lo < hi, "--range {s:?} is empty");
    Ok(lo..hi)
}

/// `shard-worker`: load an artifact dir (recipe.toml + weight.npy),
/// build the pipeline executor restricted to one output-column range
/// and serve it over the remote batch protocol until the process is
/// killed. The range comes from `--shards N --index I` (the same even
/// cut the gathering server assumes) or an explicit `--range a..b`.
/// Network artifact directories serve a *layer* range instead
/// (`--layer-range a..b`, 0-based, default all layers): the worker runs
/// those layers — bias and activation included — so a chain of such
/// workers composes, hop by hop, into the full network.
fn cmd_shard_worker(flags: Flags) -> Result<()> {
    let artifact = flags.get("artifact").context("--artifact dir is required")?.clone();
    let listen = flags.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:0".to_string());
    let dir = Path::new(&artifact);
    let mut recipe = Recipe::from_env_over(Recipe::for_checkpoint(dir)?);
    if let Some(m) = flags.get("exec-mode") {
        recipe.exec.exec_mode =
            ExecMode::parse(m).with_context(|| format!("--exec-mode {m:?} (use float|fixed)"))?;
    }
    // never locally shard the range engine: the remote gather is the
    // shard layer, and the cut plan is one shard's worth of work
    recipe.shard = None;
    let mode = recipe.exec.exec_mode;
    let worker = if NetworkCheckpoint::is_network_dir(dir) {
        let ckpt = NetworkCheckpoint::load(dir)?;
        let layers = match flags.get("layer-range") {
            Some(r) => parse_range(r)?,
            None => 0..ckpt.num_layers(),
        };
        anyhow::ensure!(
            layers.end <= ckpt.num_layers(),
            "--layer-range {}..{} out of {} layers",
            layers.start,
            layers.end,
            ckpt.num_layers()
        );
        let net = NetworkPipeline::from_recipe(&recipe)?.run(&ckpt)?;
        let exec = net.layer_range_executor(layers.clone())?;
        let rows = exec.num_outputs();
        let worker = ShardWorker::spawn(Arc::new(exec), 0..rows, mode, &listen)?;
        println!(
            "shard-worker: {artifact} layers {}..{} ({} mode) on {}",
            layers.start,
            layers.end,
            mode.as_str(),
            worker.addr()
        );
        worker
    } else {
        let w = load_weight_matrix(dir)?;
        let model = Pipeline::from_recipe(&recipe)?.run(&w)?;
        let range = match flags.get("range") {
            Some(r) => parse_range(r)?,
            None => {
                let shards: usize = flag(&flags, "shards", 1)?.max(1);
                let index: usize = flag(&flags, "index", 0)?;
                anyhow::ensure!(index < shards, "--index {index} out of --shards {shards}");
                even_ranges(w.rows(), shards)[index].clone()
            }
        };
        let exec = model.range_executor(range.clone())?;
        let worker = ShardWorker::spawn(Arc::new(exec), range.clone(), mode, &listen)?;
        println!(
            "shard-worker: {artifact} rows {}..{} ({} mode) on {}",
            range.start,
            range.end,
            mode.as_str(),
            worker.addr()
        );
        worker
    };
    let drain_on = flags.get("drain-on").cloned();
    match drain_on {
        None => loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
        Some(path) => {
            // Graceful-drain hook: poll for the marker file, then stop
            // accepting new batches (in-flight ones finish, fresh Execs
            // get a typed ERR_DRAINING refusal) and exit cleanly.
            let marker = PathBuf::from(path);
            while !marker.exists() {
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
            worker.drain();
            while worker.in_flight() > 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            // small grace window so the last replies flush before exit
            std::thread::sleep(std::time::Duration::from_millis(200));
            drop(worker);
            println!("shard-worker: drained, exiting");
            Ok(())
        }
    }
}

/// `serve`: stand up the multi-model registry server and drive it with
/// synthetic traffic — the smoke/demo driver for a deployment.
///
/// Models come from (layered, all optional): `LCCNN_SERVE_MODELS` env,
/// `--config file.toml` (`[serve]` + `[serve.models]` +
/// `[serve.exec.<name>]`), repeatable `--model name=path` flags, and
/// `--demo N` synthetic LCC models. Checkpoints are 2-D `.npy` weights
/// (file or dir) compressed at load through a recipe: `--recipe r.toml`
/// (or `[serve] recipe` / `LCCNN_SERVE_RECIPE`) applies one recipe to
/// every load; otherwise artifact dirs carrying `recipe.toml` use it
/// and bare weights get the legacy LCC-only lowering.
fn cmd_serve(flags: Flags) -> Result<()> {
    let mut serve_cfg = ServeConfig::from_env();
    let mut specs: Vec<ModelSpec> = lccnn::config::serve_models_from_env();
    if let Some(cfg_path) = flags.get("config") {
        let p = Path::new(cfg_path);
        serve_cfg = ServeConfig::from_toml_over(p, serve_cfg)?;
        specs.extend(lccnn::config::serve_models_from_toml(p)?);
    }
    for s in flags.get_all("model") {
        specs.push(ModelSpec::parse(s).with_context(|| format!("--model {s:?} (use name=path)"))?);
    }
    serve_cfg.max_batch = flag(&flags, "max-batch", serve_cfg.max_batch)?.max(1);
    serve_cfg.batch_timeout_us = flag(&flags, "timeout-us", serve_cfg.batch_timeout_us)?;
    let demo: usize = flag(&flags, "demo", 0)?;
    let requests: usize = flag(&flags, "requests", 256)?;
    let clients: usize = flag(&flags, "client-threads", 4)?.max(1);
    let seed: u64 = flag(&flags, "seed", 0)?;
    // --recheck-delay-ms: rerun the --remote-check pass once more after
    // this pause — a window for killing and restarting a worker so the
    // half-open probe's recovery is exercised end to end.
    let recheck_delay_ms: u64 = flag(&flags, "recheck-delay-ms", 0)?;
    // --client-delay-ms: pace each hammer request so an external script
    // can inject faults (e.g. kill a replica) while traffic is in flight.
    let client_delay_ms: u64 = flag(&flags, "client-delay-ms", 0)?;

    // --shards N shards every engine this process builds: demo/graph
    // models via ExecConfig::shards, checkpoint loads via the recipe
    let shards: usize = flag(&flags, "shards", 0)?;
    let mut base_exec = ExecConfig::from_env();
    if shards > 0 {
        base_exec.shards = shards;
    }
    // --exec-mode overrides env/recipe for every engine this process
    // builds (demo/graph models via base_exec, checkpoints via recipe)
    let exec_mode: Option<ExecMode> = match flags.get("exec-mode") {
        Some(m) => Some(
            ExecMode::parse(m).with_context(|| format!("--exec-mode {m:?} (use float|fixed)"))?,
        ),
        None => None,
    };
    if let Some(m) = exec_mode {
        base_exec.exec_mode = m;
    }
    let registry = Arc::new(ModelRegistry::new());
    // compression recipe for checkpoint loads: --recipe flag > [serve]
    // recipe key / LCCNN_SERVE_RECIPE > per-checkpoint discovery (artifact
    // dirs carrying recipe.toml; LCC-only fallback for bare weights)
    let recipe_path = flags.get("recipe").cloned().or_else(|| serve_cfg.recipe.clone());
    let shared_recipe: Option<Recipe> = match &recipe_path {
        Some(p) => Some(Recipe::from_env_over(Recipe::from_toml(Path::new(p))?)),
        None => None,
    };
    for spec in &specs {
        let mut recipe = match &shared_recipe {
            Some(r) => r.clone(),
            None => Recipe::for_checkpoint(Path::new(&spec.path))?,
        };
        if let Some(e) = spec.exec {
            recipe.exec = e; // per-model [serve.exec.<name>] wins
        }
        if shards > 0 {
            recipe.shard = Some(ShardSpec { shards, mode: recipe.exec.shard_mode });
        }
        if let Some(m) = exec_mode {
            recipe.exec.exec_mode = m;
        }
        let entry = registry.load_checkpoint_with_recipe(
            &spec.name,
            Path::new(&spec.path),
            Some(&recipe),
            serve_cfg.max_batch,
        )?;
        println!(
            "loaded {:?} from {} ({:?} inputs, {} shard(s))",
            spec.name,
            spec.path,
            entry.input_dim(),
            recipe.shard_spec().map(|s| s.shards).unwrap_or(1)
        );
    }
    let mut rng = Rng::new(seed);
    for i in 0..demo {
        // distinct shapes per demo model so routing bugs show up as
        // arity errors instead of silently-wrong numbers
        let (rows, cols) = (48 + 16 * i, 12 + 4 * i);
        let w = Matrix::randn(rows, cols, 0.5, &mut rng);
        let d = decompose(&w, &LccConfig::fs());
        let name = format!("demo-{i}");
        registry.register_graph(&name, d.graph(), base_exec, serve_cfg.max_batch);
        println!("demo model {name:?}: {rows}x{cols} weight, LCC graph {} adds", d.additions());
    }

    // --remote-shard host:port (repeatable, after [serve] remote_shards /
    // LCCNN_SERVE_REMOTE_SHARDS) gathers shard-worker processes behind
    // one model entry; shard failure counters land on remote_metrics
    let mut remote_addrs = serve_cfg.remote_shards.clone();
    remote_addrs.extend(flags.get_all("remote-shard").iter().cloned());
    let remote_name = flags.get("remote-name").cloned().unwrap_or_else(|| "remote".to_string());
    let remote_metrics = Arc::new(Metrics::new());
    if !remote_addrs.is_empty() {
        let opts = RemoteOptions::from_config(&serve_cfg.remote);
        let entry = registry.register_remote_sharded(
            &remote_name,
            &remote_addrs,
            opts,
            base_exec,
            Arc::clone(&remote_metrics),
            serve_cfg.max_batch,
        )?;
        println!(
            "remote model {remote_name:?}: {} shard(s) [{}], {:?} inputs",
            remote_addrs.len(),
            remote_addrs.join(", "),
            entry.input_dim()
        );
    }
    // --remote-layer host:port (repeatable, ordered): each address is a
    // shard-worker serving a *layer range* of a network artifact; the
    // hops chain — output of one feeds the next — into one served model
    let layer_addrs: Vec<String> = flags.get_all("remote-layer").to_vec();
    let layer_name =
        flags.get("remote-layer-name").cloned().unwrap_or_else(|| "remote-layers".to_string());
    if !layer_addrs.is_empty() {
        let mut hops: Vec<Arc<dyn Executor>> = Vec::with_capacity(layer_addrs.len());
        for (i, addr) in layer_addrs.iter().enumerate() {
            let opts = RemoteOptions::from_config(&serve_cfg.remote);
            let remote = RemoteExecutor::connect(addr, opts)
                .map_err(|e| anyhow::anyhow!("remote layer hop {addr}: {e}"))?
                .with_metrics(Arc::clone(&remote_metrics), &format!("layer_hop.{i}"));
            hops.push(Arc::new(remote));
        }
        let chain = ChainedExecutor::new(hops)?;
        println!(
            "remote layer chain {layer_name:?}: {} hop(s) [{}], {} -> {} dims",
            layer_addrs.len(),
            layer_addrs.join(" -> "),
            chain.num_inputs(),
            chain.num_outputs()
        );
        registry.register(&layer_name, Arc::new(chain), base_exec, serve_cfg.max_batch);
    }
    // --remote-check dir: rebuild the artifact locally and hold the
    // remote gather to bit-identical answers (the CI remote smoke)
    let remote_oracle: Option<lccnn::compress::PipelineExecutor> = match flags.get("remote-check") {
        Some(dir) if !remote_addrs.is_empty() => {
            let p = Path::new(dir);
            let mut recipe = Recipe::from_env_over(Recipe::for_checkpoint(p)?);
            if let Some(m) = exec_mode {
                recipe.exec.exec_mode = m;
            }
            let w = load_weight_matrix(p)?;
            Some(Pipeline::from_recipe(&recipe)?.run(&w)?.into_executor())
        }
        Some(_) => bail!("--remote-check needs at least one remote shard"),
        None => None,
    };
    // --remote-layer-check dir: rebuild the full network locally and
    // hold the chained layer hops to bit-identical answers — worker
    // rebuilds are deterministic, so even fixed-mode hops must match
    let layer_oracle: Option<NetworkExecutor> = match flags.get("remote-layer-check") {
        Some(dir) if !layer_addrs.is_empty() => {
            let p = Path::new(dir);
            let mut recipe = Recipe::from_env_over(Recipe::for_checkpoint(p)?);
            if let Some(m) = exec_mode {
                recipe.exec.exec_mode = m;
            }
            recipe.shard = None; // mirror the workers' unsharded rebuild
            let ckpt = NetworkCheckpoint::load(p)?;
            Some(NetworkPipeline::from_recipe(&recipe)?.run(&ckpt)?.into_executor()?)
        }
        Some(_) => bail!("--remote-layer-check needs at least one --remote-layer hop"),
        None => None,
    };

    if registry.is_empty() {
        bail!("no models to serve: pass --model name=path, --config file.toml or --demo N");
    }

    let names = registry.names();
    println!(
        "serving {} model(s) [{}] with max_batch {} timeout {}us, {} client thread(s) x {} requests",
        names.len(),
        names.join(", "),
        serve_cfg.max_batch,
        serve_cfg.batch_timeout_us,
        clients,
        requests,
    );
    // every enabled oracle check runs through the same harness: fresh
    // deterministic traffic, served answers held bit-exact to the local
    // rebuild, with an optional recheck pass after the recovery window
    let mut checks: Vec<(&str, Arc<dyn Executor>)> = Vec::new();
    if let Some(o) = remote_oracle {
        checks.push((remote_name.as_str(), Arc::new(o)));
    }
    if let Some(o) = layer_oracle {
        checks.push((layer_name.as_str(), Arc::new(o)));
    }
    let server = Server::start_registry(Arc::clone(&registry), serve_cfg);
    let mut check_failures = 0usize;
    for (ci, (check_name, oracle)) in checks.iter().enumerate() {
        let n = requests.clamp(1, 64);
        let passes = if recheck_delay_ms > 0 { 2 } else { 1 };
        for pass in 0..passes {
            if pass > 0 {
                println!("{check_name:?} check: recheck in {recheck_delay_ms}ms (recovery window)");
                std::thread::sleep(std::time::Duration::from_millis(recheck_delay_ms));
            }
            let mut pass_failures = 0usize;
            let mut crng = rng.fork(997 + pass + 131 * ci as u64);
            for _ in 0..n {
                let x = crng.normal_vec(oracle.num_inputs(), 1.0);
                let want = oracle.execute_one(&x);
                match server.infer_model(check_name, x) {
                    Ok(y) if y == want => {}
                    Ok(y) => {
                        eprintln!("{check_name:?} check: served {y:?} != local {want:?}");
                        pass_failures += 1;
                    }
                    Err(e) => {
                        eprintln!("{check_name:?} check: request failed: {e}");
                        pass_failures += 1;
                    }
                }
            }
            println!(
                "{check_name:?} check pass {}: {n} request(s) vs local rebuild, {pass_failures} \
                 mismatch(es)",
                pass + 1
            );
            check_failures += pass_failures;
        }
    }
    let per_client = requests.div_ceil(clients);
    let errors = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..clients {
            let server = &server;
            let registry = &registry;
            let names = &names;
            let errors = &errors;
            let mut rng = rng.fork(t as u64 + 1);
            scope.spawn(move || {
                for k in 0..per_client {
                    if client_delay_ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(client_delay_ms));
                    }
                    let name = &names[(t + k) % names.len()];
                    let Some(dim) = registry.get(name).and_then(|e| e.input_dim()) else {
                        errors.fetch_add(1, Ordering::Relaxed);
                        continue;
                    };
                    match server.infer_model(name, rng.normal_vec(dim, 1.0)) {
                        Ok(y) if !y.is_empty() => {}
                        Ok(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            eprintln!("request to {name:?} failed: {e}");
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });

    let mut t = Table::new(
        "per-model serving stats",
        &["model", "requests", "batches", "mean batch", "p50 us", "p99 us"],
    );
    // enumerate from the metrics (covers models hot-removed mid-run),
    // falling back to the roster if nothing was served
    let mut seen = server.models_seen();
    if seen.is_empty() {
        seen = names.clone();
    }
    for name in &seen {
        let s = server.model_stats(name);
        t.add_row(vec![
            name.clone(),
            s.requests.to_string(),
            s.batches.to_string(),
            format!("{:.1}", s.mean_batch_size),
            format!("{:.1}", s.p50_latency_us),
            format!("{:.1}", s.p99_latency_us),
        ]);
    }
    println!("{}", t.render());
    println!("{}", server.metrics_text());
    if !remote_addrs.is_empty() {
        println!("remote shard metrics:\n{}", remote_metrics.render());
    }
    let stats = server.shutdown();
    let failed = errors.load(Ordering::Relaxed);
    if failed + check_failures > 0 {
        bail!(
            "{failed} of {} requests failed, {check_failures} remote check mismatch(es)",
            clients * per_client
        );
    }
    println!("served {} requests across {} models, 0 errors", stats.requests, names.len());
    Ok(())
}

const USAGE: &str = "usage: lccnn <info|fig2|table1|decompose|compress|gate|tune|serve|\
                     shard-worker> [--flag value ...]\n(`lccnn <cmd> --help` or `lccnn help \
                     <cmd>` prints each command's flags)";

/// Per-subcommand usage text (`lccnn <cmd> --help`). Flag coverage here
/// is the contract README documents — keep the three in sync.
fn help_text(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "info" => "usage: lccnn info\n\nList runtime artifacts and the platform.",
        "fig2" => {
            "usage: lccnn fig2 [--lambda F] [--steps N] [--retrain-steps N] \
             [--train-examples N] [--seed N] [--lcc fp|fs]\n\n\
             Run the Fig. 2 MLP pipeline (prune -> share -> LCC with accuracy per stage) \
             for one regularization strength lambda."
        }
        "table1" => {
            "usage: lccnn table1 [--steps N] [--lambda F] [--train-examples N] \
             [--eval-limit N] [--seed N]\n\n\
             Run the Table-I residual-CNN pipeline (FK and PK compression points)."
        }
        "decompose" => {
            "usage: lccnn decompose [--rows N] [--cols K] [--seed N]\n\n\
             LCC (FP and FS) vs CSD additions, SQNR and graph depth on a random matrix."
        }
        "compress" => {
            "usage: lccnn compress [--recipe r.toml] [--checkpoint w.npy | --demo N | \
             --network dir|demo] [--out dir] [--shards N] [--exec-mode float|fixed] \
             [--requests N] [--seed N]\n\n\
             Run a compression recipe end to end: raw weights -> pruned/shared/LCC'd \
             artifact -> served engine, self-verified against the NaiveExecutor oracle \
             and a registry serve round-trip (nonzero exit on any mismatch). --demo N \
             compresses N synthetic matrices; --network compresses a multi-layer \
             checkpoint directory through the per-layer recipe path and verifies the \
             chained NetworkExecutor."
        }
        "gate" => {
            "usage: lccnn gate [--recipe r.toml] [--epsilon F] [--steps N] [--train N] \
             [--test N] [--batch N] [--lr F] [--seed N] [--exec-mode float|fixed]\n\n\
             The accuracy gate: train the LeNet-300-100-shaped MLP on synth-MNIST, \
             compress it as a network, and fail (nonzero exit) unless the compressed \
             accuracy stays within epsilon of the dense baseline."
        }
        "tune" => {
            "usage: lccnn tune [--spec tune.toml] [--demo | --checkpoint w.npy | \
             --network dir|demo] [--budget N] [--seed N] [--measure] [--out dir] \
             [--recipe base.toml]\n\n\
             Recipe autotuner: sweep prune thresholds x share scales x LCC algo/width x \
             exec mode x shard counts over the target, score every candidate on \
             (additions, rel-err), and flag the Pareto frontier. --budget N evaluates a \
             seeded subsample of the grid; --measure also times each served engine \
             (us/sample); --out emits recipe-<id>.toml per point, best.toml, tune.toml \
             and sweep.json/tsv/md. --demo and --measure are bare flags (no value). \
             Axes come from --spec / LCCNN_TUNE_* env over the built-in default grid."
        }
        "serve" => {
            "usage: lccnn serve [--model name=path]... [--config file.toml] [--demo N] \
             [--recipe r.toml] [--shards N] [--exec-mode float|fixed] [--max-batch N] \
             [--timeout-us N] [--requests N] [--client-threads N] [--seed N] \
             [--remote-shard host:port[|host:port...]]... [--remote-name name] \
             [--remote-check artifact-dir] [--recheck-delay-ms MS] [--client-delay-ms MS] \
             [--remote-layer host:port]... [--remote-layer-name name] \
             [--remote-layer-check network-dir]\n\n\
             Multi-model registry server driver. Remote shards gather behind one model \
             (`|`-joined addresses are replicas of the same range); repeated \
             --remote-layer flags chain layer-range workers into one served model; the \
             --remote-check/--remote-layer-check oracles hold served answers bit-exact \
             to a local rebuild."
        }
        "shard-worker" => {
            "usage: lccnn shard-worker --artifact dir [--listen host:port] \
             [--shards N --index I | --range a..b | --layer-range a..b] \
             [--exec-mode float|fixed] [--drain-on path]\n\n\
             Serve one output-column range (or, for network artifact dirs, one 0-based \
             layer range) of an artifact over the remote batch protocol until killed. \
             With --drain-on the worker polls for that file, then drains (in-flight \
             batches finish, new ones get a typed refusal) and exits cleanly."
        }
        _ => return None,
    })
}

fn main() -> Result<()> {
    logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{USAGE}");
            return Ok(());
        }
    };
    if cmd == "help" || cmd == "--help" || cmd == "-h" {
        match rest.first().map(String::as_str).and_then(help_text) {
            Some(h) => println!("{h}"),
            None => println!("{USAGE}"),
        }
        return Ok(());
    }
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        match help_text(cmd) {
            Some(h) => {
                println!("{h}");
                return Ok(());
            }
            None => bail!("unknown command {cmd:?}"),
        }
    }
    match cmd {
        "info" => cmd_info(),
        "fig2" => cmd_fig2(parse_flags(&rest)?),
        "table1" => cmd_table1(parse_flags(&rest)?),
        "decompose" => cmd_decompose(parse_flags(&rest)?),
        "compress" => cmd_compress(parse_flags(&rest)?),
        "gate" => cmd_gate(parse_flags(&rest)?),
        "tune" => cmd_tune(parse_flags(&normalize_bool_flags(&rest, &["demo", "measure"]))?),
        "serve" => cmd_serve(parse_flags(&rest)?),
        "shard-worker" => cmd_shard_worker(parse_flags(&rest)?),
        other => bail!("unknown command {other:?}"),
    }
}
