//! Procedural 28×28 digit dataset — the MNIST stand-in.
//!
//! Digits are rendered as seven-segment strokes with per-sample affine
//! jitter (shift/scale/rotation), stroke-thickness variation, Gaussian
//! blur and pixel noise, giving a learnable 10-class task with the exact
//! MNIST geometry (784-d inputs) the paper's MLP experiment uses.

use super::Dataset;
use crate::util::Rng;

pub const SIDE: usize = 28;
pub const DIMS: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// Segment endpoints in a unit box (x0, y0, x1, y1); standard 7-seg
/// layout: 0 top, 1 top-left, 2 top-right, 3 middle, 4 bottom-left,
/// 5 bottom-right, 6 bottom.
const SEGMENTS: [(f32, f32, f32, f32); 7] = [
    (0.2, 0.1, 0.8, 0.1),
    (0.2, 0.1, 0.2, 0.5),
    (0.8, 0.1, 0.8, 0.5),
    (0.2, 0.5, 0.8, 0.5),
    (0.2, 0.5, 0.2, 0.9),
    (0.8, 0.5, 0.8, 0.9),
    (0.2, 0.9, 0.8, 0.9),
];

const DIGIT_SEGMENTS: [&[usize]; 10] = [
    &[0, 1, 2, 4, 5, 6],    // 0
    &[2, 5],                // 1
    &[0, 2, 3, 4, 6],       // 2
    &[0, 2, 3, 5, 6],       // 3
    &[1, 2, 3, 5],          // 4
    &[0, 1, 3, 5, 6],       // 5
    &[0, 1, 3, 4, 5, 6],    // 6
    &[0, 2, 5],             // 7
    &[0, 1, 2, 3, 4, 5, 6], // 8
    &[0, 1, 2, 3, 5, 6],    // 9
];

fn render_digit(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    out.fill(0.0);
    let cx = 0.5 + rng.range_f64(-0.07, 0.07) as f32;
    let cy = 0.5 + rng.range_f64(-0.07, 0.07) as f32;
    let scale = rng.range_f64(0.8, 1.15) as f32;
    let theta = rng.range_f64(-0.18, 0.18) as f32;
    let (sin, cos) = theta.sin_cos();
    let thickness = rng.range_f64(1.0, 1.8) as f32;
    let tf = |x: f32, y: f32| -> (f32, f32) {
        // center, scale, rotate, recenter, to pixels
        let (dx, dy) = ((x - 0.5) * scale, (y - 0.5) * scale);
        let (rx, ry) = (dx * cos - dy * sin, dx * sin + dy * cos);
        ((rx + cx) * SIDE as f32, (ry + cy) * SIDE as f32)
    };
    for &seg in DIGIT_SEGMENTS[digit] {
        let (x0, y0, x1, y1) = SEGMENTS[seg];
        let (px0, py0) = tf(x0, y0);
        let (px1, py1) = tf(x1, y1);
        let len = ((px1 - px0).powi(2) + (py1 - py0).powi(2)).sqrt();
        let steps = (len * 2.0).ceil().max(2.0) as usize;
        for s in 0..=steps {
            let t = s as f32 / steps as f32;
            let (sx, sy) = (px0 + t * (px1 - px0), py0 + t * (py1 - py0));
            // splat a Gaussian brush
            let r = thickness.ceil() as isize + 1;
            for dy in -r..=r {
                for dx in -r..=r {
                    let (ix, iy) = (sx as isize + dx, sy as isize + dy);
                    if ix < 0 || iy < 0 || ix >= SIDE as isize || iy >= SIDE as isize {
                        continue;
                    }
                    let d2 = ((ix as f32 - sx).powi(2) + (iy as f32 - sy).powi(2))
                        / (thickness * thickness);
                    let v = (-d2).exp();
                    let p = &mut out[iy as usize * SIDE + ix as usize];
                    *p = (*p + v * 0.8).min(1.0);
                }
            }
        }
    }
    // pixel noise
    for p in out.iter_mut() {
        *p = (*p + 0.04 * rng.normal_f32()).clamp(0.0, 1.0);
    }
}

/// Generate `n` examples with balanced random classes.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * DIMS];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = i % CLASSES; // balanced
        render_digit(digit, &mut rng, &mut images[i * DIMS..(i + 1) * DIMS]);
        labels.push(digit as i32);
    }
    // shuffle examples jointly
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut ds = Dataset { images: vec![0.0; n * DIMS], labels: vec![0; n], dims: DIMS };
    for (new_i, &old_i) in order.iter().enumerate() {
        ds.images[new_i * DIMS..(new_i + 1) * DIMS]
            .copy_from_slice(&images[old_i * DIMS..(old_i + 1) * DIMS]);
        ds.labels[new_i] = labels[old_i];
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = generate(50, 7);
        let b = generate(50, 7);
        assert_eq!(a.len(), 50);
        assert_eq!(a.dims, 784);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn pixels_in_range_and_nontrivial() {
        let d = generate(30, 1);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mean: f32 = d.images.iter().sum::<f32>() / d.images.len() as f32;
        assert!(mean > 0.02 && mean < 0.6, "mean {mean}");
    }

    #[test]
    fn classes_balanced() {
        let d = generate(100, 2);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // nearest-mean classifier on raw pixels should beat chance by a lot
        let train = generate(400, 3);
        let test = generate(100, 4);
        let mut means = vec![vec![0.0f32; DIMS]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..train.len() {
            let l = train.labels[i] as usize;
            counts[l] += 1;
            for (m, &v) in means[l].iter_mut().zip(train.example(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let x = test.example(i);
            let pred = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = x.iter().zip(&means[a]).map(|(&p, &q)| (p - q) * (p - q)).sum();
                    let db: f32 = x.iter().zip(&means[b]).map(|(&p, &q)| (p - q) * (p - q)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == test.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 60, "nearest-mean accuracy {correct}/100");
    }
}
