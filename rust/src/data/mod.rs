//! Synthetic datasets (DESIGN.md Substitutions: no MNIST/TinyImageNet on
//! this host, so procedurally generated stand-ins with the same shapes
//! and task structure drive the pipeline end to end).

pub mod synth_mnist;
pub mod synth_tiny;

use crate::util::Rng;

/// A labelled dataset of flattened f32 examples.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// row-major: example i occupies [i*dims, (i+1)*dims)
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub dims: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn example(&self, i: usize) -> &[f32] {
        &self.images[i * self.dims..(i + 1) * self.dims]
    }

    /// Gather a batch by indices into contiguous buffers.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.dims);
        let mut ys = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.example(i));
            ys.push(self.labels[i]);
        }
        (xs, ys)
    }

    /// Split off the last `n` examples as a held-out set.
    pub fn split_off(mut self, n: usize) -> (Dataset, Dataset) {
        assert!(n <= self.len());
        let keep = self.len() - n;
        let test = Dataset {
            images: self.images.split_off(keep * self.dims),
            labels: self.labels.split_off(keep),
            dims: self.dims,
        };
        (self, test)
    }
}

/// Shuffled epoch iterator producing fixed-size batches (drops the
/// ragged tail, as the AOT artifacts have a fixed batch dimension).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { data, batch, order, pos: 0, rng }
    }

    /// Next batch, reshuffling at epoch end. Returns (x, y, new_epoch).
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>, bool) {
        let mut new_epoch = false;
        if self.pos + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            new_epoch = true;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        let (x, y) = self.data.gather(idx);
        (x, y, new_epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            images: (0..20).map(|v| v as f32).collect(),
            labels: (0..10).collect(),
            dims: 2,
        }
    }

    #[test]
    fn gather_batches() {
        let d = toy();
        let (x, y) = d.gather(&[1, 3]);
        assert_eq!(x, vec![2.0, 3.0, 6.0, 7.0]);
        assert_eq!(y, vec![1, 3]);
    }

    #[test]
    fn split_off_sizes() {
        let (train, test) = toy().split_off(3);
        assert_eq!(train.len(), 7);
        assert_eq!(test.len(), 3);
        assert_eq!(test.labels, vec![7, 8, 9]);
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let d = toy();
        let mut it = BatchIter::new(&d, 3, 0);
        let mut seen = 0;
        let mut epochs = 0;
        for _ in 0..6 {
            let (_, y, new_epoch) = it.next_batch();
            assert_eq!(y.len(), 3);
            if new_epoch {
                epochs += 1;
            }
            seen += 3;
        }
        assert!(seen >= 10 && epochs >= 1);
    }
}
