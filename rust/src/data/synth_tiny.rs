//! Procedural 32×32×3 shape/texture dataset — the TinyImageNet stand-in
//! (DESIGN.md Substitutions), 40 classes = 8 shapes × 5 color palettes.

use super::Dataset;
use crate::util::Rng;

pub const SIDE: usize = 32;
pub const CHANNELS: usize = 3;
pub const DIMS: usize = SIDE * SIDE * CHANNELS;
pub const SHAPES: usize = 8;
pub const PALETTES: usize = 5;
pub const CLASSES: usize = SHAPES * PALETTES;

const PALETTE_RGB: [[f32; 3]; PALETTES] = [
    [0.9, 0.2, 0.2],
    [0.2, 0.8, 0.3],
    [0.25, 0.35, 0.95],
    [0.9, 0.8, 0.2],
    [0.8, 0.3, 0.85],
];

fn shape_mask(shape: usize, fx: f32, fy: f32, size: f32, rot: f32) -> f32 {
    // fx, fy in [-1, 1] centered coords (already jitter-shifted)
    let (s, c) = rot.sin_cos();
    let x = fx * c - fy * s;
    let y = fx * s + fy * c;
    let r = (x * x + y * y).sqrt();
    match shape {
        0 => ((size - r) * 8.0).clamp(0.0, 1.0),                     // disc
        1 => {
            let ring = (r - size * 0.75).abs();
            ((size * 0.25 - ring) * 10.0).clamp(0.0, 1.0)            // ring
        }
        2 => {
            let d = x.abs().max(y.abs());
            ((size - d) * 8.0).clamp(0.0, 1.0)                       // square
        }
        3 => {
            let d = x.abs() + y.abs();
            ((size - d) * 8.0).clamp(0.0, 1.0)                       // diamond
        }
        4 => {
            // triangle: inside y > -size/2 and below the two slanted edges
            let inside = y > -size * 0.6
                && y < size * 0.9 - 2.0 * x.abs();
            if inside { 1.0 } else { 0.0 }
        }
        5 => (0.5 + 0.5 * (x * std::f32::consts::PI * 4.0 / size).sin()).powi(2), // v stripes
        6 => (0.5 + 0.5 * (y * std::f32::consts::PI * 4.0 / size).sin()).powi(2), // h stripes
        _ => {
            let cxs = (x * std::f32::consts::PI * 3.0 / size).sin();
            let cys = (y * std::f32::consts::PI * 3.0 / size).sin();
            if cxs * cys > 0.0 { 1.0 } else { 0.0 }                  // checker
        }
    }
}

fn render(class: usize, rng: &mut Rng, out: &mut [f32]) {
    let shape = class % SHAPES;
    let palette = class / SHAPES;
    let base = PALETTE_RGB[palette];
    let cx = rng.range_f64(-0.25, 0.25) as f32;
    let cy = rng.range_f64(-0.25, 0.25) as f32;
    let size = rng.range_f64(0.45, 0.75) as f32;
    let rot = rng.range_f64(-0.5, 0.5) as f32;
    let tint: [f32; 3] = [
        (base[0] + 0.1 * rng.normal_f32()).clamp(0.05, 1.0),
        (base[1] + 0.1 * rng.normal_f32()).clamp(0.05, 1.0),
        (base[2] + 0.1 * rng.normal_f32()).clamp(0.05, 1.0),
    ];
    let bg = rng.range_f64(0.0, 0.25) as f32;
    for py in 0..SIDE {
        for px in 0..SIDE {
            let fx = (px as f32 / SIDE as f32) * 2.0 - 1.0 - cx;
            let fy = (py as f32 / SIDE as f32) * 2.0 - 1.0 - cy;
            let m = shape_mask(shape, fx, fy, size, rot);
            for ch in 0..CHANNELS {
                let v = bg * (1.0 - m) + tint[ch] * m + 0.03 * rng.normal_f32();
                out[(py * SIDE + px) * CHANNELS + ch] = v.clamp(0.0, 1.0);
            }
        }
    }
}

/// Generate `n` examples with balanced classes (NHWC flattened).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * DIMS];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % CLASSES;
        render(class, &mut rng, &mut images[i * DIMS..(i + 1) * DIMS]);
        labels.push(class as i32);
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut ds = Dataset { images: vec![0.0; n * DIMS], labels: vec![0; n], dims: DIMS };
    for (new_i, &old_i) in order.iter().enumerate() {
        ds.images[new_i * DIMS..(new_i + 1) * DIMS]
            .copy_from_slice(&images[old_i * DIMS..(old_i + 1) * DIMS]);
        ds.labels[new_i] = labels[old_i];
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let a = generate(40, 0);
        let b = generate(40, 0);
        assert_eq!(a.images, b.images);
        assert_eq!(a.dims, 32 * 32 * 3);
        assert!(a.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn covers_all_classes() {
        let d = generate(CLASSES * 2, 1);
        let mut seen = vec![0usize; CLASSES];
        for &l in &d.labels {
            seen[l as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c == 2), "{seen:?}");
    }

    #[test]
    fn classes_differ_visually() {
        // mean intensity per class should not all collapse to one value
        let d = generate(CLASSES * 4, 2);
        let mut per_class = vec![Vec::new(); CLASSES];
        for i in 0..d.len() {
            let m: f32 = d.example(i).iter().sum::<f32>() / DIMS as f32;
            per_class[d.labels[i] as usize].push(m);
        }
        let means: Vec<f32> = per_class
            .iter()
            .map(|v| v.iter().sum::<f32>() / v.len() as f32)
            .collect();
        let spread = means.iter().cloned().fold(f32::MIN, f32::max)
            - means.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 0.05, "class means too similar: {spread}");
    }
}
