//! The stage interface and the paper's four built-in stages.
//!
//! A [`Stage`] transforms a [`ModelState`] in place; ordering contracts
//! live on the state's mutators, so a stage cannot corrupt the artifact.
//! [`super::Pipeline`] composes stages from [`StageSpec`]s (serializable)
//! or from custom boxed implementations (not serializable, but fully
//! composable — e.g. a re-scaling or permutation pass between prune and
//! share).

use super::recipe::StageSpec;
use super::state::ModelState;
use crate::cluster::affinity::AffinityParams;
use crate::config::ExecConfig;
use crate::lcc::LccConfig;
use crate::quant::FixedPointFormat;
use anyhow::Result;

/// One transformation of the compression artifact.
pub trait Stage: Send + Sync {
    /// Short stage name for reports and errors.
    fn name(&self) -> &'static str;

    /// Apply the transformation.
    fn apply(&self, state: &mut ModelState) -> Result<()>;
}

/// Drop near-zero columns and compact the matrix (paper Sec. III-B).
#[derive(Clone, Copy, Debug)]
pub struct PruneStage {
    pub eps: f32,
}

impl Stage for PruneStage {
    fn name(&self) -> &'static str {
        "prune"
    }

    fn apply(&self, state: &mut ModelState) -> Result<()> {
        state.apply_prune(self.eps)
    }
}

/// Tie correlated columns to shared centroids (paper Sec. III-C).
#[derive(Clone, Copy, Debug)]
pub struct ShareStage {
    pub params: AffinityParams,
}

impl Stage for ShareStage {
    fn name(&self) -> &'static str {
        "share"
    }

    fn apply(&self, state: &mut ModelState) -> Result<()> {
        state.apply_share(&self.params)
    }
}

/// Snap the live coefficients to a fixed-point grid (the CSD baseline's
/// quantization, applied explicitly when LCC is not the final stage).
#[derive(Clone, Copy, Debug)]
pub struct QuantizeStage {
    pub fmt: FixedPointFormat,
}

impl Stage for QuantizeStage {
    fn name(&self) -> &'static str {
        "quantize"
    }

    fn apply(&self, state: &mut ModelState) -> Result<()> {
        state.apply_quantize(self.fmt)
    }
}

/// Decompose the live coefficients into a shift-add adder graph and
/// lower it to the batch-major engine (paper Sec. III-A). Terminal.
#[derive(Clone, Copy, Debug)]
pub struct LccStage {
    pub cfg: LccConfig,
    pub exec: ExecConfig,
}

impl Stage for LccStage {
    fn name(&self) -> &'static str {
        "lcc"
    }

    fn apply(&self, state: &mut ModelState) -> Result<()> {
        state.apply_lcc(&self.cfg, self.exec)
    }
}

impl StageSpec {
    /// Instantiate the stage a spec describes; `exec` is the pipeline's
    /// engine tuning (only the LCC lowering consumes it).
    pub fn to_stage(&self, exec: ExecConfig) -> Box<dyn Stage> {
        match self {
            StageSpec::Prune(p) => Box::new(PruneStage { eps: p.eps }),
            StageSpec::Share(s) => Box::new(ShareStage { params: s.to_params() }),
            StageSpec::Quantize(q) => Box::new(QuantizeStage { fmt: q.to_format() }),
            StageSpec::Lcc(l) => Box::new(LccStage { cfg: l.to_config(), exec }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::demo_weights;
    use crate::compress::recipe::{LccSpec, PruneSpec, QuantSpec, ShareSpec};

    #[test]
    fn specs_instantiate_matching_stages() {
        let exec = ExecConfig::serial();
        let names: Vec<&str> = [
            StageSpec::Prune(PruneSpec::default()),
            StageSpec::Share(ShareSpec::default()),
            StageSpec::Quantize(QuantSpec::default()),
            StageSpec::Lcc(LccSpec::default()),
        ]
        .iter()
        .map(|s| s.to_stage(exec).name())
        .collect();
        assert_eq!(names, vec!["prune", "share", "quantize", "lcc"]);
    }

    #[test]
    fn stages_drive_the_state() {
        let w = demo_weights(12, 3, 3, 7);
        let mut state = ModelState::new(&w);
        StageSpec::Prune(PruneSpec::default())
            .to_stage(ExecConfig::serial())
            .apply(&mut state)
            .unwrap();
        assert_eq!(state.active_columns(), 9);
        StageSpec::Lcc(LccSpec::default())
            .to_stage(ExecConfig::serial())
            .apply(&mut state)
            .unwrap();
        assert!(state.lcc().is_some());
    }
}
