//! The servable form of a compressed model: a [`crate::exec::Executor`]
//! over the full pipeline artifact.
//!
//! Requests carry the *original* input dimension; the executor gathers
//! the kept features (pruned inputs are simply never read — on the FPGA
//! they are not wired), segment-sums shared clusters, and runs the LCC
//! adder graph through the batch-major engine. Pre-LCC artifacts (dense
//! or shared-only recipes) evaluate their dense product directly, so any
//! recipe's result is servable through `serve::ModelRegistry`.

use super::state::ModelState;
use crate::config::{ExecConfig, ExecMode, ShardSpec};
use crate::exec::{BatchEngine, Executor, FixedEngine, FixedPlan, ShardedExecutor};
use crate::share::SharedLayer;
use crate::tensor::Matrix;
use anyhow::{bail, ensure, Result};
use std::ops::Range;
use std::sync::Arc;

/// The engine serving an LCC artifact: the single unsharded engine
/// (float, or the fixed-point datapath when the recipe's
/// `exec_mode = fixed`), the output-range-sharded wrapper over the
/// same program when the recipe asks for it (`[compress.shard]` /
/// `exec.shards`) — in which case the unsharded engine is not kept
/// resident at all — or a mode-dispatched engine over a range-cut
/// plan (the remote `shard-worker` serving path).
enum LccEngine {
    Single(BatchEngine),
    Fixed(FixedEngine),
    Sharded(ShardedExecutor),
    Dyn(Arc<dyn Executor>),
}

impl LccEngine {
    fn as_executor(&self) -> &dyn Executor {
        match self {
            LccEngine::Single(e) => e,
            LccEngine::Fixed(e) => e,
            LccEngine::Sharded(sh) => sh,
            LccEngine::Dyn(e) => e.as_ref(),
        }
    }
}

enum Repr {
    Dense(Matrix),
    Shared(SharedLayer),
    Lcc {
        /// the sharing metadata (labels for segment sums); the engine
        /// evaluates the LCC program over the cluster inputs
        layer: SharedLayer,
        /// total additions (segment sums + LCC program), precomputed so
        /// the decomposition need not stay resident
        additions: usize,
        /// degenerate one-column-per-cluster sharing: segment sums are
        /// the identity, so inputs feed the engine directly (bit-
        /// identical to serving the bare graph)
        identity_sharing: bool,
        /// analytic |served − exact| bound of the engine's datapath:
        /// 0 for the float engines (bit-identical to the oracle), the
        /// lowered plan's max output bound in fixed mode
        err_bound: f64,
        engine: LccEngine,
    },
}

/// The compressed model behind the [`Executor`] interface.
pub struct PipelineExecutor {
    input_dim: usize,
    rows: usize,
    /// None = identity (nothing pruned): skip the gather entirely
    kept: Option<Vec<usize>>,
    repr: Repr,
}

impl PipelineExecutor {
    /// Build by moving the artifact's parts (no engine/matrix clones —
    /// the runtime checkpoint-load path). `shard` wraps the LCC engine
    /// in an output-range [`ShardedExecutor`]; pre-LCC representations
    /// (dense/shared) have no lowered program to partition and ignore it.
    pub(crate) fn from_state_sharded(state: ModelState, shard: Option<ShardSpec>) -> Self {
        let (input_dim, rows, kept, dense, shared, lcc) = state.into_executor_parts();
        let kept = (kept.len() != input_dim).then_some(kept);
        let repr = if let Some(slcc) = lcc {
            let additions = slcc.additions();
            let cfg = *slcc.engine().config();
            let sharded = shard.filter(|s| s.shards > 1).map(|s| {
                let cfg = ExecConfig { shards: s.shards, shard_mode: s.mode, ..cfg };
                // reuse the already-lowered plan: no re-lowering of the
                // graph (shard engines pick float/fixed per exec_mode)
                ShardedExecutor::from_plan(slcc.engine().plan(), cfg)
            });
            // unsharded fixed mode: re-lower the already-lowered plan
            // onto the integer datapath (a non-shift-add plan falls
            // back to the float engine with a warning — serving must
            // not fail on a representable-but-unlowerable artifact)
            let fixed = if sharded.is_none() && cfg.exec_mode == ExecMode::Fixed {
                match FixedEngine::from_plan(slcc.engine().plan(), cfg) {
                    Ok(e) => Some(e),
                    Err(e) => {
                        log::warn!("fixed lowering failed, serving float engine instead: {e}");
                        None
                    }
                }
            } else {
                None
            };
            // the shard sub-plans evaluate the identical expressions, so
            // the unsharded plan's bound covers the sharded engine too
            let err_bound = match &fixed {
                Some(fx) => fx.max_error_bound(),
                None if cfg.exec_mode == ExecMode::Fixed => {
                    FixedPlan::lower(slcc.engine().plan(), &cfg)
                        .map(|p| p.max_error_bound())
                        .unwrap_or(0.0)
                }
                None => 0.0,
            };
            // once the shard engines exist, the unsharded engine (and
            // the decomposition) are dropped with the rest of the SharedLcc
            let (layer, _decomposition, single) = slcc.into_parts();
            let identity_sharing = layer.labels.iter().enumerate().all(|(i, &l)| i == l);
            let engine = match (sharded, fixed) {
                (Some(sh), _) => LccEngine::Sharded(sh),
                (None, Some(fx)) => LccEngine::Fixed(fx),
                (None, None) => LccEngine::Single(single),
            };
            Repr::Lcc { layer, additions, identity_sharing, err_bound, engine }
        } else if let Some(s) = shared {
            Repr::Shared(s)
        } else {
            Repr::Dense(dense)
        };
        PipelineExecutor { input_dim, rows, kept, repr }
    }

    /// Build an executor restricted to the output rows in `range` —
    /// the remote `shard-worker` serving path. Requests still carry
    /// the full original input dimension (the kept-feature gather and
    /// segment sums are input-side and identical on every shard); only
    /// the LCC program is cut down to the range via
    /// [`crate::exec::ExecPlan::extract_output_range`], so a gather
    /// over range executors is bit-identical to the unsharded engine
    /// in both float and fixed modes.
    pub(crate) fn from_state_range(state: ModelState, range: Range<usize>) -> Result<Self> {
        let (input_dim, rows, kept, _dense, _shared, lcc) = state.into_executor_parts();
        ensure!(
            range.start < range.end && range.end <= rows,
            "output range {}..{} out of 0..{rows}",
            range.start,
            range.end
        );
        let Some(slcc) = lcc else {
            bail!("range-restricted serving needs an LCC artifact (recipe has no lcc step)");
        };
        let kept = (kept.len() != input_dim).then_some(kept);
        // never re-shard the cut plan: the remote gather is the shard layer
        let cfg = ExecConfig { shards: 1, ..*slcc.engine().config() };
        let sub = slcc.engine().plan().extract_output_range(range.start, range.end);
        let additions = sub.additions();
        let err_bound = if cfg.exec_mode == ExecMode::Fixed {
            FixedPlan::lower(&sub, &cfg).map(|p| p.max_error_bound()).unwrap_or(0.0)
        } else {
            0.0
        };
        let engine = LccEngine::Dyn(crate::exec::engine_for_plan(sub, cfg));
        let (layer, _decomposition, _single) = slcc.into_parts();
        let identity_sharing = layer.labels.iter().enumerate().all(|(i, &l)| i == l);
        let repr = Repr::Lcc { layer, additions, identity_sharing, err_bound, engine };
        Ok(PipelineExecutor { input_dim, rows: range.len(), kept, repr })
    }

    /// Additions of the represented program (segment sums included).
    pub fn additions(&self) -> Option<usize> {
        match &self.repr {
            Repr::Lcc { additions, .. } => Some(*additions),
            _ => None,
        }
    }

    /// Shard count of the serving engine (1 = unsharded).
    pub fn num_shards(&self) -> usize {
        match &self.repr {
            Repr::Lcc { engine: LccEngine::Sharded(sh), .. } => sh.num_shards(),
            _ => 1,
        }
    }

    /// Analytic |served − exact| bound of the engine's datapath per
    /// output: 0.0 when serving float engines (bit-identical to the
    /// oracle), the lowered fixed plan's worst output bound when the
    /// recipe selected `exec_mode = fixed`. Differential verification
    /// (the `compress` CLI's oracle check) keys its tolerance off this.
    pub fn max_error_bound(&self) -> f64 {
        match &self.repr {
            Repr::Lcc { err_bound, .. } => *err_bound,
            _ => 0.0,
        }
    }

    /// True when the LCC program is served by the fixed-point datapath.
    pub fn is_fixed(&self) -> bool {
        matches!(&self.repr, Repr::Lcc { engine: LccEngine::Fixed(_), .. })
    }
}

impl Executor for PipelineExecutor {
    fn num_inputs(&self) -> usize {
        self.input_dim
    }

    fn num_outputs(&self) -> usize {
        self.rows
    }

    fn name(&self) -> &'static str {
        "pipeline-exec"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        for x in xs {
            assert_eq!(x.len(), self.input_dim, "sample has wrong input arity");
        }
        let gathered: Option<Vec<Vec<f32>>> = self.kept.as_ref().map(|kept| {
            xs.iter().map(|x| kept.iter().map(|&i| x[i]).collect()).collect()
        });
        let inputs: &[Vec<f32>] = gathered.as_deref().unwrap_or(xs);
        match &self.repr {
            Repr::Dense(w) => {
                ys.resize_with(xs.len(), Vec::new);
                for (x, y) in inputs.iter().zip(ys.iter_mut()) {
                    *y = w.matvec(x);
                }
            }
            Repr::Shared(s) => {
                ys.resize_with(xs.len(), Vec::new);
                for (x, y) in inputs.iter().zip(ys.iter_mut()) {
                    *y = s.apply(x);
                }
            }
            Repr::Lcc { layer, identity_sharing, engine, .. } => {
                let engine = engine.as_executor();
                if *identity_sharing {
                    engine.execute_batch_into(inputs, ys);
                } else {
                    let sums: Vec<Vec<f32>> =
                        inputs.iter().map(|x| layer.segment_sums(x)).collect();
                    engine.execute_batch_into(&sums, ys);
                }
            }
        }
    }
}

impl std::fmt::Debug for PipelineExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let repr = match &self.repr {
            Repr::Dense(_) => "dense",
            Repr::Shared(_) => "shared",
            Repr::Lcc { .. } => "lcc",
        };
        f.debug_struct("PipelineExecutor")
            .field("input_dim", &self.input_dim)
            .field("rows", &self.rows)
            .field("pruned", &self.kept.is_some())
            .field("repr", &repr)
            .field("shards", &self.num_shards())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{demo_weights, Pipeline, Recipe};
    use crate::config::ExecConfig;
    use crate::exec::NaiveExecutor;
    use crate::lcc::{decompose, LccConfig};
    use crate::util::Rng;

    fn serial_recipe() -> Recipe {
        Recipe { exec: ExecConfig::serial(), ..Recipe::default() }
    }

    #[test]
    fn full_recipe_matches_oracle_composition_bit_exact() {
        let w = demo_weights(16, 3, 4, 0);
        let model = Pipeline::from_recipe(&serial_recipe()).unwrap().run(&w).unwrap();
        let exec = model.executor();
        assert_eq!(exec.num_inputs(), w.cols());
        assert_eq!(exec.num_outputs(), w.rows());
        let slcc = model.lcc().unwrap();
        let oracle = NaiveExecutor::new(slcc.graph().clone());
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f32>> = (0..13).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let got = exec.execute_batch(&xs);
        for (x, y) in xs.iter().zip(&got) {
            let xk: Vec<f32> = model.kept().iter().map(|&i| x[i]).collect();
            let want = oracle.execute_one(&slcc.layer.segment_sums(&xk));
            assert_eq!(*y, want);
        }
    }

    #[test]
    fn lcc_only_recipe_bit_identical_to_bare_graph_engine() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(32, 8, 0.5, &mut rng);
        let recipe = Recipe::lcc_only(&LccConfig::fs(), ExecConfig::serial());
        let model = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
        let exec = model.executor();
        // the legacy path: engine straight over decompose(w)
        let d = decompose(&w, &LccConfig::fs());
        let legacy = crate::exec::BatchEngine::with_config(d.graph(), ExecConfig::serial());
        let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(8, 1.0)).collect();
        assert_eq!(exec.execute_batch(&xs), legacy.execute_batch(&xs));
    }

    #[test]
    fn dense_and_shared_recipes_are_servable() {
        let w = demo_weights(12, 3, 3, 2);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(w.cols(), 1.0);

        let prune_only = Pipeline::builder().prune(1e-6).build().unwrap().run(&w).unwrap();
        let e = prune_only.executor();
        let xk: Vec<f32> = prune_only.kept().iter().map(|&i| x[i]).collect();
        assert_eq!(e.execute_one(&x), prune_only.state().dense().matvec(&xk));
        assert!(e.additions().is_none());

        let shared = Pipeline::builder().prune(1e-6).share().build().unwrap().run(&w).unwrap();
        let e = shared.executor();
        let xk: Vec<f32> = shared.kept().iter().map(|&i| x[i]).collect();
        assert_eq!(e.execute_one(&x), shared.state().shared().unwrap().apply(&xk));
    }

    #[test]
    fn sharded_executor_matches_unsharded_and_oracle() {
        use crate::config::{ShardMode, ShardSpec};
        let w = demo_weights(18, 3, 4, 8);
        let recipe = serial_recipe();
        let model = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
        let plain = model.executor();
        assert_eq!(plain.num_shards(), 1);
        let mut rng = Rng::new(15);
        let xs: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let want = plain.execute_batch(&xs);
        for mode in [ShardMode::Serial, ShardMode::Parallel] {
            let sharded_recipe = Recipe {
                shard: Some(ShardSpec { shards: 4, mode }),
                ..recipe.clone()
            };
            let sharded = Pipeline::from_recipe(&sharded_recipe)
                .unwrap()
                .run(&w)
                .unwrap()
                .into_executor();
            assert!(sharded.num_shards() > 1, "shard spec must engage");
            assert_eq!(sharded.num_inputs(), w.cols());
            assert_eq!(sharded.num_outputs(), w.rows());
            assert_eq!(sharded.execute_batch(&xs), want, "mode {mode:?}");
        }
    }

    #[test]
    fn fixed_mode_recipe_serves_within_error_bound() {
        use crate::config::{ShardMode, ShardSpec};
        let w = demo_weights(16, 3, 4, 0);
        let float_exec = Pipeline::from_recipe(&serial_recipe()).unwrap().run(&w).unwrap();
        let fixed_recipe = Recipe {
            exec: ExecConfig { exec_mode: ExecMode::Fixed, ..ExecConfig::serial() },
            ..Recipe::default()
        };
        let model = Pipeline::from_recipe(&fixed_recipe).unwrap().run(&w).unwrap();
        let exec = model.executor();
        assert!(exec.is_fixed(), "fixed recipe must serve the fixed datapath");
        let bound = exec.max_error_bound();
        assert!(bound > 0.0, "fixed mode must report a nonzero bound");
        assert_eq!(float_exec.executor().max_error_bound(), 0.0, "float serving is exact");

        let mut rng = Rng::new(33);
        let xs: Vec<Vec<f32>> = (0..11).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let want = float_exec.executor().execute_batch(&xs);
        let got = exec.execute_batch(&xs);
        for (ws, gs) in want.iter().zip(&got) {
            for (wv, gv) in ws.iter().zip(gs) {
                let tol = bound + 1e-4 * (1.0 + wv.abs() as f64);
                assert!(((wv - gv).abs() as f64) <= tol, "fixed {gv} vs float {wv} > {bound}");
            }
        }

        // sharded fixed serving: same integers, bit-identical gather
        let sharded = Pipeline::from_recipe(&Recipe {
            shard: Some(ShardSpec { shards: 3, mode: ShardMode::Serial }),
            ..fixed_recipe
        })
        .unwrap()
        .run(&w)
        .unwrap()
        .into_executor();
        assert!(sharded.num_shards() > 1);
        assert_eq!(sharded.max_error_bound(), bound, "bound survives sharding");
        assert_eq!(sharded.execute_batch(&xs), got, "sharded fixed ≡ unsharded fixed");
    }

    #[test]
    #[should_panic(expected = "wrong input arity")]
    fn wrong_arity_panics_like_the_engine() {
        let w = demo_weights(8, 2, 2, 1);
        let model = Pipeline::from_recipe(&serial_recipe()).unwrap().run(&w).unwrap();
        let _ = model.executor().execute_one(&[1.0]);
    }
}
