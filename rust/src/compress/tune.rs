//! Recipe autotuner: sweep the paper's operating points, keep the
//! Pareto frontier.
//!
//! The paper hand-picks one prune threshold, one share cluster scale
//! and one LCC slicing per result; since every such choice is a
//! deterministic, serializable [`Recipe`] and every run emits a
//! [`super::CompressionReport`], the search over them is mechanical.
//! A [`TuneSpec`] names the axes (prune thresholds × share scales ×
//! FP/FS × slice widths × float/fixed × shard counts), [`sweep_matrix`]
//! / [`sweep_network`] run every candidate through the existing
//! [`Pipeline`] / [`NetworkPipeline`] — in parallel on
//! [`crate::exec::global_pool`] — and score each point on the paper's
//! own trade-off: **additions** (the cost metric) vs **relative
//! error**. The result keeps every evaluated point, flags the Pareto
//! frontier ([`super::pareto_frontier`]: dominated points excluded,
//! exact ties kept), and [`TuneResult::write`] emits an output
//! directory — one `recipe-<id>.toml` per point, the frontier's
//! cheapest point as `best.toml`, machine-readable `sweep.json`
//! (JSON-lines, [`bench::json_line`] rows like `BENCH_exec.json`),
//! `sweep.tsv`, and a `sweep.md` table that pastes into EXPERIMENTS.md
//! §Recipe-sweep.
//!
//! Everything is deterministic: same spec + same seed + same weights ⇒
//! the same candidates (a seeded subsample when `budget` caps the
//! grid), the same scores, the same frontier, byte-identical emitted
//! files — and each emitted recipe re-runs through `compress --recipe`
//! to bit-identical additions/rel-err. The exception is opt-in:
//! `measure = true` times each candidate's served engine (µs/sample),
//! which is host-dependent by nature.
//!
//! ```
//! use lccnn::compress::{demo_weights, tune, Recipe, TuneSpec};
//!
//! let spec = TuneSpec { budget: 4, ..TuneSpec::default() };
//! let w = demo_weights(16, 3, 4, 0);
//! let result = tune::sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
//! assert_eq!(result.points.len(), 4);
//! assert!(!result.frontier().is_empty());
//! let best = result.best().unwrap();
//! assert!(best.frontier && best.additions > 0);
//! ```

use super::recipe::TuneSpec;
use super::report::pareto_frontier;
use super::{
    LccSpec, NetworkCheckpoint, NetworkPipeline, Pipeline, PruneSpec, Recipe, ShareSpec, StageSpec,
};
use crate::config::{ExecMode, LccAlgoConfig, ShardSpec};
use crate::exec::{global_pool, Executor};
use crate::lcc::LccConfig;
use crate::report::Table;
use crate::tensor::Matrix;
use crate::util::{bench, Rng};
use anyhow::{anyhow, Context, Result};
use std::fmt::Write as _;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

fn algo_name(a: LccAlgoConfig) -> &'static str {
    match a {
        LccAlgoConfig::Fp => "fp",
        LccAlgoConfig::Fs => "fs",
    }
}

/// One grid cell of a sweep: the axis values, before evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Candidate {
    /// position in the full grid (stable across budget subsampling, so
    /// `recipe-<id>.toml` names identify the same cell in any run)
    id: usize,
    prune_eps: f64,
    share_scale: f64,
    algo: LccAlgoConfig,
    width: usize,
    mode: ExecMode,
    shards: usize,
}

impl Candidate {
    fn label(&self) -> String {
        format!(
            "eps={} share={} {} w{} {} x{}",
            self.prune_eps,
            self.share_scale,
            algo_name(self.algo),
            self.width,
            self.mode.as_str(),
            self.shards
        )
    }
}

/// The full grid in a fixed nested order (prune_eps slowest, shards
/// fastest), ids dense from 0.
fn candidates(spec: &TuneSpec) -> Vec<Candidate> {
    let mut out = Vec::with_capacity(spec.grid_size());
    let mut id = 0;
    for &prune_eps in &spec.prune_eps {
        for &share_scale in &spec.share_scale {
            for &algo in &spec.lcc_algos {
                for &width in &spec.lcc_widths {
                    for &mode in &spec.exec_modes {
                        for &shards in &spec.shards {
                            out.push(Candidate {
                                id,
                                prune_eps,
                                share_scale,
                                algo,
                                width,
                                mode,
                                shards,
                            });
                            id += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

/// The candidates a sweep evaluates: the full grid, or — when `budget`
/// caps it — a seeded uniform subsample, re-sorted into grid order.
fn selected(spec: &TuneSpec) -> Vec<Candidate> {
    let mut all = candidates(spec);
    if spec.budget > 0 && spec.budget < all.len() {
        let mut rng = Rng::new(spec.seed);
        rng.shuffle(&mut all);
        all.truncate(spec.budget);
        all.sort_by_key(|c| c.id);
    }
    all
}

/// Materialize one grid cell as a concrete [`Recipe`] over `base`.
///
/// The stage stack is the canonical prune → share → (quantize) → lcc
/// order with the candidate's axis values written over `base`'s stage
/// parameters: `share_scale == 0` drops the share stage, a quantize
/// stage is carried over only if `base` had one, and an algorithm swap
/// reseeds the FP/FS-specific knobs from that algorithm's defaults
/// while keeping the target error / quant step / shift range. The
/// engine tuning keeps `base.exec` except `exec_mode` (swept) and
/// `exec.shards` (pinned to 1 so the candidate's shard axis is
/// authoritative through [`ShardSpec::effective`]). Per-layer overrides
/// are cleared — a sweep varies the global stack, and a fixed override
/// would silently mask the axes for that layer.
fn candidate_recipe(base: &Recipe, c: &Candidate) -> Recipe {
    let mut prune = PruneSpec::default();
    let mut share = ShareSpec::default();
    let mut lcc = LccSpec::default();
    let mut quantize = None;
    for s in &base.stages {
        match s {
            StageSpec::Prune(p) => prune = *p,
            StageSpec::Share(sh) => share = *sh,
            StageSpec::Quantize(q) => quantize = Some(*q),
            StageSpec::Lcc(l) => lcc = l.clone(),
        }
    }
    prune.eps = c.prune_eps as f32;
    share.preference_scale = c.share_scale as f32;
    if lcc.algo != c.algo {
        let seeded = LccSpec::from_config(&match c.algo {
            LccAlgoConfig::Fp => LccConfig::fp(),
            LccAlgoConfig::Fs => LccConfig::fs(),
        });
        lcc = LccSpec {
            target_rel_err: lcc.target_rel_err,
            quant_step: lcc.quant_step,
            shift_min: lcc.shift_min,
            shift_max: lcc.shift_max,
            ..seeded
        };
    }
    lcc.slice_width = c.width;
    let mut stages = vec![StageSpec::Prune(prune)];
    if c.share_scale > 0.0 {
        stages.push(StageSpec::Share(share));
    }
    if let Some(q) = quantize {
        stages.push(StageSpec::Quantize(q));
    }
    stages.push(StageSpec::Lcc(lcc));
    let mut exec = base.exec;
    exec.exec_mode = c.mode;
    exec.shards = 1;
    let shard_mode = base.shard.map(|s| s.mode).unwrap_or(base.exec.shard_mode);
    Recipe {
        stages,
        exec,
        shard: (c.shards > 1).then_some(ShardSpec { shards: c.shards, mode: shard_mode }),
        layers: Default::default(),
        gate_epsilon: base.gate_epsilon,
    }
}

/// One evaluated sweep point: the grid cell, the concrete recipe it
/// materialized to, and its scores.
#[derive(Clone, Debug, PartialEq)]
pub struct TunePoint {
    /// position in the full grid (names the emitted `recipe-<id>.toml`)
    pub id: usize,
    pub prune_eps: f64,
    pub share_scale: f64,
    pub algo: LccAlgoConfig,
    pub width: usize,
    pub mode: ExecMode,
    pub shards: usize,
    /// the exact recipe evaluated — re-running it through `compress`
    /// reproduces `additions` / `rel_err` bit-identically
    pub recipe: Recipe,
    /// additions of the final representation (one forward pass)
    pub additions: usize,
    /// the target's dense CSD baseline additions
    pub baseline_additions: usize,
    /// baseline / additions
    pub ratio: f64,
    /// final relative error (worst layer, for network sweeps)
    pub rel_err: f64,
    /// measured serve-time µs/sample, when the spec's `measure` is on
    pub us_per_sample: Option<f64>,
    /// on the (additions, rel_err) Pareto frontier of this sweep
    pub frontier: bool,
}

impl TunePoint {
    /// Compact axis summary, e.g. `eps=0.001 share=0.3 fs w4 float x1`.
    pub fn label(&self) -> String {
        Candidate {
            id: self.id,
            prune_eps: self.prune_eps,
            share_scale: self.share_scale,
            algo: self.algo,
            width: self.width,
            mode: self.mode,
            shards: self.shards,
        }
        .label()
    }

    /// The point as JSON-lines / bench fields (`sweep.json` row).
    fn row_fields(&self) -> Vec<(&'static str, String)> {
        let mut f = vec![
            ("id", self.id.to_string()),
            ("prune_eps", self.prune_eps.to_string()),
            ("share_scale", self.share_scale.to_string()),
            ("algo", algo_name(self.algo).to_string()),
            ("width", self.width.to_string()),
            ("mode", self.mode.as_str().to_string()),
            ("shards", self.shards.to_string()),
            ("additions", self.additions.to_string()),
            ("baseline", self.baseline_additions.to_string()),
            ("ratio", self.ratio.to_string()),
            ("rel_err", self.rel_err.to_string()),
            ("frontier", (self.frontier as u8).to_string()),
        ];
        if let Some(u) = self.us_per_sample {
            f.push(("us_per_sample", u.to_string()));
        }
        f
    }
}

/// A finished sweep: every evaluated point with frontier flags set.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneResult {
    /// what was swept, for table titles (`matrix 24x20`, `network 3 layers`)
    pub target: String,
    /// size of the full grid (≥ `points.len()` when a budget applied)
    pub grid_size: usize,
    /// evaluated points in grid-id order
    pub points: Vec<TunePoint>,
}

impl TuneResult {
    /// The Pareto-frontier points, in grid-id order.
    pub fn frontier(&self) -> Vec<&TunePoint> {
        self.points.iter().filter(|p| p.frontier).collect()
    }

    /// The frontier's cheapest point: fewest additions, ties broken by
    /// lower rel-err then lower grid id. `None` only for an empty sweep.
    pub fn best(&self) -> Option<&TunePoint> {
        self.points.iter().filter(|p| p.frontier).min_by(|a, b| {
            a.additions
                .cmp(&b.additions)
                .then(a.rel_err.total_cmp(&b.rel_err))
                .then(a.id.cmp(&b.id))
        })
    }

    /// Render as an aligned table for the CLI (`*` marks the frontier).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "tune sweep ({}; {} of {} grid points)",
                self.target,
                self.points.len(),
                self.grid_size
            ),
            &["id", "candidate", "additions", "ratio", "rel err", "us/sample", "front"],
        );
        for p in &self.points {
            t.add_row(vec![
                p.id.to_string(),
                p.label(),
                p.additions.to_string(),
                format!("{:.2}", p.ratio),
                format!("{:.2e}", p.rel_err),
                p.us_per_sample.map(|u| format!("{u:.2}")).unwrap_or_else(|| "-".into()),
                if p.frontier { "*".into() } else { "".into() },
            ]);
        }
        t.render()
    }

    /// Markdown table in the EXPERIMENTS.md §Recipe-sweep schema.
    pub fn render_markdown(&self) -> String {
        let mut s = String::from(
            "| id | prune eps | share | algo | width | mode | shards | additions | ratio \
             | rel err | us/sample | frontier |\n\
             |---:|----------:|------:|:-----|------:|:-----|-------:|----------:|------:\
             |--------:|----------:|:--------:|\n",
        );
        for p in &self.points {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} | {} | {} | {} | {} | {:.2} | {:.2e} | {} | {} |",
                p.id,
                p.prune_eps,
                p.share_scale,
                algo_name(p.algo),
                p.width,
                p.mode.as_str(),
                p.shards,
                p.additions,
                p.ratio,
                p.rel_err,
                p.us_per_sample.map(|u| format!("{u:.2}")).unwrap_or_else(|| "-".into()),
                if p.frontier { "yes" } else { "" },
            );
        }
        s
    }

    /// Tab-separated rows (full-precision numbers, `-` for unmeasured).
    pub fn to_tsv(&self) -> String {
        let mut out = String::from(
            "id\tprune_eps\tshare_scale\talgo\twidth\tmode\tshards\tadditions\tbaseline\
             \tratio\trel_err\tus_per_sample\tfrontier\n",
        );
        for p in &self.points {
            let _ = writeln!(
                out,
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
                p.id,
                p.prune_eps,
                p.share_scale,
                algo_name(p.algo),
                p.width,
                p.mode.as_str(),
                p.shards,
                p.additions,
                p.baseline_additions,
                p.ratio,
                p.rel_err,
                p.us_per_sample.map(|u| u.to_string()).unwrap_or_else(|| "-".into()),
                p.frontier as u8,
            );
        }
        out
    }

    /// JSON-lines rows (one [`bench::json_line`] per point — the
    /// `sweep.json` format, same spirit as `BENCH_exec.json`).
    pub fn sweep_json(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            out.push_str(&bench::json_line("tune", &p.row_fields()));
        }
        out
    }

    /// Write the sweep's artifact directory: `recipe-<id>.toml` per
    /// point, the frontier's cheapest recipe as `best.toml`,
    /// `sweep.json` / `sweep.tsv` / `sweep.md`, and — when
    /// `LCCNN_BENCH_JSON` is set — one `tune` bench row per point.
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        for p in &self.points {
            p.recipe.save(&dir.join(format!("recipe-{:03}.toml", p.id)))?;
        }
        let put = |name: &str, text: String| {
            std::fs::write(dir.join(name), text)
                .with_context(|| format!("write {}", dir.join(name).display()))
        };
        put("sweep.json", self.sweep_json())?;
        put("sweep.tsv", self.to_tsv())?;
        put("sweep.md", self.render_markdown())?;
        if let Some(best) = self.best() {
            best.recipe.save(&dir.join("best.toml"))?;
        }
        for p in &self.points {
            bench::emit("tune", &p.row_fields());
        }
        Ok(())
    }
}

/// Average serve-time µs/sample of one engine over a deterministic
/// batch (wall-clock; quick iteration counts under `LCCNN_BENCH_QUICK`).
fn time_executor(e: &dyn Executor, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let batch: Vec<Vec<f32>> = (0..32).map(|_| rng.normal_vec(e.num_inputs(), 1.0)).collect();
    let mut ys = Vec::new();
    e.execute_batch_into(&batch, &mut ys); // warmup
    let iters = bench::pick(2, 20);
    let t0 = Instant::now();
    for _ in 0..iters {
        e.execute_batch_into(&batch, &mut ys);
    }
    t0.elapsed().as_secs_f64() * 1e6 / (iters * batch.len()) as f64
}

/// The shared sweep driver: enumerate + subsample candidates, evaluate
/// each through `eval` in parallel on [`global_pool`] (results land in
/// per-candidate slots, so scores are deterministic regardless of
/// scheduling), then flag the Pareto frontier.
fn sweep_with<E>(spec: &TuneSpec, base: &Recipe, target: &str, eval: E) -> Result<TuneResult>
where
    E: Fn(&Recipe) -> Result<(usize, usize, f64, Option<f64>)> + Sync,
{
    spec.validate()?;
    let cands = selected(spec);
    let slots: Vec<Mutex<Option<Result<TunePoint>>>> =
        cands.iter().map(|_| Mutex::new(None)).collect();
    let eval = &eval;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = cands
        .iter()
        .zip(&slots)
        .map(|(c, slot)| {
            Box::new(move || {
                let recipe = candidate_recipe(base, c);
                let point = eval(&recipe).map(|(additions, baseline, rel_err, us)| TunePoint {
                    id: c.id,
                    prune_eps: c.prune_eps,
                    share_scale: c.share_scale,
                    algo: c.algo,
                    width: c.width,
                    mode: c.mode,
                    shards: c.shards,
                    recipe,
                    additions,
                    baseline_additions: baseline,
                    ratio: baseline as f64 / additions.max(1) as f64,
                    rel_err,
                    us_per_sample: us,
                    frontier: false,
                });
                *slot.lock().unwrap() = Some(point);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    global_pool().run_scoped(tasks).map_err(|p| anyhow!("tune sweep: {p}"))?;
    let mut points = Vec::with_capacity(cands.len());
    for (c, slot) in cands.iter().zip(&slots) {
        let res =
            slot.lock().unwrap().take().unwrap_or_else(|| Err(anyhow!("candidate never ran")));
        points.push(res.with_context(|| format!("tune candidate {} ({})", c.id, c.label()))?);
    }
    let scores: Vec<(usize, f64)> = points.iter().map(|p| (p.additions, p.rel_err)).collect();
    for (p, f) in points.iter_mut().zip(pareto_frontier(&scores)) {
        p.frontier = f;
    }
    Ok(TuneResult { target: target.to_string(), grid_size: spec.grid_size(), points })
}

/// Sweep over a single weight matrix through [`Pipeline`].
pub fn sweep_matrix(spec: &TuneSpec, base: &Recipe, w: &Matrix) -> Result<TuneResult> {
    let target = format!("matrix {}x{}", w.rows(), w.cols());
    sweep_with(spec, base, &target, |r| {
        let model = Pipeline::from_recipe(r)?.run(w)?;
        let rep = model.report();
        let us = spec.measure.then(|| time_executor(&model.executor(), spec.seed));
        Ok((rep.final_additions(), rep.baseline_additions, rep.final_rel_err(), us))
    })
}

/// Sweep over a multi-layer checkpoint through [`NetworkPipeline`]
/// (additions and baselines summed over layers, rel-err the worst
/// layer's).
pub fn sweep_network(
    spec: &TuneSpec,
    base: &Recipe,
    ckpt: &NetworkCheckpoint,
) -> Result<TuneResult> {
    let target = format!("network {} layers", ckpt.num_layers());
    sweep_with(spec, base, &target, |r| {
        let net = NetworkPipeline::from_recipe(r)?.run(ckpt)?;
        let rep = net.report();
        let us = if spec.measure {
            Some(time_executor(&net.executor()?, spec.seed))
        } else {
            None
        };
        Ok((rep.total_additions(), rep.baseline_additions(), rep.max_rel_err(), us))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::demo_weights;

    #[test]
    fn grid_enumeration_is_dense_and_ordered() {
        let spec = TuneSpec::default();
        let all = candidates(&spec);
        assert_eq!(all.len(), spec.grid_size());
        for (i, c) in all.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        // shards is the fastest axis, prune_eps the slowest
        assert_eq!(all[0].prune_eps, spec.prune_eps[0]);
        assert_eq!(all.last().unwrap().prune_eps, *spec.prune_eps.last().unwrap());
    }

    #[test]
    fn budget_subsample_is_a_deterministic_sorted_subset() {
        let spec = TuneSpec { budget: 5, seed: 7, ..TuneSpec::default() };
        let a = selected(&spec);
        let b = selected(&spec);
        assert_eq!(a, b, "same spec + seed => same subsample");
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0].id < w[1].id), "re-sorted into grid order");
        let full: Vec<Candidate> = candidates(&spec);
        assert!(a.iter().all(|c| full[c.id] == *c), "subset of the grid");
        let varied =
            (1..9).map(|seed| selected(&TuneSpec { seed, ..spec.clone() })).collect::<Vec<_>>();
        assert!(varied.iter().any(|v| *v != a), "subsample depends on the seed");
    }

    #[test]
    fn candidate_recipes_follow_the_axes() {
        let base = Recipe::default();
        let c = Candidate {
            id: 0,
            prune_eps: 0.01,
            share_scale: 0.0,
            algo: LccAlgoConfig::Fp,
            width: 8,
            mode: ExecMode::Fixed,
            shards: 4,
        };
        let r = candidate_recipe(&base, &c);
        assert_eq!(r.stages.len(), 2, "share_scale 0 drops the share stage");
        assert!(matches!(&r.stages[0], StageSpec::Prune(p) if p.eps == 0.01));
        match &r.stages[1] {
            StageSpec::Lcc(l) => {
                assert_eq!(l.algo, LccAlgoConfig::Fp, "algo swapped from the FS base");
                assert_eq!(l.slice_width, 8);
                let fs = LccSpec::default();
                assert_eq!(l.target_rel_err, fs.target_rel_err, "error target carried over");
            }
            other => panic!("expected lcc last, got {other:?}"),
        }
        assert_eq!(r.exec.exec_mode, ExecMode::Fixed);
        assert_eq!(r.exec.shards, 1, "shard axis is authoritative");
        assert_eq!(r.shard.unwrap().shards, 4);
        assert_eq!(r.shard_spec().unwrap().shards, 4);
        // shards <= 1 means an unsharded engine
        let r1 = candidate_recipe(&base, &Candidate { shards: 1, share_scale: 0.3, ..c });
        assert!(r1.shard.is_none() && r1.shard_spec().is_none());
        assert_eq!(r1.stages.len(), 3, "share stage back in");
        assert!(matches!(&r1.stages[1], StageSpec::Share(s) if s.preference_scale == 0.3));
        // every candidate recipe round-trips through TOML
        let text = r.to_toml_string();
        assert_eq!(Recipe::from_toml_str(&text).unwrap(), r, "\n{text}");
    }

    #[test]
    fn sweep_is_deterministic_and_flags_a_frontier() {
        let spec = TuneSpec { budget: 6, seed: 3, ..TuneSpec::default() };
        let w = demo_weights(16, 3, 4, 0);
        let a = sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
        let b = sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
        assert_eq!(a, b, "same spec + seed + weights => identical result");
        assert_eq!(a.points.len(), 6);
        assert!(!a.frontier().is_empty(), "a non-empty sweep has a frontier");
        let best = a.best().unwrap();
        assert!(best.frontier);
        assert!(a.frontier().iter().all(|p| p.additions >= best.additions));
        // bench artifacts agree with the points
        assert_eq!(a.sweep_json().lines().count(), 6);
        assert_eq!(a.to_tsv().lines().count(), 7, "header + 6 rows");
        assert!(a.render().contains("tune sweep"));
        assert!(a.render_markdown().starts_with("| id |"));
    }

    #[test]
    fn evaluated_points_reproduce_through_the_pipeline() {
        let spec = TuneSpec { budget: 3, ..TuneSpec::default() };
        let w = demo_weights(16, 3, 4, 0);
        let res = sweep_matrix(&spec, &Recipe::default(), &w).unwrap();
        for p in &res.points {
            // the emitted recipe, re-parsed from its TOML bytes, re-runs
            // to bit-identical scores (the acceptance criterion)
            let r = Recipe::from_toml_str(&p.recipe.to_toml_string()).unwrap();
            let model = Pipeline::from_recipe(&r).unwrap().run(&w).unwrap();
            assert_eq!(model.report().final_additions(), p.additions, "{}", p.label());
            assert_eq!(model.report().final_rel_err(), p.rel_err, "{}", p.label());
        }
    }
}
