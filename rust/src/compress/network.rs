//! Whole-network compression and serving: the paper's per-layer LCC
//! scheme applied to a multi-layer model as one artifact.
//!
//! The single-matrix pipeline (`Pipeline` → `PipelineExecutor`) covers
//! the layer-1 scope; deep models need the Deep-Compression-style sweep
//! — every layer pruned/shared/LCC'd with its own tuning — plus an
//! execution engine that *runs* the compressed per-layer representation
//! end to end (the EIE argument). This module supplies both:
//!
//! * [`NetworkCheckpoint`] — the multi-layer checkpoint format: a
//!   directory of `layer<k>.weight.npy` (+ optional `layer<k>.bias.npy`)
//!   files described by a `network.toml` manifest naming per-layer
//!   shapes and activations.
//! * [`NetworkPipeline`] — runs the existing compression stages once per
//!   layer, resolving each layer's stage list and parameters through
//!   [`Recipe::layer_recipe`] (`[compress.layer.<k>]` overrides), and
//!   aggregates per-layer accounting into one [`NetworkReport`].
//! * [`NetworkExecutor`] — a [`crate::exec::Executor`] chaining the
//!   per-layer [`PipelineExecutor`]s with batch-major bias/activation
//!   kernels (ReLU, identity) and reused inter-layer lane buffers. It
//!   composes with everything behind the `Executor` seam: float/fixed
//!   datapaths (per-layer analytic bounds propagate into a network-level
//!   bound), per-layer sharding, registry hot-swap, and per-layer
//!   [`crate::exec::Executor::layer_stats`] metrics.
//! * [`ChainedExecutor`] — dimension-checked sequential composition of
//!   arbitrary executors; the serve-side gather for remote workers that
//!   each serve one layer range ([`CompressedNetwork::layer_range_executor`]).
//!
//! Differential verification: [`CompressedNetwork::oracle_forward`]
//! evaluates the same compressed representation by hand-chaining the
//! [`NaiveExecutor`] oracle per layer — float serving must be
//! bit-identical to it, fixed serving within
//! [`NetworkExecutor::max_error_bound`].

use super::pipeline::{CompressedModel, Pipeline};
use super::recipe::Recipe;
use super::report::CompressionReport;
use super::PipelineExecutor;
use crate::config::{parse_toml, TomlValue};
use crate::exec::{ExecError, ExecHealth, Executor, LayerStat, NaiveExecutor};
use crate::metrics::Metrics;
use crate::nn::npy::{read_npy, write_npy, NpyArray};
use crate::report::Table;
use crate::tensor::Matrix;
use crate::util::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::ops::Range;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A layer's nonlinearity, applied in place on batch-major lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    /// no-op (output layers serve raw logits)
    Identity,
}

impl Activation {
    /// Parse a manifest name (`relu`, `identity`; `none`/`linear` are
    /// accepted aliases of `identity`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "relu" => Some(Activation::Relu),
            "identity" | "none" | "linear" => Some(Activation::Identity),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Identity => "identity",
        }
    }

    /// Apply in place over one output lane.
    pub fn apply(&self, y: &mut [f32]) {
        if let Activation::Relu = self {
            for v in y.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
}

/// One layer of a multi-layer checkpoint: the weight matrix (rows =
/// outputs, cols = inputs), an optional bias, and the activation that
/// follows the affine map.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkLayer {
    pub weight: Matrix,
    pub bias: Option<Vec<f32>>,
    pub activation: Activation,
}

/// A multi-layer checkpoint: an ordered list of [`NetworkLayer`]s,
/// persisted as a directory of `layer<k>.weight.npy` files (1-based)
/// plus a `network.toml` manifest. Layer dimension chaining is *not*
/// required here — per-layer compression works on any layer list; the
/// executor build ([`NetworkExecutor`]) enforces chaining.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkCheckpoint {
    layers: Vec<NetworkLayer>,
}

impl NetworkCheckpoint {
    pub fn new(layers: Vec<NetworkLayer>) -> Result<Self> {
        ensure!(!layers.is_empty(), "a network needs at least one layer");
        for (i, l) in layers.iter().enumerate() {
            if let Some(b) = &l.bias {
                ensure!(
                    b.len() == l.weight.rows(),
                    "layer {}: bias length {} != {} output rows",
                    i + 1,
                    b.len(),
                    l.weight.rows()
                );
            }
        }
        Ok(NetworkCheckpoint { layers })
    }

    pub fn layers(&self) -> &[NetworkLayer] {
        &self.layers
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Input dimension of the first layer.
    pub fn input_dim(&self) -> usize {
        self.layers[0].weight.cols()
    }

    /// Output dimension of the last layer.
    pub fn output_dim(&self) -> usize {
        self.layers[self.layers.len() - 1].weight.rows()
    }

    /// True when `path` is a multi-layer checkpoint directory (carries a
    /// `network.toml` manifest) — how the registry and CLI dispatch
    /// between the network and single-matrix load paths.
    pub fn is_network_dir(path: &Path) -> bool {
        path.is_dir() && path.join("network.toml").is_file()
    }

    /// Write `layer<k>.weight.npy` (+ `layer<k>.bias.npy`) per layer and
    /// the `network.toml` manifest, creating the directory.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        let mut manifest = String::from("# lccnn network checkpoint manifest\n[network]\n");
        let _ = writeln!(manifest, "layers = {}", self.layers.len());
        for (i, layer) in self.layers.iter().enumerate() {
            let k = i + 1;
            let w = &layer.weight;
            write_npy(
                &dir.join(format!("layer{k}.weight.npy")),
                &NpyArray::f32(vec![w.rows(), w.cols()], w.data().to_vec()),
            )?;
            if let Some(b) = &layer.bias {
                write_npy(
                    &dir.join(format!("layer{k}.bias.npy")),
                    &NpyArray::f32(vec![b.len()], b.clone()),
                )?;
            }
            let _ = writeln!(
                manifest,
                "\n[network.layer.{k}]\nrows = {}\ncols = {}\nactivation = \"{}\"\nbias = {}",
                w.rows(),
                w.cols(),
                layer.activation.as_str(),
                layer.bias.is_some()
            );
        }
        std::fs::write(dir.join("network.toml"), manifest)
            .with_context(|| format!("write network manifest in {}", dir.display()))
    }

    /// Load a checkpoint directory, validating every `.npy` shape
    /// against the manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("network.toml");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read network manifest {}", manifest_path.display()))?;
        let t = parse_toml(&text)
            .with_context(|| format!("parse network manifest {}", manifest_path.display()))?;
        let n = t
            .get("network")
            .and_then(|s| s.get("layers"))
            .and_then(TomlValue::as_int)
            .context("network.toml: [network] layers count missing")?;
        ensure!(n >= 1, "network.toml: layers must be >= 1, got {n}");
        let n = n as usize;
        let mut layers = Vec::with_capacity(n);
        for k in 1..=n {
            let sec = format!("network.layer.{k}");
            let s = t.get(&sec).with_context(|| format!("network.toml: [{sec}] missing"))?;
            let rows = manifest_dim(s, &sec, "rows")?;
            let cols = manifest_dim(s, &sec, "cols")?;
            let activation = match s.get("activation").and_then(TomlValue::as_str) {
                Some(a) => Activation::parse(a).with_context(|| {
                    format!("network.toml: [{sec}] unknown activation {a:?} (use relu|identity)")
                })?,
                // hidden layers default to relu, the output layer to identity
                None if k == n => Activation::Identity,
                None => Activation::Relu,
            };
            let has_bias = s.get("bias").and_then(TomlValue::as_bool).unwrap_or(false);
            let wpath = dir.join(format!("layer{k}.weight.npy"));
            let arr = read_npy(&wpath)?;
            ensure!(
                arr.shape == [rows, cols],
                "{}: shape {:?} != manifest {rows}x{cols}",
                wpath.display(),
                arr.shape
            );
            let weight = Matrix::from_vec(rows, cols, arr.data);
            let bias = if has_bias {
                let bpath = dir.join(format!("layer{k}.bias.npy"));
                let b = read_npy(&bpath)?;
                ensure!(
                    b.numel() == rows,
                    "{}: {} values != {rows} output rows",
                    bpath.display(),
                    b.numel()
                );
                Some(b.data)
            } else {
                None
            };
            layers.push(NetworkLayer { weight, bias, activation });
        }
        NetworkCheckpoint::new(layers)
    }
}

/// Read one positive manifest dimension (`rows` / `cols`).
fn manifest_dim(s: &BTreeMap<String, TomlValue>, sec: &str, key: &str) -> Result<usize> {
    let v = s
        .get(key)
        .and_then(TomlValue::as_int)
        .with_context(|| format!("network.toml: [{sec}] {key} missing"))?;
    ensure!(v >= 1, "network.toml: [{sec}] {key} must be >= 1, got {v}");
    Ok(v as usize)
}

/// Synthetic multi-layer checkpoint for demos and smokes: per layer,
/// column groups of 4 = 3 near-identical columns + 1 exactly-zero
/// column, so pruning, sharing and LCC all genuinely engage on every
/// layer (the network analogue of [`super::demo_weights`]). Hidden
/// layers get ReLU, the output layer identity; magnitudes are kept
/// small so chained activations stay inside the fixed-point range.
pub fn demo_network(dims: &[usize], seed: u64) -> NetworkCheckpoint {
    assert!(dims.len() >= 2, "need at least input and output dims");
    let mut rng = Rng::new(seed);
    let last = dims.len() - 2;
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (k, pair) in dims.windows(2).enumerate() {
        let (nin, nout) = (pair[0], pair[1]);
        let mut w = Matrix::zeros(nout, nin);
        let mut c = 0;
        while c < nin {
            let group = (nin - c).min(4);
            // the 4th column of a full group stays zero (prunable);
            // short tail groups are fully filled
            let filled = if group == 4 { 3 } else { group };
            let base = rng.normal_vec(nout, 0.3);
            for j in 0..filled {
                for r in 0..nout {
                    *w.at_mut(r, c + j) = base[r] + 0.005 * rng.normal_f32();
                }
            }
            c += group;
        }
        let bias: Vec<f32> = (0..nout).map(|_| 0.05 * rng.normal_f32()).collect();
        let activation = if k == last { Activation::Identity } else { Activation::Relu };
        layers.push(NetworkLayer { weight: w, bias: Some(bias), activation });
    }
    NetworkCheckpoint::new(layers).expect("demo network is well-formed")
}

/// Aggregated accounting of a network compression run: one
/// [`CompressionReport`] per layer plus network totals.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkReport {
    pub layers: Vec<CompressionReport>,
}

impl NetworkReport {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Σ per-layer final additions (one forward pass through the
    /// compressed network).
    pub fn total_additions(&self) -> usize {
        self.layers.iter().map(CompressionReport::final_additions).sum()
    }

    /// Σ per-layer CSD baselines (one dense forward pass).
    pub fn baseline_additions(&self) -> usize {
        self.layers.iter().map(|r| r.baseline_additions).sum()
    }

    /// Network compression ratio: baseline / compressed additions.
    pub fn total_ratio(&self) -> f64 {
        self.baseline_additions() as f64 / self.total_additions().max(1) as f64
    }

    /// Worst per-layer relative error.
    pub fn max_rel_err(&self) -> f64 {
        self.layers.iter().map(CompressionReport::final_rel_err).fold(0.0, f64::max)
    }

    /// Render per-layer rows plus a total row for the CLI.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!("network compression report ({} layers)", self.layers.len()),
            &["layer", "shape", "additions", "ratio", "rel err"],
        );
        for (i, r) in self.layers.iter().enumerate() {
            t.add_row(vec![
                format!("layer{}", i + 1),
                format!("{}x{}", r.input_rows, r.input_cols),
                r.final_additions().to_string(),
                format!("{:.2}", r.final_ratio()),
                format!("{:.2e}", r.final_rel_err()),
            ]);
        }
        t.add_row(vec![
            "total".into(),
            "-".into(),
            self.total_additions().to_string(),
            format!("{:.2}", self.total_ratio()),
            format!("{:.2e}", self.max_rel_err()),
        ]);
        t.render()
    }

    /// Tab-separated per-layer rows + total, for artifact directories.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("layer\trows\tcols\tadditions\tbaseline\tratio\trel_err\n");
        for (i, r) in self.layers.iter().enumerate() {
            let _ = writeln!(
                out,
                "layer{}\t{}\t{}\t{}\t{}\t{}\t{}",
                i + 1,
                r.input_rows,
                r.input_cols,
                r.final_additions(),
                r.baseline_additions,
                r.final_ratio(),
                r.final_rel_err()
            );
        }
        let _ = writeln!(
            out,
            "total\t-\t-\t{}\t{}\t{}\t{}",
            self.total_additions(),
            self.baseline_additions(),
            self.total_ratio(),
            self.max_rel_err()
        );
        out
    }

    /// Publish as `compress.network.*` gauges: network totals plus
    /// `compress.network.layer.<k>.additions|ratio|rel_err` per layer.
    pub fn publish(&self, metrics: &Metrics) {
        metrics.incr("compress.network.runs", 1);
        metrics.gauge("compress.network.layers", self.layers.len() as f64);
        metrics.gauge("compress.network.total_additions", self.total_additions() as f64);
        metrics.gauge("compress.network.baseline_additions", self.baseline_additions() as f64);
        metrics.gauge("compress.network.total_ratio", self.total_ratio());
        for (i, r) in self.layers.iter().enumerate() {
            let p = format!("compress.network.layer.{}", i + 1);
            metrics.gauge(&format!("{p}.additions"), r.final_additions() as f64);
            metrics.gauge(&format!("{p}.ratio"), r.final_ratio());
            metrics.gauge(&format!("{p}.rel_err"), r.final_rel_err());
        }
    }
}

/// One compressed layer of a [`CompressedNetwork`]: the single-matrix
/// pipeline artifact plus the layer's bias and activation.
pub struct CompressedLayer {
    model: CompressedModel,
    bias: Option<Vec<f32>>,
    activation: Activation,
}

impl CompressedLayer {
    pub fn model(&self) -> &CompressedModel {
        &self.model
    }

    pub fn bias(&self) -> Option<&[f32]> {
        self.bias.as_deref()
    }

    pub fn activation(&self) -> Activation {
        self.activation
    }
}

/// Drives the single-matrix [`Pipeline`] once per network layer, each
/// layer under its recipe-resolved stage list and parameters
/// ([`Recipe::layer_recipe`]).
///
/// ```
/// use lccnn::compress::{demo_network, NetworkPipeline, Recipe};
/// use lccnn::exec::Executor;
///
/// let ckpt = demo_network(&[12, 10, 8, 6], 0);
/// let net = NetworkPipeline::from_recipe(&Recipe::default()).unwrap().run(&ckpt).unwrap();
/// assert_eq!(net.report().num_layers(), 3);
/// assert!(net.report().total_ratio() > 1.0);
/// // the chained engine serves the whole network in one call
/// let y = net.executor().unwrap().execute_one(&[0.5; 12]);
/// assert_eq!(y.len(), 6);
/// ```
pub struct NetworkPipeline {
    recipe: Recipe,
}

impl NetworkPipeline {
    /// Validates the recipe's global stage composition up front (every
    /// per-layer resolved list is re-validated when its layer runs).
    pub fn from_recipe(recipe: &Recipe) -> Result<Self> {
        Pipeline::from_recipe(recipe).context("network recipe (global stages)")?;
        Ok(NetworkPipeline { recipe: recipe.clone() })
    }

    pub fn recipe(&self) -> &Recipe {
        &self.recipe
    }

    /// Compress every layer of `ckpt` and aggregate the accounting.
    pub fn run(&self, ckpt: &NetworkCheckpoint) -> Result<CompressedNetwork> {
        if let Some(&k) = self.recipe.layers.keys().find(|&&k| k > ckpt.num_layers()) {
            bail!("recipe overrides layer {k} but the checkpoint has {} layers", ckpt.num_layers());
        }
        let mut layers = Vec::with_capacity(ckpt.num_layers());
        let mut reports = Vec::with_capacity(ckpt.num_layers());
        for (i, layer) in ckpt.layers().iter().enumerate() {
            let k = i + 1;
            let recipe = self.recipe.layer_recipe(k)?;
            let model = Pipeline::from_recipe(&recipe)
                .and_then(|p| p.run(&layer.weight))
                .with_context(|| format!("compressing network layer {k}"))?;
            reports.push(model.report().clone());
            layers.push(CompressedLayer {
                model,
                bias: layer.bias.clone(),
                activation: layer.activation,
            });
        }
        Ok(CompressedNetwork {
            layers,
            report: NetworkReport { layers: reports },
            gate_epsilon: self.recipe.gate_epsilon,
        })
    }

    /// [`NetworkPipeline::run`], publishing the aggregated report
    /// (`compress.network.*` series).
    pub fn run_with_metrics(
        &self,
        ckpt: &NetworkCheckpoint,
        metrics: &Metrics,
    ) -> Result<CompressedNetwork> {
        let net = self.run(ckpt)?;
        net.report().publish(metrics);
        Ok(net)
    }
}

/// The result of a network compression run: per-layer artifacts plus
/// the aggregated report — convertible into the chained serving engine
/// or a per-layer-range sub-engine for remote workers.
pub struct CompressedNetwork {
    layers: Vec<CompressedLayer>,
    report: NetworkReport,
    gate_epsilon: Option<f64>,
}

impl CompressedNetwork {
    pub fn report(&self) -> &NetworkReport {
        &self.report
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layers(&self) -> &[CompressedLayer] {
        &self.layers
    }

    /// The recipe-declared accuracy-gate tolerance, when one was set.
    pub fn gate_epsilon(&self) -> Option<f64> {
        self.gate_epsilon
    }

    fn part(layer: &CompressedLayer) -> LayerPart {
        LayerPart {
            inf_norm: inf_norm(&layer.model.state().reconstruction()),
            exec: layer.model.executor(),
            bias: layer.bias.clone(),
            activation: layer.activation,
        }
    }

    /// The chained serving engine (cloning the per-layer engines).
    pub fn executor(&self) -> Result<NetworkExecutor> {
        NetworkExecutor::from_parts(self.layers.iter().map(Self::part).collect())
    }

    /// Consume into the chained serving engine without cloning the
    /// per-layer engines (the runtime checkpoint-load path).
    pub fn into_executor(self) -> Result<NetworkExecutor> {
        let parts = self
            .layers
            .into_iter()
            .map(|l| {
                let inf_norm = inf_norm(&l.model.state().reconstruction());
                LayerPart {
                    inf_norm,
                    exec: l.model.into_executor(),
                    bias: l.bias,
                    activation: l.activation,
                }
            })
            .collect();
        NetworkExecutor::from_parts(parts)
    }

    /// A sub-chain serving only the layers in `range` (0-based, end
    /// exclusive) — what a remote `shard-worker --layer-range` process
    /// serves. Every layer in the range applies its bias and activation,
    /// so chaining the range engines in order reproduces the full
    /// network exactly.
    pub fn layer_range_executor(&self, range: Range<usize>) -> Result<NetworkExecutor> {
        ensure!(
            range.start < range.end && range.end <= self.layers.len(),
            "layer range {}..{} out of 0..{}",
            range.start,
            range.end,
            self.layers.len()
        );
        NetworkExecutor::from_parts(self.layers[range].iter().map(Self::part).collect())
    }

    /// Evaluate one sample by hand-chaining the per-layer *oracle*
    /// evaluation of the identical compressed representation
    /// (kept-feature gather → segment sums → [`NaiveExecutor`] over the
    /// adder graph → bias → activation). Float serving must be
    /// bit-identical to this; fixed serving within
    /// [`NetworkExecutor::max_error_bound`].
    pub fn oracle_forward(&self, x: &[f32]) -> Vec<f32> {
        self.oracle_forward_batch(&[x.to_vec()]).pop().expect("one sample in, one out")
    }

    /// Batch [`CompressedNetwork::oracle_forward`] (the oracle graph is
    /// instantiated once per layer, not per sample).
    pub fn oracle_forward_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut cur: Vec<Vec<f32>> = xs.to_vec();
        for l in &self.layers {
            let state = l.model.state();
            let oracle = state.lcc().map(|s| NaiveExecutor::new(s.graph().clone()));
            cur = cur
                .iter()
                .map(|x| {
                    let xk: Vec<f32> = state.kept().iter().map(|&i| x[i]).collect();
                    let mut y = if let Some(slcc) = state.lcc() {
                        let sums = slcc.layer.segment_sums(&xk);
                        oracle.as_ref().expect("oracle exists with lcc").execute_one(&sums)
                    } else if let Some(sh) = state.shared() {
                        sh.apply(&xk)
                    } else {
                        state.dense().matvec(&xk)
                    };
                    if let Some(b) = &l.bias {
                        for (v, add) in y.iter_mut().zip(b) {
                            *v += *add;
                        }
                    }
                    l.activation.apply(&mut y);
                    y
                })
                .collect();
        }
        cur
    }
}

/// Operator ∞-norm (max absolute row sum) — the per-layer amplification
/// factor of the network error recurrence.
fn inf_norm(m: &Matrix) -> f64 {
    (0..m.rows())
        .map(|r| m.row(r).iter().map(|v| v.abs() as f64).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Build input for [`NetworkExecutor::from_parts`]: one layer's engine
/// plus the chaining metadata the executor needs.
struct LayerPart {
    exec: PipelineExecutor,
    bias: Option<Vec<f32>>,
    activation: Activation,
    /// ∞-norm of the layer's compressed linear map (error amplification)
    inf_norm: f64,
}

/// One chained layer at serve time, with its running batch counters.
struct LayerRun {
    exec: PipelineExecutor,
    bias: Option<Vec<f32>>,
    activation: Activation,
    additions: Option<usize>,
    err_bound: f64,
    batch_us: AtomicU64,
    batches: AtomicU64,
}

impl LayerRun {
    /// Engine → bias → activation, batch-major throughout, timing the
    /// whole layer step.
    fn run(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        let t0 = Instant::now();
        self.exec.execute_batch_into(xs, ys);
        if let Some(b) = &self.bias {
            for y in ys.iter_mut() {
                for (v, add) in y.iter_mut().zip(b) {
                    *v += *add;
                }
            }
        }
        for y in ys.iter_mut() {
            self.activation.apply(y);
        }
        self.batch_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// The chained network serving engine: per-layer [`PipelineExecutor`]s
/// connected through batch-major bias/activation kernels, never leaving
/// batch-major form. Inter-layer activations ping-pong between two
/// reused lane buffers (concurrent batches fall back to local buffers
/// instead of serializing). Per-layer analytic error bounds propagate
/// into [`NetworkExecutor::max_error_bound`]; per-layer timing,
/// additions and bounds surface through
/// [`crate::exec::Executor::layer_stats`].
pub struct NetworkExecutor {
    layers: Vec<LayerRun>,
    input_dim: usize,
    output_dim: usize,
    err_bound: f64,
    scratch: Mutex<(Vec<Vec<f32>>, Vec<Vec<f32>>)>,
}

impl NetworkExecutor {
    fn from_parts(parts: Vec<LayerPart>) -> Result<NetworkExecutor> {
        ensure!(!parts.is_empty(), "a network executor needs at least one layer");
        let mut bound = 0.0f64;
        let mut layers: Vec<LayerRun> = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let LayerPart { exec, bias, activation, inf_norm } = part;
            if let Some(prev) = layers.last() {
                ensure!(
                    exec.num_inputs() == prev.exec.num_outputs(),
                    "layer {} input dim {} != layer {} output dim {}",
                    i + 1,
                    exec.num_inputs(),
                    i,
                    prev.exec.num_outputs()
                );
            }
            if let Some(b) = &bias {
                ensure!(
                    b.len() == exec.num_outputs(),
                    "layer {}: bias length {} != {} engine outputs",
                    i + 1,
                    b.len(),
                    exec.num_outputs()
                );
            }
            // error recurrence: an input perturbation passes through the
            // layer's linear map (amplified at most by its ∞-norm — the
            // bias shift is exact and ReLU is 1-Lipschitz) and the
            // layer's own datapath error adds on top
            let err_bound = exec.max_error_bound();
            bound = inf_norm * bound + err_bound;
            layers.push(LayerRun {
                additions: exec.additions(),
                err_bound,
                exec,
                bias,
                activation,
                batch_us: AtomicU64::new(0),
                batches: AtomicU64::new(0),
            });
        }
        let input_dim = layers.first().expect("non-empty").exec.num_inputs();
        let output_dim = layers.last().expect("non-empty").exec.num_outputs();
        Ok(NetworkExecutor {
            layers,
            input_dim,
            output_dim,
            err_bound: bound,
            scratch: Mutex::new((Vec::new(), Vec::new())),
        })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Propagated analytic |served − exact| bound of the whole chain:
    /// 0.0 when every layer serves a float engine (bit-identical to the
    /// hand-chained oracle), the recurrence over per-layer bounds and
    /// ∞-norms in fixed mode.
    pub fn max_error_bound(&self) -> f64 {
        self.err_bound
    }

    /// Σ per-layer additions, when every layer has a lowered program.
    pub fn total_additions(&self) -> Option<usize> {
        self.layers.iter().map(|l| l.additions).sum()
    }
}

impl Executor for NetworkExecutor {
    fn num_inputs(&self) -> usize {
        self.input_dim
    }

    fn num_outputs(&self) -> usize {
        self.output_dim
    }

    fn name(&self) -> &'static str {
        "network-exec"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        let n = self.layers.len();
        if n == 1 {
            self.layers[0].run(xs, ys);
            return;
        }
        // reuse the inter-layer lane buffers when free; a concurrent
        // batch falls back to locals rather than serializing on the lock
        let mut guard = self.scratch.try_lock().ok();
        let (mut local_a, mut local_b) = (Vec::new(), Vec::new());
        let (a, b) = match guard.as_deref_mut() {
            Some((a, b)) => (a, b),
            None => (&mut local_a, &mut local_b),
        };
        self.layers[0].run(xs, a);
        let (mut cur, mut next) = (a, b);
        for layer in &self.layers[1..n - 1] {
            layer.run(cur, next);
            std::mem::swap(&mut cur, &mut next);
        }
        self.layers[n - 1].run(cur, ys);
    }

    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            for (label, health) in l.exec.health_report() {
                let name = if label.is_empty() {
                    format!("layer.{}", i + 1)
                } else {
                    format!("layer.{}.{label}", i + 1)
                };
                out.push((name, health));
            }
        }
        out
    }

    fn layer_stats(&self) -> Vec<LayerStat> {
        self.layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerStat {
                index: i + 1,
                batch_us_total: l.batch_us.load(Ordering::Relaxed),
                batches: l.batches.load(Ordering::Relaxed),
                additions: l.additions,
                err_bound: l.err_bound,
            })
            .collect()
    }
}

impl std::fmt::Debug for NetworkExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NetworkExecutor")
            .field("layers", &self.layers.len())
            .field("input_dim", &self.input_dim)
            .field("output_dim", &self.output_dim)
            .field("err_bound", &self.err_bound)
            .finish()
    }
}

/// Dimension-checked sequential composition of arbitrary executors —
/// the serve-side gather when each hop is a [`crate::exec::RemoteExecutor`]
/// fronting a worker that serves one layer range. Hop errors propagate
/// typed through [`Executor::try_execute_batch_into`], so shed/failover
/// semantics compose exactly like single-engine remote serving.
pub struct ChainedExecutor {
    hops: Vec<Arc<dyn Executor>>,
}

impl ChainedExecutor {
    pub fn new(hops: Vec<Arc<dyn Executor>>) -> Result<Self> {
        ensure!(!hops.is_empty(), "a chained executor needs at least one hop");
        for (i, pair) in hops.windows(2).enumerate() {
            ensure!(
                pair[1].num_inputs() == pair[0].num_outputs(),
                "hop {} output dim {} != hop {} input dim {}",
                i,
                pair[0].num_outputs(),
                i + 1,
                pair[1].num_inputs()
            );
        }
        Ok(ChainedExecutor { hops })
    }

    pub fn num_hops(&self) -> usize {
        self.hops.len()
    }
}

impl Executor for ChainedExecutor {
    fn num_inputs(&self) -> usize {
        self.hops.first().expect("non-empty").num_inputs()
    }

    fn num_outputs(&self) -> usize {
        self.hops.last().expect("non-empty").num_outputs()
    }

    fn name(&self) -> &'static str {
        "chained-exec"
    }

    fn execute_batch_into(&self, xs: &[Vec<f32>], ys: &mut Vec<Vec<f32>>) {
        self.try_execute_batch_into(xs, ys).expect("chained hop failed");
    }

    fn try_execute_batch_into(
        &self,
        xs: &[Vec<f32>],
        ys: &mut Vec<Vec<f32>>,
    ) -> std::result::Result<(), ExecError> {
        let n = self.hops.len();
        if n == 1 {
            return self.hops[0].try_execute_batch_into(xs, ys);
        }
        let mut cur = Vec::new();
        let mut next = Vec::new();
        self.hops[0].try_execute_batch_into(xs, &mut cur)?;
        for hop in &self.hops[1..n - 1] {
            hop.try_execute_batch_into(&cur, &mut next)?;
            std::mem::swap(&mut cur, &mut next);
        }
        self.hops[n - 1].try_execute_batch_into(&cur, ys)
    }

    fn health_report(&self) -> Vec<(String, ExecHealth)> {
        let mut out = Vec::new();
        for (i, hop) in self.hops.iter().enumerate() {
            for (label, health) in hop.health_report() {
                let name = if label.is_empty() {
                    format!("hop.{i}")
                } else {
                    format!("hop.{i}.{label}")
                };
                out.push((name, health));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecConfig, ExecMode};

    fn serial_recipe() -> Recipe {
        Recipe { exec: ExecConfig::serial(), ..Recipe::default() }
    }

    fn test_inputs(dim: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_vec(dim, 1.0)).collect()
    }

    #[test]
    fn checkpoint_save_load_round_trip() {
        let ckpt = demo_network(&[9, 7, 5], 3);
        let dir = std::env::temp_dir().join(format!("lccnn-net-ckpt-{}", std::process::id()));
        ckpt.save(&dir).unwrap();
        assert!(NetworkCheckpoint::is_network_dir(&dir));
        let back = NetworkCheckpoint::load(&dir).unwrap();
        assert_eq!(back, ckpt, "f32 npy round-trip is lossless");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_errors_are_typed() {
        let dir = std::env::temp_dir().join(format!("lccnn-net-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!NetworkCheckpoint::is_network_dir(&dir), "no manifest yet");
        // manifest names a layer whose npy file has the wrong shape
        let ckpt = demo_network(&[6, 4], 1);
        ckpt.save(&dir).unwrap();
        let w = &ckpt.layers()[0].weight;
        write_npy(
            &dir.join("layer1.weight.npy"),
            &NpyArray::f32(vec![w.cols(), w.rows()], w.data().to_vec()),
        )
        .unwrap();
        let err = NetworkCheckpoint::load(&dir).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn network_executor_matches_hand_chained_oracle_float() {
        let ckpt = demo_network(&[12, 10, 6], 11);
        let net = NetworkPipeline::from_recipe(&serial_recipe()).unwrap().run(&ckpt).unwrap();
        assert_eq!(net.report().num_layers(), 3);
        assert!(net.report().total_additions() > 0);
        assert!(net.report().total_ratio() > 1.0, "demo net must actually compress");
        let exec = net.executor().unwrap();
        assert_eq!(exec.num_inputs(), 12);
        assert_eq!(exec.num_outputs(), 6);
        assert_eq!(exec.max_error_bound(), 0.0, "float chain is exact");
        let xs = test_inputs(12, 7, 5);
        let got = exec.execute_batch(&xs);
        let want = net.oracle_forward_batch(&xs);
        assert_eq!(got, want, "float serving must be bit-identical to the chained oracle");
        // per-layer stats accumulated one batch per layer
        let stats = exec.layer_stats();
        assert_eq!(stats.len(), 3);
        for (i, s) in stats.iter().enumerate() {
            assert_eq!(s.index, i + 1);
            assert_eq!(s.batches, 1);
            assert!(s.additions.is_some(), "lcc recipe lowers every layer");
        }
    }

    #[test]
    fn fixed_network_within_propagated_bound() {
        let ckpt = demo_network(&[10, 8, 5], 21);
        let recipe = Recipe {
            exec: ExecConfig { exec_mode: ExecMode::Fixed, ..ExecConfig::serial() },
            ..Recipe::default()
        };
        let net = NetworkPipeline::from_recipe(&recipe).unwrap().run(&ckpt).unwrap();
        let exec = net.executor().unwrap();
        let bound = exec.max_error_bound();
        assert!(bound > 0.0, "fixed chain must report a bound");
        let xs = test_inputs(10, 6, 9);
        let got = exec.execute_batch(&xs);
        let want = net.oracle_forward_batch(&xs);
        for (ws, gs) in want.iter().zip(&got) {
            for (wv, gv) in ws.iter().zip(gs) {
                let tol = bound + 1e-3 * (1.0 + wv.abs() as f64);
                assert!(((wv - gv).abs() as f64) <= tol, "fixed {gv} vs oracle {wv} > {bound}");
            }
        }
    }

    #[test]
    fn layer_range_chain_reproduces_full_network() {
        let ckpt = demo_network(&[11, 9, 7, 4], 31);
        let net = NetworkPipeline::from_recipe(&serial_recipe()).unwrap().run(&ckpt).unwrap();
        let full = net.executor().unwrap();
        let front = net.layer_range_executor(0..2).unwrap();
        let back = net.layer_range_executor(2..4).unwrap();
        assert_eq!(front.num_layers(), 2);
        assert_eq!(front.num_outputs(), back.num_inputs());
        let hops: Vec<Arc<dyn Executor>> = vec![Arc::new(front), Arc::new(back)];
        let chain = ChainedExecutor::new(hops).unwrap();
        let xs = test_inputs(11, 5, 13);
        assert_eq!(
            chain.execute_batch(&xs),
            full.execute_batch(&xs),
            "layer-range sub-chains gather bit-identically"
        );
        assert!(net.layer_range_executor(2..5).is_err(), "range end past the last layer");
        let a = net.layer_range_executor(0..1).unwrap();
        let c = net.layer_range_executor(2..3).unwrap();
        let bad: Vec<Arc<dyn Executor>> = vec![Arc::new(a), Arc::new(c)];
        assert!(ChainedExecutor::new(bad).is_err(), "mis-chained hops are rejected");
    }

    #[test]
    fn per_layer_overrides_steer_individual_layers() {
        let ckpt = demo_network(&[8, 6, 4], 41);
        let mut recipe = serial_recipe();
        // layer 2 skips share+prune entirely
        recipe.layers.entry(2).or_default().stages = Some(vec!["lcc".to_string()]);
        let net = NetworkPipeline::from_recipe(&recipe).unwrap().run(&ckpt).unwrap();
        let names: Vec<Vec<&str>> = net
            .report()
            .layers
            .iter()
            .map(|r| r.stages.iter().map(|s| s.stage.as_str()).collect())
            .collect();
        assert_eq!(names[0], vec!["prune", "share", "lcc"]);
        assert_eq!(names[1], vec!["lcc"], "layer 2 stage-list override wins");
        assert_eq!(names[2], vec!["prune", "share", "lcc"]);
        // an override beyond the checkpoint is a typed error
        let mut bad = serial_recipe();
        bad.layers.entry(9).or_default().stages = Some(vec!["lcc".to_string()]);
        let err = NetworkPipeline::from_recipe(&bad).unwrap().run(&ckpt).unwrap_err().to_string();
        assert!(err.contains("layer 9"), "{err}");
    }

    #[test]
    fn activation_parse_and_apply() {
        assert_eq!(Activation::parse("relu"), Some(Activation::Relu));
        assert_eq!(Activation::parse("identity"), Some(Activation::Identity));
        assert_eq!(Activation::parse("none"), Some(Activation::Identity));
        assert_eq!(Activation::parse("tanh"), None);
        let mut y = vec![-1.0, 0.5, -0.0, 2.0];
        Activation::Relu.apply(&mut y);
        assert_eq!(y, vec![0.0, 0.5, 0.0, 2.0]);
        let mut z = vec![-1.0, 0.5];
        Activation::Identity.apply(&mut z);
        assert_eq!(z, vec![-1.0, 0.5]);
    }
}
