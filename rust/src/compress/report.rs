//! Per-run compression accounting.
//!
//! A [`CompressionReport`] records, after every stage, the quantities
//! the paper tracks: additions (the cost metric), the compression ratio
//! against the input matrix's CSD baseline, shapes (active columns,
//! clusters) and the approximation error against the exact post-prune
//! reference. Reports are deterministic — same recipe + same weights
//! produce an equal report — and publishable into
//! [`crate::metrics::Metrics`] as `compress.*` series.

use super::state::ModelState;
use crate::metrics::Metrics;
use crate::quant::FixedPointFormat;
use crate::report::Table;

/// The artifact's accounting after one stage.
#[derive(Clone, Debug, PartialEq)]
pub struct StageReport {
    pub stage: String,
    /// additions to evaluate the representation once
    pub additions: usize,
    /// baseline additions / stage additions
    pub ratio: f64,
    pub active_columns: usize,
    /// clusters after sharing; 0 before
    pub clusters: usize,
    /// relative Frobenius error vs the exact post-prune reference
    pub rel_err: f64,
}

/// Accounting for a whole pipeline run.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionReport {
    pub input_rows: usize,
    pub input_cols: usize,
    /// CSD adders of the input matrix (the paper's dense baseline)
    pub baseline_additions: usize,
    pub stages: Vec<StageReport>,
}

impl CompressionReport {
    pub(crate) fn new(input_rows: usize, input_cols: usize, baseline_additions: usize) -> Self {
        CompressionReport { input_rows, input_cols, baseline_additions, stages: Vec::new() }
    }

    pub(crate) fn push_stage(&mut self, name: &str, state: &ModelState, fmt: FixedPointFormat) {
        let additions = state.additions(fmt);
        self.stages.push(StageReport {
            stage: name.to_string(),
            additions,
            ratio: self.baseline_additions as f64 / additions.max(1) as f64,
            active_columns: state.active_columns(),
            clusters: state.clusters(),
            rel_err: state.rel_err(),
        });
    }

    /// Additions of the final representation (the baseline if no stage
    /// ran).
    pub fn final_additions(&self) -> usize {
        self.stages.last().map(|s| s.additions).unwrap_or(self.baseline_additions)
    }

    /// Approximation error of the final representation.
    pub fn final_rel_err(&self) -> f64 {
        self.stages.last().map(|s| s.rel_err).unwrap_or(0.0)
    }

    /// Compression ratio of the final representation vs the baseline.
    pub fn final_ratio(&self) -> f64 {
        self.baseline_additions as f64 / self.final_additions().max(1) as f64
    }

    /// Render as an aligned table for the CLI.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "compression report ({}x{}, baseline {} CSD adds)",
                self.input_rows, self.input_cols, self.baseline_additions
            ),
            &["stage", "additions", "ratio", "cols", "clusters", "rel err"],
        );
        for s in &self.stages {
            t.add_row(vec![
                s.stage.clone(),
                s.additions.to_string(),
                format!("{:.2}", s.ratio),
                s.active_columns.to_string(),
                if s.clusters > 0 { s.clusters.to_string() } else { "-".into() },
                format!("{:.2e}", s.rel_err),
            ]);
        }
        t.render()
    }

    /// Tab-separated rows for artifact directories and sweeps.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("stage\tadditions\tratio\tcols\tclusters\trel_err\n");
        out.push_str(&format!(
            "baseline\t{}\t1\t{}\t0\t0\n",
            self.baseline_additions, self.input_cols
        ));
        for s in &self.stages {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\n",
                s.stage, s.additions, s.ratio, s.active_columns, s.clusters, s.rel_err
            ));
        }
        out
    }

    /// Publish the accounting as `compress.*` metrics: one gauge set per
    /// stage (`compress.<stage>.additions|ratio|rel_err|cols|clusters`),
    /// the baseline, and a `compress.runs` counter.
    pub fn publish(&self, metrics: &Metrics) {
        metrics.incr("compress.runs", 1);
        metrics.gauge("compress.baseline_additions", self.baseline_additions as f64);
        metrics.gauge("compress.final_additions", self.final_additions() as f64);
        metrics.gauge("compress.final_ratio", self.final_ratio());
        for s in &self.stages {
            let p = format!("compress.{}", s.stage);
            metrics.gauge(&format!("{p}.additions"), s.additions as f64);
            metrics.gauge(&format!("{p}.ratio"), s.ratio);
            metrics.gauge(&format!("{p}.rel_err"), s.rel_err);
            metrics.gauge(&format!("{p}.cols"), s.active_columns as f64);
            metrics.gauge(&format!("{p}.clusters"), s.clusters as f64);
        }
    }
}

/// Which of `points` (cost, error) lie on the Pareto frontier of the
/// minimize-both problem.
///
/// A point is dominated — and excluded — iff some other point is no
/// worse on both axes and strictly better on at least one. Exact ties
/// on both axes dominate nothing and are all kept, so distinct recipes
/// landing on the same (additions, rel-err) point each stay visible in
/// the sweep output. O(n²), fine for recipe sweeps (n ≲ thousands).
///
/// ```
/// use lccnn::compress::pareto_frontier;
///
/// // (additions, rel_err): the middle point is beaten on both axes.
/// let front = pareto_frontier(&[(100, 0.5), (200, 0.6), (300, 0.1)]);
/// assert_eq!(front, vec![true, false, true]);
/// ```
pub fn pareto_frontier(points: &[(usize, f64)]) -> Vec<bool> {
    points
        .iter()
        .map(|&(cost, err)| {
            !points.iter().any(|&(c, e)| c <= cost && e <= err && (c < cost || e < err))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CompressionReport {
        CompressionReport {
            input_rows: 8,
            input_cols: 12,
            baseline_additions: 1000,
            stages: vec![
                StageReport {
                    stage: "prune".into(),
                    additions: 500,
                    ratio: 2.0,
                    active_columns: 8,
                    clusters: 0,
                    rel_err: 0.0,
                },
                StageReport {
                    stage: "lcc".into(),
                    additions: 100,
                    ratio: 10.0,
                    active_columns: 8,
                    clusters: 0,
                    rel_err: 0.01,
                },
            ],
        }
    }

    #[test]
    fn final_quantities() {
        let r = sample();
        assert_eq!(r.final_additions(), 100);
        assert_eq!(r.final_rel_err(), 0.01);
        assert!((r.final_ratio() - 10.0).abs() < 1e-12);
        let empty = CompressionReport::new(4, 4, 77);
        assert_eq!(empty.final_additions(), 77);
        assert_eq!(empty.final_rel_err(), 0.0);
    }

    #[test]
    fn render_and_tsv_contain_all_stages() {
        let r = sample();
        let text = r.render();
        assert!(text.contains("prune") && text.contains("lcc"), "{text}");
        let tsv = r.to_tsv();
        assert_eq!(tsv.lines().count(), 4, "header + baseline + 2 stages:\n{tsv}");
        assert!(tsv.starts_with("stage\t"));
    }

    #[test]
    fn pareto_excludes_dominated_keeps_ties() {
        // single point is trivially on the frontier
        assert_eq!(pareto_frontier(&[(10, 0.5)]), vec![true]);
        // strictly dominated on both axes: excluded
        assert_eq!(pareto_frontier(&[(10, 0.1), (20, 0.2)]), vec![true, false]);
        // equal cost, worse error: excluded (one-axis domination)
        assert_eq!(pareto_frontier(&[(10, 0.1), (10, 0.2)]), vec![true, false]);
        // incomparable points: both kept
        assert_eq!(pareto_frontier(&[(10, 0.5), (20, 0.1)]), vec![true, true]);
        // exact ties on both axes: all kept
        assert_eq!(pareto_frontier(&[(10, 0.1), (10, 0.1), (30, 0.0)]), vec![true, true, true]);
        // a chain: only the staircase survives
        let pts = [(5, 0.9), (6, 0.9), (5, 1.0), (4, 1.5), (9, 0.05)];
        assert_eq!(pareto_frontier(&pts), vec![true, false, false, true, true]);
        assert!(pareto_frontier(&[]).is_empty());
    }

    #[test]
    fn publish_exposes_gauges() {
        let m = Metrics::new();
        sample().publish(&m);
        assert_eq!(m.counter("compress.runs"), 1);
        assert_eq!(m.gauge_value("compress.lcc.additions"), Some(100.0));
        assert_eq!(m.gauge_value("compress.final_ratio"), Some(10.0));
        assert_eq!(m.gauge_value("compress.prune.rel_err"), Some(0.0));
    }
}
